//! Integration: schedules + calibrated presets reproduce the paper's
//! headline efficiency claims (shape, not absolute numbers), and the real
//! threaded executor agrees with the fused-HLO oracle numerically.

use std::path::Path;
use std::sync::Arc;

use scmoe::cluster::{LinkModel, Scenario};
use scmoe::coordinator::adaptive::overlap_fraction;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::exec::{run_pair_real, Cluster};
use scmoe::coordinator::schedule::build_pair_schedule_auto;
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::report::efficiency::{gpt_proxy_costs, proxy_costs, train_costs};
use scmoe::runtime::{Engine, HostTensor};

#[test]
fn paper_claim_speedup_bands() {
    // Table 2 (PCIe): ScMoE 1.43x train / 1.66x inference over top-2.
    let c = proxy_costs(Scenario::PcieA30x8);
    let ct = train_costs(&c);
    let base_inf = build_pair_schedule_auto(&c, MoEKind::Standard { k: 2 },
                                            Strategy::Sequential).makespan();
    let base_tr = build_pair_schedule_auto(&ct, MoEKind::Standard { k: 2 },
                                           Strategy::Sequential).makespan();
    let sc_inf = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                          Strategy::Overlap).makespan();
    let sc_tr = build_pair_schedule_auto(&ct, MoEKind::ScMoE { k: 1 },
                                         Strategy::Overlap).makespan();
    let sp_inf = base_inf / sc_inf;
    let sp_tr = base_tr / sc_tr;
    assert!((1.3..2.0).contains(&sp_inf), "PCIe inference speedup {sp_inf}");
    assert!((1.2..1.8).contains(&sp_tr), "PCIe train speedup {sp_tr}");

    // Table 3 (NVLink): 1.12x / 1.17x.
    let c = gpt_proxy_costs(Scenario::NvlinkA800x8);
    let ct = train_costs(&c);
    let b_inf = build_pair_schedule_auto(&c, MoEKind::Standard { k: 2 },
                                         Strategy::Sequential).makespan();
    let b_tr = build_pair_schedule_auto(&ct, MoEKind::Standard { k: 2 },
                                        Strategy::Sequential).makespan();
    let s_inf = b_inf / build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                                 Strategy::Overlap).makespan();
    let s_tr = b_tr / build_pair_schedule_auto(&ct, MoEKind::ScMoE { k: 1 },
                                               Strategy::Overlap).makespan();
    assert!((1.05..1.35).contains(&s_inf), "NVLink inference speedup {s_inf}");
    assert!((1.03..1.3).contains(&s_tr), "NVLink train speedup {s_tr}");
}

#[test]
fn paper_claim_overlap_band_70_to_100() {
    // §1: "a substantial overlap of 70% to 100%" across the scenarios.
    for sc in Scenario::all() {
        let c = proxy_costs(sc);
        let f = overlap_fraction(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!((0.70..=1.0).contains(&f),
                "{}: overlap fraction {f}", sc.label());
    }
}

#[test]
fn paper_claim_scmoe_beats_top1_when_comm_over_20pct() {
    // Table 2 / §4.2.2: ScMoE surpasses the standard top-1 MoE by 13%
    // (train) / 20% (inference) on PCIe where comm is 60% of MoE time.
    let c = proxy_costs(Scenario::PcieA30x8);
    let top1 = build_pair_schedule_auto(&c, MoEKind::Standard { k: 1 },
                                        Strategy::Sequential).makespan();
    let sc = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                      Strategy::Overlap).makespan();
    let gain = top1 / sc - 1.0;
    assert!((0.05..0.35).contains(&gain),
            "ScMoE gain over top-1 on PCIe: {gain}");
    // and on NVLink (comm 15% < 20%): top-1 is NOT clearly beaten — the
    // crossover the paper describes in §4.2.3.
    let c = proxy_costs(Scenario::NvlinkA800x8);
    let top1_nv = build_pair_schedule_auto(&c, MoEKind::Standard { k: 1 },
                                           Strategy::Sequential).makespan();
    let sc_nv = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                         Strategy::Overlap).makespan();
    assert!(sc_nv > top1_nv * 0.95,
            "below the ~20% comm crossover ScMoE shouldn't dominate top-1");
}

#[test]
fn paper_claim_fig8_improvements() {
    // Fig. 8a (PCIe): ScMoE ≈ 27% over shared-expert, ≈ 42% over pipelined
    // top-2; Fig. 8c (2-node): 24% and 43%. Assert generous bands.
    for (sc, lo_se, hi_se) in [(Scenario::PcieA30x8, 0.10, 0.45),
                               (Scenario::TwoNodeA800x16, 0.10, 0.45)] {
        let c = proxy_costs(sc);
        let shared = build_pair_schedule_auto(&c, MoEKind::SharedExpert,
                                              Strategy::Pipelined { chunks: 1 }).makespan();
        let top2p = build_pair_schedule_auto(&c, MoEKind::Standard { k: 2 },
                                             Strategy::Pipelined { chunks: 2 }).makespan();
        let scmoe = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                             Strategy::Overlap).makespan();
        let over_se = shared / scmoe - 1.0;
        let over_t2 = top2p / scmoe - 1.0;
        assert!((lo_se..hi_se).contains(&over_se),
                "{}: vs shared-expert {over_se}", sc.label());
        assert!(over_t2 > 0.2, "{}: vs pipelined top-2 {over_t2}", sc.label());
    }
}

#[test]
fn real_distributed_pair_matches_fused_oracle_and_overlap_wins() {
    let root = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/ops_tiny"));
    if !root.join("manifest.json").exists() {
        eprintln!("skipping: ops artifacts not built");
        return;
    }
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(root).unwrap();
    let m = &set.manifest;
    let (t, d) = (m.tokens, m.config.d_model);
    let k = 1usize;
    let cluster = Cluster::spawn(&set, 4, k).unwrap();

    let x: Vec<f32> = (0..t * d).map(|i| ((i * 29 % 97) as f32 / 97.0) - 0.5).collect();
    let xt = HostTensor::f32(vec![t, d], x);

    // link injected at a scale where comm dominates a backbone op
    let link = LinkModel::new(0.0, 50e6); // slow on purpose
    let ovl_spec = ScheduleSpec::new(MoEKind::ScMoE { k }, Strategy::Overlap);
    let seq_spec = ScheduleSpec::new(MoEKind::ScMoE { k }, Strategy::Sequential);
    let (y_overlap, _) =
        run_pair_real(&set, &cluster, &xt, &ovl_spec, None, link, 1.0, 2).unwrap();
    let (y_seq, _) =
        run_pair_real(&set, &cluster, &xt, &seq_spec, None, link, 1.0, 2).unwrap();

    // numerics: both strategies produce identical results
    for (a, b) in y_overlap.iter().zip(&y_seq) {
        assert!((a - b).abs() < 1e-5, "overlap vs sequential numerics");
    }

    // numerics vs fused oracle
    let w = &cluster.weights;
    let fused = set.get("moe_fused_op_k1").unwrap();
    let yf = fused.run(&[xt.clone(), w.ln_g.clone(), w.ln_b.clone(), w.wg.clone(),
                         w.w1.clone(), w.b1.clone(), w.w2.clone(), w.b2.clone()])
        .unwrap();
    let yf = yf[0].as_f32().unwrap();
    let mut max_err = 0.0f32;
    for (a, b) in y_overlap.iter().zip(yf) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-4, "distributed != fused oracle: {max_err}");

    // wall-clock: overlap hides the injected comm behind the backbone
    let time = |spec: &ScheduleSpec| {
        let t0 = std::time::Instant::now();
        run_pair_real(&set, &cluster, &xt, spec, None, link, 1.0, 2).unwrap();
        t0.elapsed().as_secs_f64()
    };
    // median of 3
    let mut seq_t: Vec<f64> = (0..3).map(|_| time(&seq_spec)).collect();
    let mut ovl_t: Vec<f64> = (0..3).map(|_| time(&ovl_spec)).collect();
    seq_t.sort_by(|a, b| a.total_cmp(b));
    ovl_t.sort_by(|a, b| a.total_cmp(b));
    assert!(ovl_t[1] < seq_t[1],
            "overlap ({:.3}s) should beat sequential ({:.3}s)", ovl_t[1], seq_t[1]);
}
