//! Acceptance tests for load-true expert compute (the `ExpertLoad` model):
//!
//! - per-chunk expert loads partition the unchunked per-device loads
//!   exactly (integers), and per-chunk expert durations partition the
//!   load-scaled expert time;
//! - balanced routing reduces *bit-exactly* to the pre-load model (the
//!   load scale is exactly 1.0, so every span of every strategy matches
//!   the loads-cleared cost model with `==` — the same property that
//!   keeps the non-routed golden corpus lines byte-identical);
//! - an `imbalance_skewed` placement strictly stretches the hot device's
//!   Expert span and the fleet makespan vs the balanced block layout;
//! - the load-skew study's headline reordering: a
//!   comm-balanced-but-compute-overloaded layout that used to beat the
//!   balanced sequential baseline under the naive model loses to it under
//!   load-true pricing.

use scmoe::cluster::{LinkModel, Scenario, Topology};
use scmoe::coordinator::costs::{ComputeCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::spec::{CostModel, ScheduleSpec};
use scmoe::moe::{ExpertLoad, Placement, RoutingTable};
use scmoe::report::efficiency::load_skew_study_rows;
use scmoe::simtime::Resource;
use scmoe::util::propcheck::{check, gen};

fn flat_topology(n_devices: usize) -> Topology {
    Topology {
        n_devices,
        devices_per_node: n_devices,
        intra: LinkModel::new(1e-6, 1e9),
        inter: None,
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    }
}

#[test]
fn prop_chunk_expert_loads_partition_unchunked_loads() {
    check("chunk-load-partition", 100, |r| gen::routing(r), |input| {
        let (idx, w, t, k, e) = input;
        let rt = RoutingTable::build(idx, w, *t, *k, *e, t * k);
        let p = Placement::new(*e, *e);
        let full = ExpertLoad::from_routing(&rt, &p);
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(),
                                         &flat_topology(*e), &rt, &p, 64);
        for chunks in [1usize, 2, 3, 5] {
            // kept copies per device partition exactly (integers)
            let mut sums = vec![0usize; *e];
            for part in rt.chunk(chunks) {
                let pl = ExpertLoad::from_routing(&part, &p);
                for (s, l) in sums.iter_mut().zip(&pl.per_device) {
                    *s += *l;
                }
            }
            if sums != full.per_device {
                return Err(format!("chunks={chunks}: {sums:?} != {:?}",
                                   full.per_device));
            }
            // and the per-chunk expert durations partition the
            // load-scaled expert time
            let ca = tc.chunk_phases(*k, chunks);
            for d in 0..*e {
                let total: f64 = (0..chunks).map(|i| ca.expert[i][d]).sum();
                let expect = tc.expert_time(d, *k);
                if (total - expect).abs() > 1e-12 {
                    return Err(format!(
                        "dev {d} chunks={chunks}: {total} vs {expect}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn balanced_routing_reduces_bit_exactly_to_the_unscaled_model() {
    // every expert exactly equally hot: the load scale is exactly 1.0,
    // so every span of every strategy matches the loads-cleared model
    // with == (this is why only genuinely skewed golden corpus entries
    // drifted when the load model landed)
    let e = 8usize;
    let tokens = 64;
    let idx: Vec<i32> = (0..tokens).map(|t| (t % e) as i32).collect();
    let w = vec![1.0f32; tokens];
    let rt = RoutingTable::build(&idx, &w, tokens, 1, e, tokens);
    let topo = Topology {
        n_devices: 8,
        devices_per_node: 4,
        intra: LinkModel::new(1e-6, 1e9),
        inter: Some(LinkModel::new(1e-5, 1e8)),
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    };
    let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo, &rt,
                                     &Placement::new(8, 8), 512);
    let load = tc.expert_load.as_ref().unwrap();
    assert_eq!(load.per_device, vec![8; 8]);
    for d in 0..8 {
        assert_eq!(load.scale(d), 1.0);
    }
    let mut naive = tc.clone();
    naive.expert_load = None;
    // chunk counts must split the balanced token pattern evenly: an
    // uneven token split (e.g. 64 tokens into 3 chunks of 22/22/20)
    // gives chunks genuinely different loads, which the token-true model
    // correctly prices differently from the even division — that is the
    // feature, not drift
    for (kind, strat, slot) in [
        (MoEKind::ScMoE { k: 1 }, Strategy::Sequential, 0),
        (MoEKind::ScMoE { k: 1 }, Strategy::Pipelined { chunks: 4 }, 0),
        (MoEKind::ScMoE { k: 1 }, Strategy::Overlap, 2),
        (MoEKind::ScMoE { k: 1 }, Strategy::OverlapPipelined { chunks: 2 }, 1),
    ] {
        let spec = ScheduleSpec::new(kind, strat).with_slot(slot);
        let (a, b) = (spec.build(&tc).run(), spec.build(&naive).run());
        assert_eq!(a.len(), b.len(), "{strat:?}");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!((x.start, x.end), (y.start, y.end),
                       "{strat:?}: {} drifted under balanced loads", x.label);
        }
    }
}

#[test]
fn skewed_placement_stretches_hot_expert_span_and_makespan() {
    // the load-skew study's rows: same node-affine routing, balanced
    // block layout vs imbalance-skewed (2 experts/device on the first
    // half of the fleet)
    let topo = Scenario::FourNodeA800IBx32.topology();
    let rows = load_skew_study_rows(&topo, 640, 7);
    let block = &rows[0].1;
    let skew = &rows[1].1;
    assert!(skew.expert_load.as_ref().unwrap().imbalance() > 1.9,
            "pack-2 layout must roughly double the hot devices' load");
    // hot device computes ~2x the balanced mean; the unloaded half
    // computes nothing at all
    assert!(skew.expert_time(0, 1) > 1.5 * block.expert_time(0, 1));
    assert_eq!(skew.expert_time(31, 1), 0.0);

    let seq = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential);
    let hot_span = |tc: &TopoCosts| -> f64 {
        seq.build(tc)
            .run()
            .iter()
            .find(|s| s.label == "Expert" && s.resource == Resource::Compute(0))
            .map(|s| s.end - s.start)
            .expect("device 0 expert span")
    };
    // mirrored: 1.498ms vs 0.779ms — the hot Expert span genuinely
    // stretches, and the barrier drags the whole fleet with it
    // (6.145ms vs 4.717ms sequential makespan)
    assert!(hot_span(skew) > hot_span(block) + 1e-4);
    assert!(seq.build(skew).makespan() > seq.build(block).makespan() + 1e-4);
}

#[test]
fn load_skew_reorders_seq_vs_overlap_in_the_study() {
    // Acceptance criterion: the load model must reorder at least one
    // seq-vs-overlap comparison in the report study. Under the naive
    // (pre-load) model the skewed overlap schedule still beat the
    // balanced sequential baseline — overloading half the fleet looked
    // free because every device was charged the balanced capacity batch.
    let topo = Scenario::FourNodeA800IBx32.topology();
    let kind = MoEKind::ScMoE { k: 1 };
    let rows = load_skew_study_rows(&topo, 640, 7);
    let block = &rows[0].1;
    let skew = &rows[1].1;
    let mut block_naive = block.clone();
    block_naive.expert_load = None;
    let mut skew_naive = skew.clone();
    skew_naive.expert_load = None;

    let seq = ScheduleSpec::new(kind, Strategy::Sequential);
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    let seq_block_naive = seq.build(&block_naive).makespan();
    let seq_block_true = seq.build(block).makespan();
    let (_, ovl_skew_naive) = ovl.choose_slot(&skew_naive);
    let (_, ovl_skew_true) = ovl.choose_slot(skew);

    // naive model: skewed overlap (mirrored 4.026ms) "beats" the balanced
    // sequential baseline (4.658ms)...
    assert!(ovl_skew_naive < seq_block_naive,
            "naive: {ovl_skew_naive} vs {seq_block_naive}");
    // ...load-true pricing flips the comparison (4.809ms vs 4.717ms)
    assert!(ovl_skew_true > seq_block_true,
            "load-true: {ovl_skew_true} vs {seq_block_true}");
}
