//! Warm-start invalidation suite for the `SimArena` re-pricing path:
//! a warm rebuild (re-pricing a cached skeleton) must be bit-identical
//! to a cold build under every cost model, every structural change must
//! fall back to a cold build, what-if appends must be shed, and the
//! arena-powered timeline/serving loops must match cold-built references
//! field for field — the "stale-cache hits are impossible" pin for the
//! report's replace and serve configurations.

#[path = "common/generators.rs"]
mod generators;

use generators::{fleet_costs_scaled, fleet_sweep_specs, routed_base_costs,
                 routed_topology};
use scmoe::cluster::{LinkModel, Topology};
use scmoe::coordinator::costs::{ComputeCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::replace::{run_replace_timeline, MigrationPlan,
                                  ReplaceConfig, ReplacePolicy};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{phase_affine_routing, AffinityEstimator, Placement,
                 RoutingTable};
use scmoe::serve::{run_serve, BatchPolicy, Request, ServeConfig,
                   TrafficProfile};
use scmoe::simtime::{Sim, SimArena};

fn assert_sims_identical(name: &str, a: &Sim, b: &Sim) {
    assert_eq!(a.len(), b.len(), "{name}: task count");
    for (x, y) in a.tasks().iter().zip(b.tasks()) {
        assert_eq!(x.label, y.label, "{name}: label");
        assert_eq!(x.resource, y.resource, "{name}: resource of {}", x.label);
        assert_eq!(x.duration.to_bits(), y.duration.to_bits(),
                   "{name}: duration of {}", x.label);
        assert_eq!(x.deps, y.deps, "{name}: deps of {}", x.label);
    }
}

/// A warm rebuild under a different cost model is bit-identical — task
/// list, spans, blockers, makespan — to a cold build under that model.
#[test]
fn warm_rebuild_is_bit_identical_to_cold() {
    for (name, spec) in fleet_sweep_specs() {
        let mut arena = SimArena::new();
        for (i, scale) in [1.0, 1.5, 0.5, 1.25].into_iter().enumerate() {
            let tc = fleet_costs_scaled(4, 2, scale);
            let built = spec.build_into(&tc, &mut arena);
            assert_eq!(built.warm, i > 0, "{name}: warm flag at build {i}");
            let cold = spec.build(&tc);
            assert_sims_identical(&format!("{name}@x{scale}"), arena.sim(),
                                  &cold.sim);
            assert_eq!(arena.makespan().to_bits(),
                       cold.makespan().to_bits(), "{name}@x{scale}: makespan");
            let warm_traced = arena.run_traced();
            let cold_traced = cold.sim.run_traced();
            for (w, c) in warm_traced.spans.iter().zip(&cold_traced.spans) {
                assert_eq!((w.start.to_bits(), w.end.to_bits()),
                           (c.start.to_bits(), c.end.to_bits()),
                           "{name}@x{scale}: span {}", w.label);
            }
            for (w, c) in
                warm_traced.blockers.iter().zip(&cold_traced.blockers)
            {
                assert_eq!(w.map(|b| (b.pred, b.kind)),
                           c.map(|b| (b.pred, b.kind)),
                           "{name}@x{scale}: blocker");
            }
        }
    }
}

/// Every structural change — chunk count, strategy, pipelining, slot,
/// device count — misses the cache on first encounter (cold build), and
/// revisiting a cached shape is warm again with correct results.
#[test]
fn structural_changes_fall_back_to_cold() {
    let mut arena = SimArena::new();
    let tc8 = fleet_costs_scaled(4, 2, 1.0);
    let tc16 = fleet_costs_scaled(4, 4, 1.0); // more devices, same builder
    let sc = MoEKind::ScMoE { k: 1 };
    let pipe2 = ScheduleSpec::new(sc, Strategy::Pipelined { chunks: 2 });
    let pipe4 = ScheduleSpec::new(sc, Strategy::Pipelined { chunks: 4 });
    let ovl2 = ScheduleSpec::new(sc, Strategy::Overlap).with_slot(2);
    let ovl3 = ScheduleSpec::new(sc, Strategy::Overlap).with_slot(3);

    assert!(!pipe2.build_into(&tc8, &mut arena).warm, "first pipe2");
    assert!(!pipe4.build_into(&tc8, &mut arena).warm, "chunk count changed");
    assert!(!ovl2.build_into(&tc8, &mut arena).warm, "strategy changed");
    assert!(!ovl3.build_into(&tc8, &mut arena).warm, "slot changed");
    assert!(!pipe2.build_into(&tc16, &mut arena).warm, "device count changed");
    // revisits of cached shapes are warm and still correct
    for (name, spec, tc) in [("pipe2", pipe2, &tc8), ("pipe4", pipe4, &tc8),
                             ("ovl2", ovl2, &tc8), ("ovl3", ovl3, &tc8),
                             ("pipe2@16", pipe2, &tc16)] {
        assert!(spec.build_into(tc, &mut arena).warm, "{name} revisit");
        assert_sims_identical(name, arena.sim(), &spec.build(tc).sim);
    }
}

/// Tasks appended after a build (migration what-ifs) are priced by the
/// next run and shed by the next build — never leaked into a warm hit.
#[test]
fn appended_migration_tasks_are_priced_then_shed() {
    let topo = routed_topology();
    let base = routed_base_costs();
    let rt = generators::routed_table();
    let block = Placement::new(4, 4);
    let affinity = Placement::affinity_packed(&rt, 4, 2);
    let plan = MigrationPlan::between(&block, &affinity, 4096);
    let h2d = LinkModel::new(0.125, 1024.0);
    let d2h = LinkModel::new(0.0625, 2048.0);
    let tc = TopoCosts::from_routing(&base, &topo, &rt, &block, 64);
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential);

    let mut arena = SimArena::new();
    spec.build_into(&tc, &mut arena);
    let clean = arena.makespan();
    plan.add_transfer_tasks(arena.sim_mut(), &h2d, Some(&d2h), 0);

    let mut cold = spec.build(&tc);
    plan.add_transfer_tasks(&mut cold.sim, &h2d, Some(&d2h), 0);
    assert_sims_identical("with-migration", arena.sim(), &cold.sim);
    assert_eq!(arena.makespan().to_bits(), cold.makespan().to_bits());

    // the next build of the same shape is warm, sheds the appends, and
    // reproduces the clean schedule exactly
    assert!(spec.build_into(&tc, &mut arena).warm);
    assert_sims_identical("shed", arena.sim(), &spec.build(&tc).sim);
    assert_eq!(arena.makespan().to_bits(), clean.to_bits());
}

/// The arena-backed slot search returns the same argmin (and the same
/// makespan bits) as the cold search, on the miss pass and the warm pass.
#[test]
fn choose_slot_in_matches_choose_slot() {
    let tc = fleet_costs_scaled(4, 2, 1.0);
    for spec in [
        ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap),
        ScheduleSpec::new(MoEKind::ScMoE { k: 2 },
                          Strategy::OverlapPipelined { chunks: 2 }),
        ScheduleSpec::new(MoEKind::Standard { k: 2 }, Strategy::Sequential),
    ] {
        let mut arena = SimArena::new();
        let cold = spec.choose_slot(&tc);
        for pass in 0..2 {
            let warm = spec.choose_slot_in(&tc, &mut arena);
            assert_eq!(warm.0, cold.0, "slot, pass {pass}");
            assert_eq!(warm.1.to_bits(), cold.1.to_bits(),
                       "makespan, pass {pass}");
        }
        // adaptive resolution goes through the same search
        let built = spec.adaptive().build_into(&tc, &mut arena);
        assert_eq!(built.expert_slot, cold.0);
        assert_eq!(spec.adaptive().build(&tc).expert_slot, cold.0);
    }
}

/// Pre-PR cold-built replace-timeline loop, kept as the reference the
/// arena-powered [`run_replace_timeline`] must reproduce bit-exactly:
/// every step builds fresh sims with `spec.build` and no caching of any
/// kind. Returns per-step
/// `(makespan, base_makespan, migrated, migration_bytes, migration_time)`.
#[allow(clippy::type_complexity)]
fn cold_reference_timeline(base: &ComputeCosts, topo: &Topology,
                           token_bytes: usize, tables: &[RoutingTable],
                           initial: &Placement, cfg: &ReplaceConfig)
                           -> Vec<(f64, f64, bool, usize, f64)> {
    let n_nodes = topo.n_devices / topo.devices_per_node;
    let mut est =
        AffinityEstimator::ewma(initial.n_experts, n_nodes, cfg.decay);
    let mut placement = initial.clone();
    let mut out = Vec::with_capacity(tables.len());
    let n_steps = tables.len();
    for (s, rt) in tables.iter().enumerate() {
        let costs = TopoCosts::from_routing(base, topo, rt, &placement,
                                            token_bytes);
        let mut sched = cfg.spec.build(&costs);
        let base_makespan = sched.makespan();
        est.observe(rt, topo.n_devices, topo.devices_per_node);
        let remaining = n_steps - s - 1;
        let mut migrated = false;
        let mut migration_bytes = 0usize;
        let mut migration_time = 0.0f64;
        if remaining > 0 && cfg.policy != ReplacePolicy::Never {
            let candidate = est.packed(topo.n_devices, topo.devices_per_node);
            let plan = MigrationPlan::between(&placement, &candidate,
                                              cfg.bytes_per_expert);
            if !plan.is_empty() {
                let mig = plan.transfer_time(&cfg.h2d, cfg.d2h_link.as_ref());
                let overhead = (mig - base_makespan).max(0.0);
                let saving = match cfg.policy {
                    ReplacePolicy::BreakEven => {
                        let cand = TopoCosts::from_routing(
                            base, topo, rt, &candidate, token_bytes);
                        base_makespan - cfg.spec.build(&cand).makespan()
                    }
                    _ => 0.0,
                };
                if cfg.policy.should_migrate(s, remaining, saving, overhead) {
                    plan.add_transfer_tasks(&mut sched.sim, &cfg.h2d,
                                            cfg.d2h_link.as_ref(), 0);
                    migrated = true;
                    migration_bytes = plan.total_bytes();
                    migration_time = mig;
                    placement = candidate;
                }
            }
        }
        let makespan = if migrated { sched.makespan() } else { base_makespan };
        out.push((makespan, base_makespan, migrated, migration_bytes,
                  migration_time));
    }
    out
}

fn drift_tables(n_steps: usize, seed: u64) -> Vec<RoutingTable> {
    (0..n_steps)
        .map(|s| phase_affine_routing(4, 2, 4, 16, 0, 0, 0.25, 0.25,
                                      seed + s as u64))
        .collect()
}

/// The stale-hit-impossible pin: across every replace policy, with and
/// without D2H source pricing, fixed and adaptive slots, the warm-started
/// timeline equals the cold-built reference loop bit for bit on every
/// step field.
#[test]
fn replace_timeline_matches_cold_reference_bit_exactly() {
    let topo = routed_topology();
    let base = routed_base_costs();
    let initial = Placement::new(4, 4);
    let tables = drift_tables(8, 131);
    let h2d = LinkModel::new(0.125, 1024.0);
    let specs = [
        ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential),
        ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
            .adaptive(),
        ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                          Strategy::Pipelined { chunks: 2 }),
    ];
    let policies = [ReplacePolicy::BreakEven, ReplacePolicy::EveryK { k: 2 },
                    ReplacePolicy::Never];
    for spec in specs {
        for policy in policies {
            for d2h in [None, Some(LinkModel::new(0.0625, 2048.0))] {
                let cfg = ReplaceConfig {
                    spec,
                    policy,
                    bytes_per_expert: 4096,
                    h2d: h2d.clone(),
                    d2h_link: d2h,
                    decay: 1.0,
                };
                let outcome = run_replace_timeline(&base, &topo, 64, &tables,
                                                   &initial, &cfg);
                let reference = cold_reference_timeline(&base, &topo, 64,
                                                        &tables, &initial,
                                                        &cfg);
                assert_eq!(outcome.steps.len(), reference.len());
                for (step, want) in outcome.steps.iter().zip(&reference) {
                    let tag = format!("{policy:?}/{:?}/step{}",
                                      spec.strategy, step.step);
                    assert_eq!(step.makespan.to_bits(), want.0.to_bits(),
                               "{tag}: makespan");
                    assert_eq!(step.base_makespan.to_bits(), want.1.to_bits(),
                               "{tag}: base_makespan");
                    assert_eq!(step.migrated, want.2, "{tag}: migrated");
                    assert_eq!(step.migration_bytes, want.3, "{tag}: bytes");
                    assert_eq!(step.migration_time.to_bits(),
                               want.4.to_bits(), "{tag}: migration_time");
                }
            }
        }
    }
}

/// The serving loop's arena path against per-step cold builds: with a
/// static placement every step's makespan must equal a fresh
/// `spec.build` on that step's table — the serve-side stale-hit pin.
#[test]
fn serve_steps_match_cold_builds() {
    let topo = routed_topology();
    let base = routed_base_costs();
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                 Strategy::Sequential);
    let seed = 977u64;
    let requests: Vec<Request> = (0..6)
        .map(|id| Request { id, arrival: 0.0, prefill_tokens: 16,
                            decode_steps: 0 })
        .collect();
    let cfg = ServeConfig {
        spec,
        batching: BatchPolicy::WaitK { k: 1 },
        policy: ReplacePolicy::Never,
        decay: 1.0,
        bytes_per_expert: 4096,
        h2d: LinkModel::new(0.125, 1024.0),
        token_bytes: 64,
        decode_tokens: 0,
        n_experts: 4,
        traffic: TrafficProfile { regime: 0, shift_at: None,
                                  prefill_noise: 0.25, decode_noise: 0.25,
                                  seed },
    };
    let block = Placement::new(4, 4);
    let outcome = run_serve(&base, &topo, &requests, &block, &cfg);
    assert_eq!(outcome.steps.len(), requests.len());
    for step in &outcome.steps {
        let rt = phase_affine_routing(4, 2, 4, 16, 0, 0, 0.25, 0.25,
                                      seed + step.step as u64);
        let tc = TopoCosts::from_routing(&base, &topo, &rt, &block, 64);
        let cold = spec.build(&tc).makespan();
        assert_eq!(step.base_makespan.to_bits(), cold.to_bits(),
                   "step {}", step.step);
        assert_eq!(step.makespan.to_bits(), cold.to_bits(),
                   "step {}", step.step);
    }
}
