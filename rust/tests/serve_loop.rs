//! Acceptance tests for the open-loop serving simulator: the bit-exact
//! closed-system reduction to `run_replace_timeline`, the phase-affine
//! traffic generator's degeneration to the PR 5 drifting generator at
//! study scale, batching-policy structure, seeded determinism, and the
//! pinned study-cell numbers on 32xA800-4node-IB. Every pinned value
//! was minted through the validated DES mirror
//! (`tools/des_mirror/mirror2.py --serve-study`).

use scmoe::cluster::Scenario;
use scmoe::coordinator::costs::Strategy;
use scmoe::coordinator::replace::ReplacePolicy;
use scmoe::moe::{phase_affine_routing, Placement, RoutingTable};
use scmoe::report::efficiency::{drifting_node_affine_routing, xl_compute_costs};
use scmoe::report::replace::{
    run_study, study_h2d_link, study_tables, STUDY_BYTES_PER_EXPERT,
    STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, STUDY_TOKENS_PER_DEVICE,
    STUDY_TOKEN_BYTES,
};
use scmoe::report::serve_report::{
    hetero_requests, knee_load, run_hetero_cell, run_serve_cell, serve_spec,
    SERVE_BUDGET, SERVE_LOADS, SERVE_REQUESTS, SERVE_SLO,
};
use scmoe::serve::{
    run_serve, trace_arrivals, BatchPolicy, ServeConfig, TrafficProfile,
};

fn experts(rt: &RoutingTable) -> Vec<usize> {
    rt.routes.iter().map(|r| r.expert).collect()
}

#[test]
fn phase_affine_degenerates_to_drifting_at_study_scale() {
    // equal phase noises + evenly divisible tokens -> the serving
    // traffic generator IS the PR 5 study generator, bit-exactly (the
    // serving study's prefill steps reuse the replace study's tables)
    for (regime, noise, seed) in [(0, STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED),
                                  (1, 0.15, 211)] {
        let a = drifting_node_affine_routing(32, 8, 32,
                                             STUDY_TOKENS_PER_DEVICE, regime,
                                             noise, seed);
        let b = phase_affine_routing(32, 8, 32,
                                     32 * STUDY_TOKENS_PER_DEVICE, 0, regime,
                                     noise, noise, seed);
        assert_eq!(experts(&a), experts(&b));
        assert_eq!(a.load, b.load);
        assert_eq!(a.kept(), b.kept());
    }
}

#[test]
fn closed_system_serving_is_the_replace_timeline_bit_exactly() {
    // all requests at t = 0, wait-1 batching, prefill-only: the serving
    // loop admits exactly one 20480-token prefill per step and its
    // `remaining` counter equals the timeline's remaining-steps count,
    // so every makespan, migration decision, and byte count must equal
    // run_replace_timeline over the same table stream with `==`
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let n = tables.len();
    let prompt = 32 * STUDY_TOKENS_PER_DEVICE;
    let requests = trace_arrivals(&vec![(0.0, prompt, 0); n]);
    for policy in [ReplacePolicy::Never, ReplacePolicy::BreakEven] {
        let reference = run_study(&tables, policy, 1.0);
        let cfg = ServeConfig {
            spec: serve_spec(Strategy::Sequential),
            batching: BatchPolicy::WaitK { k: 1 },
            policy,
            decay: 1.0,
            bytes_per_expert: STUDY_BYTES_PER_EXPERT,
            h2d: study_h2d_link(),
            token_bytes: STUDY_TOKEN_BYTES,
            decode_tokens: 0,
            n_experts: 32,
            traffic: TrafficProfile {
                regime: 0,
                shift_at: None,
                prefill_noise: STUDY_DRIFT_NOISE,
                decode_noise: STUDY_DRIFT_NOISE,
                seed: STUDY_DRIFT_SEED,
            },
        };
        let out = run_serve(&base, &topo, &requests, &Placement::new(32, 32),
                            &cfg);
        assert_eq!(out.steps.len(), n);
        assert_eq!(out.migrations, reference.migrations);
        assert_eq!(out.total_time, reference.total); // bit-exact
        assert_eq!(out.busy, out.total_time, "no idle gaps at t = 0");
        assert_eq!(out.latencies.len(), n);
        for (s, r) in out.steps.iter().zip(&reference.steps) {
            assert_eq!(s.step, r.step);
            assert_eq!(s.makespan, r.makespan); // bit-exact, no tolerance
            assert_eq!(s.base_makespan, r.base_makespan);
            assert_eq!(s.migrated, r.migrated);
            assert_eq!(s.migration_bytes, r.migration_bytes);
            assert_eq!(s.migration_time, r.migration_time);
            assert_eq!(s.prefills, 1);
            assert_eq!(s.prefill_tokens, prompt);
            assert_eq!(s.decodes, 0);
            assert_eq!(s.decode_tokens, 0);
        }
        for e in 0..32 {
            assert_eq!(out.final_placement.device_of(e),
                       reference.final_placement.device_of(e));
        }
    }
}

#[test]
fn deadline_batching_holds_prefills_then_steps_decode() {
    // two requests at t = 0 under a 1-second deadline: the loop waits
    // out the window (first step starts at exactly 1.0), admits both,
    // then runs their four decode iterations as pure-decode steps
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let requests = trace_arrivals(&[(0.0, 2048, 4), (0.0, 2048, 4)]);
    let cfg = ServeConfig {
        spec: serve_spec(Strategy::Sequential),
        batching: BatchPolicy::Deadline { window: 1.0 },
        policy: ReplacePolicy::Never,
        decay: 1.0,
        bytes_per_expert: STUDY_BYTES_PER_EXPERT,
        h2d: study_h2d_link(),
        token_bytes: STUDY_TOKEN_BYTES,
        decode_tokens: 64,
        n_experts: 32,
        traffic: TrafficProfile {
            regime: 0,
            shift_at: None,
            prefill_noise: 0.05,
            decode_noise: 0.25,
            seed: 7,
        },
    };
    let out = run_serve(&base, &topo, &requests, &Placement::new(32, 32),
                        &cfg);
    assert_eq!(out.steps.len(), 5, "1 joint prefill + 4 decode steps");
    assert_eq!(out.steps[0].start, 1.0, "deadline launches at the window");
    assert_eq!(out.steps[0].prefills, 2);
    assert_eq!(out.steps[0].prefill_tokens, 4096);
    assert_eq!(out.steps[0].decodes, 0);
    for s in &out.steps[1..] {
        assert_eq!(s.prefills, 0);
        assert_eq!(s.decodes, 2);
        assert_eq!(s.decode_tokens, 128);
    }
    assert_eq!(out.steps[4].completed, 2);
    assert_eq!(out.latencies.len(), 2);
    // latency includes the full deadline wait
    assert!(out.latencies.iter().all(|&l| l > 1.0));
}

#[test]
fn token_budget_is_respected_on_every_step() {
    let out = run_serve_cell(SERVE_LOADS[2], Strategy::Sequential,
                             BatchPolicy::TokenBudget { budget: SERVE_BUDGET },
                             ReplacePolicy::Never);
    assert_eq!(out.latencies.len(), SERVE_REQUESTS);
    for s in &out.steps {
        assert!(s.prefill_tokens + s.decode_tokens <= SERVE_BUDGET,
                "step {} holds {} tokens over the {} budget",
                s.step, s.prefill_tokens + s.decode_tokens, SERVE_BUDGET);
        assert!(s.prefill_tokens > 0 || s.decode_tokens > 0,
                "steps only launch when something runs");
    }
    // the virtual clock includes idle gaps the fleet doesn't work through
    assert!(out.busy <= out.total_time);
    assert!(out.goodput(SERVE_SLO) <= out.throughput() + 1e-12);
    assert!(out.p50() <= out.p99());
}

#[test]
fn serving_runs_are_seeded_and_deterministic() {
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    let a = run_serve_cell(SERVE_LOADS[0], Strategy::Sequential, budget,
                           ReplacePolicy::BreakEven);
    let b = run_serve_cell(SERVE_LOADS[0], Strategy::Sequential, budget,
                           ReplacePolicy::BreakEven);
    assert_eq!(a.latencies, b.latencies); // bit-exact, not statistical
    assert_eq!(a.p50(), b.p50());
    assert_eq!(a.p99(), b.p99());
    assert_eq!(a.migrations, b.migrations);
    assert_eq!(a.steps.len(), b.steps.len());
}

#[test]
fn pinned_mid_load_cell_matches_the_mirror() {
    // 240 req/s, sequential, budget-6144, break-even replacement —
    // every value minted via mirror2.py --serve-study
    let out = run_serve_cell(SERVE_LOADS[1], Strategy::Sequential,
                             BatchPolicy::TokenBudget { budget: SERVE_BUDGET },
                             ReplacePolicy::BreakEven);
    assert_eq!(out.steps.len(), 69);
    assert_eq!(out.migrations, 1);
    assert!((out.p50() - 0.0218996409740376).abs() < 1e-12);
    assert!((out.p99() - 0.02451450296505059).abs() < 1e-12);
    assert!((out.throughput() - 220.71254693080124).abs() < 1e-9);
    assert!((out.goodput(SERVE_SLO) - 220.71254693080124).abs() < 1e-9);
    assert!((out.busy - 0.27727460941869164).abs() < 1e-12);
    assert!((out.total_time - 0.28996992191869164).abs() < 1e-12);
}

#[test]
fn pinned_knee_sequential_saturates_where_overlap_holds() {
    // the headline of the study: at 480 req/s the sequential strategy's
    // p99 (33.4 ms static / 32.1 ms replacing) blows the 30 ms SLO, so
    // its knee sits at 240 req/s; adaptive overlap holds 29.6 ms and
    // keeps the knee at the top swept load (values minted via the
    // mirror; replacement also buys sequential ~3 req/s at saturation)
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    let sweep = |strategy, policy| -> Vec<(f64, _)> {
        SERVE_LOADS
            .iter()
            .map(|&rate| (rate, run_serve_cell(rate, strategy, budget, policy)))
            .collect()
    };
    let seq_static = sweep(Strategy::Sequential, ReplacePolicy::Never);
    let seq_replace = sweep(Strategy::Sequential, ReplacePolicy::BreakEven);
    let ovl_static = sweep(Strategy::Overlap, ReplacePolicy::Never);
    assert_eq!(knee_load(&seq_static), Some(240.0));
    assert_eq!(knee_load(&seq_replace), Some(240.0));
    assert_eq!(knee_load(&ovl_static), Some(480.0));
    let (_, seq_n) = &seq_static[2];
    let (_, seq_b) = &seq_replace[2];
    let (_, ovl_n) = &ovl_static[2];
    assert!((seq_n.p99() - 0.033394557878060754).abs() < 1e-12);
    assert!((seq_b.p99() - 0.03207592575449253).abs() < 1e-12);
    assert!((ovl_n.p99() - 0.02957762333282865).abs() < 1e-12);
    assert_eq!(seq_b.migrations, 1);
    assert_eq!(ovl_n.steps.len(), 42);
    assert!((seq_n.throughput() - 377.13767455706653).abs() < 1e-9);
    assert!((seq_b.throughput() - 380.2588736359133).abs() < 1e-9);
    assert!((ovl_n.throughput() - 385.9883989740929).abs() < 1e-9);
    // past the knee, goodput falls away from throughput for sequential
    assert!((seq_n.goodput(SERVE_SLO) - 341.7810175673415).abs() < 1e-9);
    assert!(ovl_n.goodput(SERVE_SLO) > seq_n.goodput(SERVE_SLO));
}

#[test]
fn pinned_batching_policies_at_mid_load() {
    // wait-2 and budget-6144 track each other; the 8 ms deadline holds
    // prompts long enough to cost 6.5 ms of p50 and most of its goodput
    let cell = |batching| {
        run_serve_cell(SERVE_LOADS[1], Strategy::Sequential, batching,
                       ReplacePolicy::BreakEven)
    };
    let wait = cell(BatchPolicy::WaitK { k: 2 });
    assert_eq!(wait.steps.len(), 69);
    assert!((wait.p50() - 0.022130384413016013).abs() < 1e-12);
    assert!((wait.p99() - 0.02502502641358134).abs() < 1e-12);
    let deadline = cell(BatchPolicy::Deadline { window: 0.008 });
    assert_eq!(deadline.steps.len(), 66);
    assert!((deadline.p50() - 0.028436106044618603).abs() < 1e-12);
    assert!((deadline.p99() - 0.03164153448842637).abs() < 1e-12);
    assert!((deadline.goodput(SERVE_SLO) - 146.19129154576098).abs() < 1e-9);
    assert!((deadline.throughput() - 217.58703857973725).abs() < 1e-9);
}

#[test]
fn knee_helper_picks_the_largest_load_within_slo() {
    // synthetic outcomes exercise the helper without full runs
    let mk = |lat: f64| scmoe::serve::ServeOutcome {
        steps: Vec::new(),
        latencies: vec![lat],
        busy: 1.0,
        total_time: 1.0,
        migrations: 0,
        final_placement: Placement::new(4, 4),
    };
    let cells = vec![(120.0, mk(0.01)), (240.0, mk(0.02)), (480.0, mk(0.09))];
    assert_eq!(knee_load(&cells), Some(240.0));
    let none = vec![(120.0, mk(0.9))];
    assert_eq!(knee_load(&none), None);
}

#[test]
fn hetero_trace_alternates_shapes_on_the_same_instants() {
    let homo = scmoe::report::serve_report::serve_requests(SERVE_LOADS[1]);
    let hetero = hetero_requests(SERVE_LOADS[1]);
    assert_eq!(hetero.len(), homo.len());
    for (i, (h, r)) in hetero.iter().zip(&homo).enumerate() {
        assert_eq!(h.id, i);
        assert_eq!(h.arrival, r.arrival); // same Poisson instants, bit-exact
        if i % 2 == 0 {
            assert_eq!((h.prefill_tokens, h.decode_steps), (1024, 2));
        } else {
            assert_eq!((h.prefill_tokens, h.decode_steps), (4096, 8));
        }
    }
}

#[test]
fn pinned_hetero_cells_match_the_mirror() {
    // minted via mirror2.py --serve-hetero-study
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    let out = run_hetero_cell(SERVE_LOADS[1], Strategy::Overlap, budget,
                              ReplacePolicy::Never);
    assert_eq!(out.steps.len(), 75);
    assert_eq!(out.migrations, 0);
    assert!((out.p50() - 0.03461212612973931).abs() < 1e-12);
    assert!((out.p99() - 0.039643354559919436).abs() < 1e-12);
    assert!((out.throughput() - 208.5524638669676).abs() < 1e-9);
    assert!((out.goodput(SERVE_SLO) - 104.2762319334838).abs() < 1e-9);

    let be = run_hetero_cell(SERVE_LOADS[2], Strategy::Sequential, budget,
                             ReplacePolicy::BreakEven);
    assert_eq!(be.steps.len(), 45);
    assert_eq!(be.migrations, 1);
    assert!((be.p50() - 0.03485513348564934).abs() < 1e-12);
    assert!((be.p99() - 0.04598329716723735).abs() < 1e-12);
}

#[test]
fn hetero_slo_bifurcates_by_request_shape() {
    // every short request (half the trace) meets the SLO, no long one
    // does, so goodput is exactly half of throughput at every cell
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    for &load in &SERVE_LOADS {
        for strategy in [Strategy::Sequential, Strategy::Overlap] {
            let out = run_hetero_cell(load, strategy, budget,
                                      ReplacePolicy::Never);
            let within = out.latencies.iter()
                .filter(|&&l| l <= SERVE_SLO).count();
            assert_eq!(within, SERVE_REQUESTS / 2,
                       "{load} req/s {}", strategy.label());
            assert_eq!(out.goodput(SERVE_SLO) * 2.0, out.throughput());
        }
    }
}
