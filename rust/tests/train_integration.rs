//! Integration: the Rust training driver over real AOT artifacts.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use scmoe::runtime::Engine;
use scmoe::train::{TrainOptions, Trainer};

fn artifacts(name: &str) -> Option<PathBuf> {
    let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).join(name);
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: {name} artifacts not built");
        None
    }
}

#[test]
fn scmoe_micro_trains_and_evaluates() {
    let Some(dir) = artifacts("quality_scmoe_micro") else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(&dir).unwrap();
    let mut tr = Trainer::new(&set, 0).unwrap();
    let before = tr.evaluate(2).unwrap();
    let opts = TrainOptions {
        steps: 12,
        eval_every: 0,
        eval_batches: 2,
        verbose: false,
        ..Default::default()
    };
    tr.run(&opts).unwrap();
    let after = tr.evaluate(2).unwrap();
    assert!(after.loss < before.loss,
            "training should reduce eval loss: {} -> {}", before.loss, after.loss);
    // loss curve recorded
    assert_eq!(tr.records.len(), 12);
    // ScMoE stats instrumentation captured (repeat-frac in [0, 1])
    assert!(!tr.stats_rows.is_empty());
    for (_, row) in &tr.stats_rows {
        assert!(row[0] >= 0.0 && row[0] <= 1.0, "repeat frac {row:?}");
        assert!(row[1] >= 0.0, "l2 distance {row:?}");
    }
}

#[test]
fn checkpoint_roundtrip() {
    let Some(dir) = artifacts("quality_top2_micro") else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(&dir).unwrap();
    let mut tr = Trainer::new(&set, 1).unwrap();
    for _ in 0..2 {
        tr.train_step().unwrap();
    }
    let params = tr.params_host().unwrap();
    let tmp = std::env::temp_dir().join("scmoe_ckpt_test.bin");
    scmoe::train::checkpoint::save(&tmp, &set.manifest, &params).unwrap();
    let loaded = scmoe::train::checkpoint::load(&tmp, &set.manifest).unwrap();
    assert_eq!(params.len(), loaded.len());
    for (a, b) in params.iter().zip(&loaded) {
        assert_eq!(a.shape, b.shape);
        assert_eq!(a.as_f32().unwrap(), b.as_f32().unwrap());
    }
    std::fs::remove_file(&tmp).ok();
}
