//! Differential harness for the fast DES engine: every simulation the
//! repo can build — the full golden corpus, the fleet-scale sweep, and
//! hundreds of seeded random DAGs — runs through both the per-resource
//! ready-queue engine (`Sim::run_traced`) and the retained reference
//! implementation (`Sim::run_traced_reference`), asserting span-for-span
//! and blocker-for-blocker bit-equality. The reference engine is the
//! pre-optimization global-heap implementation kept verbatim precisely
//! so this suite can lock the rework down; if the two ever disagree the
//! fast engine is wrong by definition.

#[path = "common/generators.rs"]
mod generators;

use generators::{fleet_sweep_sims, golden_sims, random_dag_sims};
use scmoe::simtime::{EngineScratch, Resource, Sim, TracedRun};

/// Bitwise span equality: id, label, resource, and the exact f64 bits of
/// start and end. No tolerances anywhere in this file.
fn assert_spans_eq(name: &str, fast: &[scmoe::simtime::Span],
                   reference: &[scmoe::simtime::Span]) {
    assert_eq!(fast.len(), reference.len(), "{name}: span count");
    for (f, r) in fast.iter().zip(reference) {
        assert_eq!(f.id, r.id, "{name}: task id order");
        assert_eq!(f.label, r.label, "{name}: label of task {}", f.id);
        assert_eq!(f.resource, r.resource, "{name}: resource of {}", f.label);
        assert_eq!(f.start.to_bits(), r.start.to_bits(),
                   "{name}: start of {} ({} vs {})", f.label, f.start, r.start);
        assert_eq!(f.end.to_bits(), r.end.to_bits(),
                   "{name}: end of {} ({} vs {})", f.label, f.end, r.end);
    }
}

/// Run `sim` through every fast-engine entry point and the reference
/// engine; assert all of them agree bit-exactly.
fn assert_equivalent(name: &str, sim: &Sim) {
    let reference: TracedRun = sim.run_traced_reference();
    let fast: TracedRun = sim.run_traced();
    assert_spans_eq(name, &fast.spans, &reference.spans);

    assert_eq!(fast.blockers.len(), reference.blockers.len(),
               "{name}: blocker count");
    for (id, (f, r)) in
        fast.blockers.iter().zip(&reference.blockers).enumerate()
    {
        match (f, r) {
            (None, None) => {}
            (Some(fb), Some(rb)) => {
                assert_eq!(fb.pred, rb.pred, "{name}: blocker pred of {id}");
                assert_eq!(fb.kind, rb.kind, "{name}: blocker kind of {id}");
            }
            _ => panic!("{name}: blocker presence of {id}: {f:?} vs {r:?}"),
        }
    }

    // the untraced paths agree with the traced ones
    let spans = sim.run();
    assert_spans_eq(name, &spans, &reference.spans);
    let ref_makespan = scmoe::simtime::makespan(&reference.spans);
    assert_eq!(sim.makespan().to_bits(), ref_makespan.to_bits(),
               "{name}: makespan");
}

#[test]
fn golden_corpus_is_engine_equivalent() {
    let sims = golden_sims();
    // the corpus the golden snapshot + mirror pin: keep in lockstep
    assert_eq!(sims.len(), 69, "golden corpus size drifted");
    for (name, sim) in &sims {
        assert_equivalent(name, sim);
    }
}

#[test]
fn random_dags_are_engine_equivalent() {
    for (name, sim) in &random_dag_sims(200, 0xD0E5) {
        assert_equivalent(name, sim);
    }
}

#[test]
fn fleet_sweep_is_engine_equivalent() {
    for (name, sim) in &fleet_sweep_sims(32, 4) {
        assert_equivalent(name, sim);
    }
}

/// One shared [`EngineScratch`] across wildly different graphs must be
/// bit-identical to fresh runs — the nonce/version revalidation at work.
#[test]
fn scratch_reuse_across_corpus_is_deterministic() {
    let mut scratch = EngineScratch::default();
    for (name, sim) in golden_sims().iter().chain(&random_dag_sims(25, 7)) {
        let shared = sim.run_traced_with(&mut scratch);
        let fresh = sim.run_traced();
        assert_spans_eq(name, &shared.spans, &fresh.spans);
        assert_eq!(sim.makespan_with(&mut scratch).to_bits(),
                   sim.makespan().to_bits(), "{name}: scratch makespan");
    }
}

/// Repeated runs of the same sim are bit-identical (no hidden state).
#[test]
fn repeated_runs_are_deterministic() {
    for (name, sim) in &random_dag_sims(25, 0xBEEF) {
        let a = sim.run_traced();
        let b = sim.run_traced();
        assert_spans_eq(name, &a.spans, &b.spans);
    }
}

/// The Graham scheduling anomaly the analysis layer documents must
/// reproduce identically on both engines: shortening task P *increases*
/// the makespan (31.0 at p=0, 21.5 at p=2) because list scheduling is
/// not monotone on arbitrary DAGs. Pinned here so the fast engine can
/// never "fix" it.
#[test]
fn graham_anomaly_pins_on_both_engines() {
    let build = |p: f64| {
        let mut sim = Sim::new();
        let pp = sim.add("P", Resource::Compute(1), p, &[]);
        let q = sim.add("Q", Resource::Free, 0.5, &[]);
        let _a = sim.add("A", Resource::Compute(0), 10.0, &[pp]);
        let b = sim.add("B", Resource::Compute(0), 1.0, &[q]);
        let _c = sim.add("C", Resource::Comm(0), 20.0, &[b]);
        sim
    };
    for (p, expect) in [(0.0, 31.0), (2.0, 21.5)] {
        let sim = build(p);
        assert_eq!(sim.makespan(), expect, "fast engine, p={p}");
        let reference = sim.run_traced_reference();
        assert_eq!(scmoe::simtime::makespan(&reference.spans), expect,
                   "reference engine, p={p}");
        assert_equivalent(&format!("graham-p{p}"), &sim);
    }
}
