//! Integration: the full python-AOT -> rust-PJRT bridge on real artifacts.
//! Requires `make artifacts` (skips cleanly if artifacts/ is absent).

use std::path::Path;
use std::sync::Arc;

use scmoe::runtime::{Engine, HostTensor};

fn artifacts_root() -> Option<&'static Path> {
    let p = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    if p.join("quality_scmoe_micro/manifest.json").exists() {
        Some(Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")))
    } else {
        eprintln!("skipping: artifacts not built");
        None
    }
}

#[test]
fn init_then_train_step_runs_and_improves() {
    let Some(root) = artifacts_root() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(&root.join("quality_scmoe_micro")).unwrap();
    let cfg = set.manifest.config.clone();
    assert_eq!(cfg.arch, "scmoe");

    let init = set.get("init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(0)]).unwrap();
    assert_eq!(params.len(), set.manifest.param_specs.len());
    for (p, (name, shape)) in params.iter().zip(&set.manifest.param_specs) {
        assert_eq!(&p.shape, shape, "param {name}");
    }

    let train = set.get("train_step").unwrap();
    let zeros: Vec<HostTensor> = params.iter()
        .map(|p| HostTensor::zeros(&p.shape))
        .collect();
    // tokens/targets: fixed tiny batch
    let b = cfg.batch_size;
    let s = cfg.seq_len;
    let tokens = HostTensor::i32(vec![b, s], (0..b * s).map(|i| (i % 250) as i32).collect());
    let targets = HostTensor::i32(vec![b, s], (0..b * s).map(|i| ((i + 1) % 250) as i32).collect());

    let mut state: Vec<HostTensor> = params.clone();
    state.extend(zeros.iter().cloned());
    state.extend(zeros.iter().cloned());

    let mut losses = Vec::new();
    for step in 0..4 {
        let mut inputs = state.clone();
        inputs.push(HostTensor::scalar_i32(step));
        inputs.push(tokens.clone());
        inputs.push(targets.clone());
        inputs.push(HostTensor::scalar_i32(step + 100));
        let out = train.run(&inputs).unwrap();
        let n = set.manifest.param_specs.len();
        let loss = out[3 * n].as_f32().unwrap()[0];
        assert!(loss.is_finite(), "loss must be finite");
        losses.push(loss);
        state = out[..3 * n].to_vec();
    }
    // same batch repeated: loss must drop
    assert!(losses[3] < losses[0],
            "loss should decrease on repeated batch: {losses:?}");
}

#[test]
fn ops_artifacts_compose_to_fused_moe() {
    let Some(root) = artifacts_root() else { return };
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(&root.join("ops_tiny")).unwrap();
    let m = &set.manifest;
    let d = m.config.d_model;
    let e = m.config.n_experts;
    let t = m.tokens;
    let k = 1usize;
    let cap = m.capacities[&k];

    // weights from ops_init
    let weights = set.get("ops_init").unwrap().run(&[HostTensor::scalar_i32(7)]).unwrap();
    // indices into ops_init outputs (see aot.py build_ops out names)
    let (ln_g, ln_b) = (&weights[0], &weights[1]);
    let wg = &weights[10];
    let (w1, b1, w2, b2) = (&weights[11], &weights[12], &weights[13], &weights[14]);

    // random-ish input
    let x: Vec<f32> = (0..t * d).map(|i| ((i * 37 % 101) as f32 / 101.0) - 0.5).collect();
    let xt = HostTensor::f32(vec![t, d], x.clone());

    // (1) rust-orchestrated path: gate -> encode -> experts -> decode
    let gate = set.get("gate_op_k1").unwrap();
    let gout = gate.run(&[xt.clone(), ln_g.clone(), ln_b.clone(), wg.clone()]).unwrap();
    let h = gout[0].as_f32().unwrap();
    let idx = gout[1].as_i32().unwrap();
    let w = gout[2].as_f32().unwrap();

    let table = scmoe::moe::RoutingTable::build(idx, w, t, k, e, cap);
    let enc = scmoe::moe::encode(&table, h, d);
    let experts = set.get(&format!("experts_op_c{cap}")).unwrap();
    let ye = experts.run(&[
        HostTensor::f32(vec![e, cap, d], enc),
        w1.clone(), b1.clone(), w2.clone(), b2.clone(),
    ]).unwrap();
    let y_rust = scmoe::moe::decode(&table, ye[0].as_f32().unwrap(), d);

    // (2) fused oracle
    let fused = set.get("moe_fused_op_k1").unwrap();
    let y_fused = fused.run(&[
        xt, ln_g.clone(), ln_b.clone(), wg.clone(),
        w1.clone(), b1.clone(), w2.clone(), b2.clone(),
    ]).unwrap();
    let yf = y_fused[0].as_f32().unwrap();

    let mut max_err = 0f32;
    for (a, b) in y_rust.iter().zip(yf) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-4, "rust-orchestrated MoE != fused oracle (max err {max_err})");
}
