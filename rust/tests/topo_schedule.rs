//! Acceptance tests for the topology-aware multi-device DES:
//!
//! - with one modeled device, the topo stack reproduces the legacy
//!   single-device makespans bit-exactly on every calibrated preset;
//! - with the full fleets (8–32 devices, 1–4 nodes), ScMoE overlap
//!   strategies reduce the makespan vs. Sequential on every preset;
//! - the adaptive expert-slot choice genuinely differs across topology
//!   presets under the comm-heavy GPT3-XL workload — the scenario
//!   diversity this layer exists to expose.

use scmoe::cluster::Scenario;
use scmoe::coordinator::adaptive::choose_expert_slot_topo;
use scmoe::coordinator::costs::{MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::schedule::{build_pair_schedule, ChunkPipelining};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::report::efficiency::{proxy_costs, topo_proxy_costs, xl_topo_proxy_costs};

#[test]
fn one_modeled_device_reproduces_legacy_makespans_on_every_preset() {
    for sc in Scenario::extended() {
        let c = proxy_costs(sc);
        let tc = TopoCosts::from_block(&c);
        for (kind, strategy, slot) in [
            (MoEKind::Standard { k: 2 }, Strategy::Sequential, 0),
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 2 }, 0),
            (MoEKind::ScMoE { k: 1 }, Strategy::Overlap, 2),
        ] {
            let legacy = build_pair_schedule(&c, kind, strategy, slot).makespan();
            let topo = ScheduleSpec::new(kind, strategy)
                .with_slot(slot)
                .build(&tc)
                .makespan();
            // bit-exact, not a tolerance: identical graphs, identical math
            assert_eq!(legacy, topo, "{}: {kind:?}/{strategy:?}", sc.label());
        }
    }
}

#[test]
fn fleet_presets_have_expected_shapes() {
    let shapes: Vec<(usize, usize)> = Scenario::extended()
        .iter()
        .map(|sc| {
            let tc = topo_proxy_costs(*sc);
            (tc.n_devices(), tc.n_nodes())
        })
        .collect();
    assert_eq!(shapes, vec![(8, 1), (8, 1), (16, 2), (32, 4), (8, 2)]);
}

#[test]
fn scmoe_overlap_reduces_fleet_makespan_on_every_preset() {
    // Both workloads, all five presets: the ScMoE overlap (with its
    // adaptive slot) must strictly beat the sequential top-2 baseline.
    // Mirrored margins range from ~190us (NVLink/Swin) to ~9.9ms
    // (PCIe/XL), so the strict comparison is robust.
    for sc in Scenario::extended() {
        for tc in [topo_proxy_costs(sc), xl_topo_proxy_costs(sc)] {
            assert!(tc.n_devices() >= 2, "fleet presets model the whole fleet");
            let seq = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                        Strategy::Sequential)
                .build(&tc)
                .makespan();
            let ovl = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                        Strategy::Overlap)
                .adaptive()
                .build(&tc)
                .makespan();
            assert!(
                ovl < seq,
                "{}: overlap {ovl} should beat sequential {seq}",
                sc.label()
            );
        }
    }
}

#[test]
fn overlap_pipelined_also_beats_sequential_on_fleets() {
    for sc in [Scenario::TwoNodeA800x16, Scenario::FourNodeA800IBx32] {
        let tc = xl_topo_proxy_costs(sc);
        let seq = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                    Strategy::Sequential)
            .build(&tc)
            .makespan();
        let ovl = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                    Strategy::OverlapPipelined { chunks: 2 })
            .adaptive()
            .build(&tc)
            .makespan();
        assert!(ovl < seq, "{}: {ovl} vs {seq}", sc.label());
    }
}

#[test]
fn staged_pipelining_strictly_beats_phase_chained_on_4node_ib() {
    // Acceptance criterion: on the 32xA800-4node-IB preset the MoNTA-style
    // staged pipeline (chunk i's uplink overlapping chunk i+1's intra
    // phase) strictly beats the phase-chained schedule at the same chunk
    // count — for both the plain pipeline and the ScMoE overlap+pipeline.
    // Mirrored margins: pipe 120us/74us/50us and ovl 43us/110us/96us at
    // chunks 2/4/8 — far beyond f64 noise.
    let tc = xl_topo_proxy_costs(Scenario::FourNodeA800IBx32);
    for chunks in [2usize, 4, 8] {
        let pipe = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                     Strategy::Pipelined { chunks });
        let staged = pipe.build(&tc).makespan();
        let chained = pipe
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .makespan();
        assert!(staged < chained,
                "pipe{chunks}: staged {staged} vs chained {chained}");

        let ospec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                      Strategy::OverlapPipelined { chunks });
        let (slot, ovl_staged) = ospec.choose_slot(&tc);
        let ovl_chained = ospec
            .with_slot(slot)
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .makespan();
        assert!(ovl_staged < ovl_chained,
                "ovl+pipe{chunks} slot {slot}: staged {ovl_staged} \
                 vs chained {ovl_chained}");
    }
}

#[test]
fn adaptive_slot_choice_differs_across_topology_presets() {
    // GPT3-XL payload (8 KB tokens): the All-to-All phases rival the
    // backbone window, so the optimal expert slot depends on the
    // topology. PCIe and the Ethernet-bridged 2-node fleet pull the
    // experts to the earliest slot (dispatch is the bottleneck); the
    // NVLink-class, IB, and heterogeneous fleets keep the post-attention
    // slot. Margins between best and runner-up slots are 60us-1.2ms
    // (the hetero margin grew when its A30 node moved to per-node PCIe
    // intra links) — far beyond f64 noise.
    let kind = MoEKind::ScMoE { k: 1 };
    let slots: Vec<usize> = Scenario::extended()
        .iter()
        .map(|sc| {
            choose_expert_slot_topo(&xl_topo_proxy_costs(*sc), kind,
                                    Strategy::Overlap).0
        })
        .collect();
    assert_eq!(slots, vec![0, 2, 0, 2, 2],
               "adaptive slots per preset {:?}",
               Scenario::extended().map(|s| s.label()));
    let distinct: std::collections::BTreeSet<usize> = slots.iter().copied().collect();
    assert!(distinct.len() >= 2, "slot choice must vary across topologies");

    // and under the lighter Swin workload every preset agrees on the
    // post-attention slot — the divergence above is workload-dependent,
    // exactly as Eq. 11 predicts.
    for sc in Scenario::extended() {
        let (slot, _) = choose_expert_slot_topo(&topo_proxy_costs(sc), kind,
                                                Strategy::Overlap);
        assert_eq!(slot, 2, "{}", sc.label());
    }
}

#[test]
fn hetero_fleet_is_gated_by_its_slow_node() {
    // The mixed A800+A30 preset's makespan must exceed the homogeneous
    // NVLink preset's (same device count, same workload): stragglers set
    // the barrier — on both compute (A30 op scale) and communication
    // (the A30 node's intra link is PCIe, not NVLink).
    let spec = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Sequential);
    let nv = spec.build(&topo_proxy_costs(Scenario::NvlinkA800x8)).makespan();
    let hetero = spec
        .build(&topo_proxy_costs(Scenario::HeteroA800A30x8))
        .makespan();
    assert!(hetero > nv, "hetero {hetero} should exceed nvlink {nv}");
}

#[test]
fn hetero_a30_node_pays_pcie_intra_phases() {
    // ROADMAP item: the mixed fleet's A30 node runs PCIe while the A800
    // node keeps NVLink — its intra-node A2A phases must be an order of
    // magnitude slower for the same uniform traffic.
    let tc = topo_proxy_costs(Scenario::HeteroA800A30x8);
    let a800 = tc.a2a_intra_k1[0];
    let a30 = tc.a2a_intra_k1[7];
    assert!(a30 > a800 * 10.0, "A30 intra {a30} vs A800 intra {a800}");
}
