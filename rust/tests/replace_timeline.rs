//! Acceptance tests for the dynamic re-placement subsystem: estimator
//! convergence under drift, migration byte-accounting exactness, H2D
//! non-overlap on the migration step, the bit-exact static reduction,
//! and the two pinned multi-step studies on 32xA800-4node-IB (break-even
//! vs static-uniform; regime shift where the break-even threshold beats
//! eager every-step re-placement). Every pinned value was minted through
//! the validated DES mirror (`tools/des_mirror/mirror2.py --study`).

use scmoe::cluster::{LinkModel, Scenario, Topology};
use scmoe::coordinator::costs::{ComputeCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::replace::{
    run_replace_timeline, MigrationPlan, ReplaceConfig, ReplacePolicy,
};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{AffinityEstimator, Placement, RoutingTable};
use scmoe::report::efficiency::drifting_node_affine_routing;
use scmoe::report::replace::{
    break_even_step, migration_marks, run_study, study_h2d_link,
    study_tables, STUDY_BYTES_PER_EXPERT, STUDY_DRIFT_NOISE,
    STUDY_DRIFT_SEED, STUDY_SHIFT_DECAY, STUDY_SHIFT_NOISE, STUDY_SHIFT_SEED,
    STUDY_SHIFT_STEP, STUDY_TOKEN_BYTES,
};
use scmoe::simtime::Resource;

/// First-maximum argmax over an expert's per-node affinities (strict
/// `>`, matching the mirror's tie semantics).
fn argmax_node(est: &AffinityEstimator, expert: usize, n_nodes: usize) -> usize {
    let mut best = 0usize;
    for node in 1..n_nodes {
        if est.affinity(expert, node) > est.affinity(expert, best) {
            best = node;
        }
    }
    best
}

#[test]
fn estimator_converges_to_planted_affinity_under_drift() {
    // planted structure: expert e is affine to node e % 4; 20% of
    // tokens route uniformly at random instead. After 4 noisy steps the
    // counting estimator must recover the planted structure exactly —
    // argmax per expert AND the packed placement's node assignment.
    let mut est = AffinityEstimator::counting(32, 4);
    for s in 0..4u64 {
        let rt = drifting_node_affine_routing(32, 8, 32, 64, 0, 0.2, 5000 + s);
        est.observe(&rt, 32, 8);
    }
    assert_eq!(est.steps, 4);
    for e in 0..32 {
        assert_eq!(argmax_node(&est, e, 4), e % 4, "expert {e} argmax");
    }
    let p = est.packed(32, 8);
    for e in 0..32 {
        assert_eq!(p.device_of(e) / 8, e % 4, "expert {e} packed node");
    }
}

fn dyadic_topo() -> Topology {
    Topology {
        n_devices: 4,
        devices_per_node: 2,
        intra: LinkModel::new(0.0625, 1024.0),
        inter: Some(LinkModel::new(0.125, 512.0)),
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    }
}

fn dyadic_base() -> ComputeCosts {
    ComputeCosts {
        attn: 1.0,
        mlp: 0.75,
        se: 0.75,
        gate: 0.0625,
        encode: 0.0625,
        decode: 0.0625,
        expert_k1: 0.5,
    }
}

fn corpus_table() -> RoutingTable {
    let indices: Vec<i32> = vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
    let weights = vec![1.0f32; 16];
    RoutingTable::build(&indices, &weights, 16, 1, 4, 16)
}

#[test]
fn static_stream_reduces_to_single_step_schedules() {
    // a Never-policy timeline over N identical tables is N independent
    // single-step schedules, bit-exactly — the multi-step composition
    // adds nothing when nothing migrates
    let rt = corpus_table();
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential);
    let single = spec
        .build(&TopoCosts::from_routing(&dyadic_base(), &dyadic_topo(), &rt,
                                        &Placement::new(4, 4), 64))
        .makespan();
    let cfg = ReplaceConfig {
        spec,
        policy: ReplacePolicy::Never,
        bytes_per_expert: 4096,
        h2d: LinkModel::new(0.125, 1024.0),
        d2h_link: None,
        decay: 1.0,
    };
    let tables = vec![rt; 4];
    let out = run_replace_timeline(&dyadic_base(), &dyadic_topo(), 64,
                                   &tables, &Placement::new(4, 4), &cfg);
    assert_eq!(out.migrations, 0);
    for step in &out.steps {
        assert_eq!(step.makespan, single); // bit-exact, not a tolerance
        assert_eq!(step.base_makespan, single);
        assert!(!step.migrated);
        assert_eq!(step.migration_bytes, 0);
    }
    let sum: f64 = out.steps.iter().map(|s| s.makespan).sum();
    assert_eq!(out.total, sum);
}

#[test]
fn migration_step_h2d_tasks_are_exact_and_never_overlap() {
    // reconstruct the drift study's migration step: one observation,
    // measured packing, plan overlapped into the block-layout schedule
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = scmoe::report::efficiency::xl_compute_costs();
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let mut est = AffinityEstimator::counting(32, 4);
    est.observe(&tables[0], 32, 8);
    let measured = est.packed(32, 8);
    let plan = MigrationPlan::between(&block, &measured, STUDY_BYTES_PER_EXPERT);
    // byte accounting is exact: 30 experts move (pinned via the mirror),
    // each carrying its full parameter footprint
    assert_eq!(plan.moves.len(), 30);
    assert_eq!(plan.total_bytes(), 30 * STUDY_BYTES_PER_EXPERT);
    assert_eq!((0..32).map(|d| plan.bytes_into(d)).sum::<usize>(),
               plan.total_bytes());
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential);
    let tc = TopoCosts::from_routing(&base, &topo, &tables[0], &block,
                                     STUDY_TOKEN_BYTES);
    let mut sched = spec.build(&tc);
    let base_makespan = sched.makespan();
    plan.add_h2d_tasks(&mut sched.sim, &study_h2d_link());
    let spans = sched.run();
    // per-engine H2D spans serialize (exclusive resource) and the step
    // stretches to the slowest engine: makespan = max(base, plan time)
    let mut h2d_spans: Vec<_> = spans
        .iter()
        .filter(|s| matches!(s.resource, Resource::H2D(_)))
        .collect();
    assert_eq!(h2d_spans.len(), 30);
    h2d_spans.sort_by(|a, b| {
        a.resource.cmp(&b.resource).then(a.start.total_cmp(&b.start))
    });
    for w in h2d_spans.windows(2) {
        if w[0].resource == w[1].resource {
            assert!(w[1].start >= w[0].end - 1e-12,
                    "H2D overlap on {:?}", w[0].resource);
        }
    }
    let end = spans.iter().fold(0.0f64, |m, s| m.max(s.end));
    let expect = base_makespan.max(plan.time(&study_h2d_link()));
    assert!((end - expect).abs() < 1e-12,
            "migration step makespan {end} vs {expect}");
    assert!(end > base_makespan, "128 MiB/expert must stretch the step");
}

#[test]
fn break_even_study_beats_static_beyond_pinned_step_count() {
    // scenario A (stable drift): the break-even policy migrates exactly
    // once, at step 0, and the cumulative makespan crosses below the
    // static-uniform baseline at step 6 (pinned via the mirror); from
    // step 1 on, every migrated-run step is strictly faster
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let static_run = run_study(&tables, ReplacePolicy::Never, 1.0);
    let replace_run = run_study(&tables, ReplacePolicy::BreakEven, 1.0);
    assert_eq!(replace_run.migrations, 1);
    assert!(replace_run.steps[0].migrated, "migration fires at step 0");
    assert_eq!(replace_run.steps[0].migration_bytes,
               30 * STUDY_BYTES_PER_EXPERT);
    assert!(replace_run.steps[0].makespan > static_run.steps[0].makespan,
            "the migration step itself costs extra");
    for (a, b) in static_run.steps.iter().zip(&replace_run.steps).skip(1) {
        assert!(b.makespan < a.makespan,
                "step {}: replaced {} vs static {}", a.step, b.makespan,
                a.makespan);
    }
    assert_eq!(break_even_step(&static_run, &replace_run), Some(6));
    assert!(replace_run.total < static_run.total,
            "16-step totals: replace {} vs static {}", replace_run.total,
            static_run.total);
    // the measured placement recovered the planted node structure
    for e in 0..32 {
        assert_eq!(replace_run.final_placement.device_of(e) / 8, e % 4);
    }
}

#[test]
fn regime_shift_threshold_beats_eager_replacement() {
    // scenario B (regime shift at step 8): eager every-step replacement
    // churns — 15 migrations, each repaying little — while the
    // break-even threshold migrates exactly twice (warmup + one step
    // after the shift) and strictly beats both eager and never
    let tables = study_tables(STUDY_SHIFT_NOISE, STUDY_SHIFT_SEED,
                              Some(STUDY_SHIFT_STEP));
    let never = run_study(&tables, ReplacePolicy::Never, STUDY_SHIFT_DECAY);
    let eager = run_study(&tables, ReplacePolicy::EveryK { k: 1 },
                          STUDY_SHIFT_DECAY);
    let threshold = run_study(&tables, ReplacePolicy::BreakEven,
                              STUDY_SHIFT_DECAY);
    assert_eq!(never.migrations, 0);
    assert_eq!(eager.migrations, 15);
    assert_eq!(threshold.migrations, 2);
    assert_eq!(migration_marks(&threshold), "M........M......");
    assert!(threshold.total < never.total,
            "replacing must beat the static layout across the shift: {} vs {}",
            threshold.total, never.total);
    assert!(threshold.total < eager.total,
            "threshold {} must strictly beat eager {}", threshold.total,
            eager.total);
    // eager's churn is the mechanism: every migration step pays for its
    // H2D transfers with makespan
    for step in eager.steps.iter().filter(|s| s.migrated) {
        assert!(step.makespan >= step.base_makespan);
        assert!(step.migration_time > step.base_makespan,
                "churn migrations outlast the step they overlap");
    }
}
