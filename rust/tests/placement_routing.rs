//! Placement-sensitivity acceptance tests for the routed A2A cost path:
//!
//! - an affinity-packed (fully node-local) placement yields *exactly zero*
//!   inter-node phase time in both A2A directions, and strictly lower
//!   sequential and overlap makespans than a maximally-remote placement,
//!   across seeded random node-affine routings on the 4-node IB preset;
//! - on the same preset the affinity-packed overlap makespan strictly
//!   beats the uniform-routing overlap makespan (the ExFlow effect);
//! - the block layout run through the routed path agrees with the legacy
//!   block byte matrix, and a symmetric matrix yields combine phases that
//!   equal the dispatch phases bit-exactly.

use scmoe::cluster::{a2a_transpose, Scenario};
use scmoe::coordinator::adaptive::choose_expert_slot_topo;
use scmoe::coordinator::costs::{ComputeCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{Placement, RoutingTable};
use scmoe::report::efficiency::{node_affine_routing, xl_compute_costs};
use scmoe::util::propcheck::{check, gen};

/// GPT3-XL-class operator costs — the comm-heavy workload where placement
/// matters most (shared with the report tables and the placement example).
fn xl_costs() -> ComputeCosts {
    xl_compute_costs()
}

/// Maximally-remote counterpart of an affinity-packed placement: every
/// expert shifted one node over, so all of its traffic crosses the fabric.
fn anti_affinity(p: &Placement, devices_per_node: usize) -> Placement {
    let n_nodes = p.n_devices / devices_per_node;
    let map = (0..p.n_experts)
        .map(|e| {
            let d = p.device_of(e);
            (d / devices_per_node + 1) % n_nodes * devices_per_node
                + d % devices_per_node
        })
        .collect();
    Placement::custom(p.n_experts, p.n_devices, map)
}

#[test]
fn prop_affinity_packing_zeroes_inter_phases_and_beats_remote() {
    // Heavy 32 KiB tokens so the remote placement's fabric traffic cannot
    // hide inside the overlap window (strict comparisons verified for all
    // seeds below).
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_costs();
    check("affinity-placement", 12, |rng| {
        let tokens_per_device = gen::usize_in(rng, 256, 640);
        let k = gen::usize_in(rng, 1, 2);
        let seed = rng.next_u64();
        (tokens_per_device, k, seed)
    }, |&(tokens_per_device, k, seed)| {
        let rt = node_affine_routing(32, 8, 32, tokens_per_device, k, seed);
        let affinity = Placement::affinity_packed(&rt, 32, 8);
        let remote = anti_affinity(&affinity, 8);
        let tc_a = TopoCosts::from_routing(&base, &topo, &rt, &affinity, 32768);
        let tc_r = TopoCosts::from_routing(&base, &topo, &rt, &remote, 32768);
        tc_a.assert_valid();
        tc_r.assert_valid();
        // fully node-local traffic: the uplink phases are exactly zero in
        // both directions — not merely small
        if !tc_a.a2a_inter_k1.iter().all(|&t| t == 0.0)
            || !tc_a.a2a_inter_combine_k1.iter().all(|&t| t == 0.0)
        {
            return Err(format!("nonzero inter phase: {:?} / {:?}",
                               tc_a.a2a_inter_k1, tc_a.a2a_inter_combine_k1));
        }
        let kind = MoEKind::ScMoE { k };
        let seq = ScheduleSpec::new(kind, Strategy::Sequential);
        let seq_a = seq.build(&tc_a).makespan();
        let seq_r = seq.build(&tc_r).makespan();
        if seq_a >= seq_r {
            return Err(format!("sequential: local {seq_a} !< remote {seq_r}"));
        }
        let ovl = ScheduleSpec::new(kind, Strategy::Overlap).with_slot(2);
        let ovl_a = ovl.build(&tc_a).makespan();
        let ovl_r = ovl.build(&tc_r).makespan();
        if ovl_a >= ovl_r {
            return Err(format!("overlap: local {ovl_a} !< remote {ovl_r}"));
        }
        Ok(())
    });
}

#[test]
fn affinity_packed_overlap_beats_uniform_routing_on_4node_ib() {
    // The headline acceptance scenario: GPT3-XL payload on the 4-node IB
    // fleet. Affinity packing the node-affine routing drives the uplink
    // phases to exactly zero and strictly beats the uniform model's
    // overlap and sequential makespans.
    //
    // Attribution caveat (pinned by the block-placement assertions below):
    // vs *uniform*, part of the win is volume normalization — the uniform
    // model carries capacity_factor = 2.0 headroom that routed bytes
    // don't. The placement-only effect at this 8 KiB payload shows up on
    // the sequential makespan and the uplink phases (affinity strictly
    // beats *routed + block* there), while the overlap window hides both
    // routed variants' comm entirely; the heavier-payload property test
    // above pins the placement-only overlap win.
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_costs();
    let kind = MoEKind::ScMoE { k: 1 };

    let uniform = TopoCosts::from_topology(&base, &topo, 640, 8192, 2.0);
    let rt = node_affine_routing(32, 8, 32, 640, 1, 7);
    assert_eq!(rt.dropped, 0, "demo routing must not drop routes");
    let affinity = Placement::affinity_packed(&rt, 32, 8);
    let routed = TopoCosts::from_routing(&base, &topo, &rt, &affinity, 8192);

    assert!(routed.a2a_inter_k1.iter().all(|&t| t == 0.0),
            "affinity packing must zero the dispatch uplink phases");
    assert!(routed.a2a_inter_combine_k1.iter().all(|&t| t == 0.0),
            "affinity packing must zero the combine uplink phases");

    let (_, ovl_uniform) = choose_expert_slot_topo(&uniform, kind, Strategy::Overlap);
    let (_, ovl_routed) = choose_expert_slot_topo(&routed, kind, Strategy::Overlap);
    assert!(ovl_routed < ovl_uniform,
            "affinity overlap {ovl_routed} must beat uniform {ovl_uniform}");

    let seq = ScheduleSpec::new(kind, Strategy::Sequential);
    let seq_uniform = seq.build(&uniform).makespan();
    let seq_routed = seq.build(&routed).makespan();
    assert!(seq_routed < seq_uniform,
            "affinity sequential {seq_routed} must beat uniform {seq_uniform}");

    // placement-only comparison: same routing, same bytes, block layout —
    // block keeps uplink traffic and pays for it on the sequential path
    let block = TopoCosts::from_routing(&base, &topo, &rt,
                                        &Placement::new(32, 32), 8192);
    assert!(block.a2a_inter_k1.iter().any(|&t| t > 0.0),
            "block layout must keep some uplink traffic");
    let seq_block = seq.build(&block).makespan();
    assert!(seq_routed < seq_block,
            "placement-only: affinity sequential {seq_routed} must beat \
             routed-block {seq_block}");
}

#[test]
fn symmetric_routed_matrix_gives_bitexact_combine_phases() {
    // a symmetric byte matrix transposes to itself, so the combine phase
    // vectors must equal the dispatch vectors exactly
    let topo = Scenario::TwoNodeA800x16.topology();
    let base = xl_costs();
    // every device's tokens route to its own expert id mirrored pairwise:
    // token block d routes to expert d (pure self-traffic => symmetric)
    let tokens_per_device = 4;
    let n_tokens = 16 * tokens_per_device;
    let indices: Vec<i32> = (0..n_tokens)
        .map(|t| (t / tokens_per_device) as i32)
        .collect();
    let weights = vec![1.0f32; n_tokens];
    let rt = RoutingTable::build(&indices, &weights, n_tokens, 1, 16, n_tokens);
    let p = Placement::new(16, 16);
    let disp = rt.a2a_bytes_placed(&p, 4096);
    assert_eq!(a2a_transpose(&disp, 16), disp, "matrix must be symmetric");
    let tc = TopoCosts::from_routing(&base, &topo, &rt, &p, 4096);
    assert_eq!(tc.a2a_intra_k1, tc.a2a_intra_combine_k1);
    assert_eq!(tc.a2a_inter_k1, tc.a2a_inter_combine_k1);
}

#[test]
fn routed_block_placement_matches_legacy_byte_matrix() {
    let rt = node_affine_routing(8, 4, 8, 16, 2, 3);
    let legacy = rt.a2a_bytes(8, 512);
    let placed = rt.a2a_bytes_placed(&Placement::new(8, 8), 512);
    assert_eq!(legacy, placed);
}

#[test]
fn skewed_placement_concentrates_and_slows_the_fleet() {
    // packing all experts onto half the devices cannot make the simulated
    // fleet faster than the balanced block layout, and it concentrates
    // every dispatch byte on the loaded device columns
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_costs();
    let rt = node_affine_routing(32, 8, 32, 256, 1, 11);
    let block = TopoCosts::from_routing(&base, &topo, &rt,
                                        &Placement::new(32, 32), 8192);
    let skew_p = Placement::imbalance_skewed(32, 32, 2);
    let skew = TopoCosts::from_routing(&base, &topo, &rt, &skew_p, 8192);
    let m = rt.a2a_bytes_placed(&skew_p, 8192);
    for dst in 16..32 {
        for src in 0..32 {
            assert_eq!(m[src * 32 + dst], 0,
                       "unloaded device {dst} must receive nothing");
        }
    }
    let kind = MoEKind::ScMoE { k: 1 };
    let seq = ScheduleSpec::new(kind, Strategy::Sequential);
    let seq_block = seq.build(&block).makespan();
    let seq_skew = seq.build(&skew).makespan();
    assert!(seq_skew >= seq_block,
            "skewed {seq_skew} should not beat block {seq_block}");
}
