//! Acceptance tests for the whole-model pipeline simulator: the pinned
//! `scmoe report model` grid on 32xA800-4node-IB (cross-layer
//! co-placement strictly beats per-layer affinity packing; pipelined
//! schedules beat layer-sequential at M >= 2), the live break-even row
//! with D2H-priced migrations, the study-scale L=1 reduction to
//! `run_replace_timeline`, the infinite-bandwidth D2H bit-exactness,
//! and the zero-transition packer reduction. Every pinned number was
//! minted through the validated DES mirror
//! (`tools/des_mirror/mirror2.py --model-study`).

use scmoe::cluster::{LinkModel, Scenario};
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::model::{
    run_model_timeline, ModelConfig, ModelSpec, PipelineSchedule,
    PlacementMode,
};
use scmoe::coordinator::replace::{
    run_replace_timeline, ReplaceConfig, ReplacePolicy,
};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{
    co_placed, AffinityEstimator, Placement, TransitionEstimator,
};
use scmoe::report::efficiency::xl_compute_costs;
use scmoe::report::model_report::{
    model_config, model_grid_placements, model_tables, run_model_cell,
    study_d2h_link, MODEL_LAYERS, MODEL_MICROBATCHES,
};
use scmoe::report::replace::{
    study_h2d_link, STUDY_BYTES_PER_EXPERT, STUDY_TOKEN_BYTES,
};

fn block_placements() -> Vec<Placement> {
    (0..MODEL_LAYERS).map(|_| Placement::new(32, 32)).collect()
}

/// Total L-layer makespan of one static grid cell.
fn cell_total(m: usize, schedule: PipelineSchedule,
              initial: &[Placement]) -> f64 {
    let tables = model_tables();
    let cfg = model_config(m, schedule, ReplacePolicy::Never,
                           PlacementMode::PerLayer, None);
    run_model_cell(&tables, initial, &cfg).total
}

#[test]
fn pinned_grid_at_one_microbatch() {
    // m = 1: every schedule builds the same graph, so one schedule
    // suffices; cross-layer < per-layer < block, strictly
    let tables = model_tables();
    let (per, cross) = model_grid_placements(&tables[0]);
    let block = cell_total(1, PipelineSchedule::LayerSequential,
                           &block_placements());
    let p = cell_total(1, PipelineSchedule::LayerSequential, &per);
    let c = cell_total(1, PipelineSchedule::LayerSequential, &cross);
    assert!((block - 0.07365077901403508).abs() < 1e-12);
    assert!((p - 0.07079783696140349).abs() < 1e-12);
    assert!((c - 0.06517393062315788).abs() < 1e-12);
    assert!(c < p && p < block, "cross {c} / per {p} / block {block}");
    for schedule in [PipelineSchedule::GPipe, PipelineSchedule::OneFOneB] {
        assert_eq!(cell_total(1, schedule, &cross), c,
                   "m=1 schedules are graph-identical");
    }
}

#[test]
fn pinned_grid_at_four_microbatches() {
    let tables = model_tables();
    let (per, cross) = model_grid_placements(&tables[0]);
    let block = block_placements();
    let cell = |s, i: &[Placement]| cell_total(MODEL_MICROBATCHES, s, i);

    let seq_b = cell(PipelineSchedule::LayerSequential, &block);
    let seq_p = cell(PipelineSchedule::LayerSequential, &per);
    let seq_c = cell(PipelineSchedule::LayerSequential, &cross);
    assert!((seq_b - 0.2360365979929824).abs() < 1e-12);
    assert!((seq_p - 0.2359304947649121).abs() < 1e-12);
    // layer-sequential at m=4 hides the deeper layers' dispatch off the
    // critical path, so per == cross here; the pipelined rows differ
    assert_eq!(seq_c, seq_p);

    let gp_b = cell(PipelineSchedule::GPipe, &block);
    let gp_p = cell(PipelineSchedule::GPipe, &per);
    let gp_c = cell(PipelineSchedule::GPipe, &cross);
    assert!((gp_b - 0.2330006984701753).abs() < 1e-12);
    assert!((gp_p - 0.2341155310035086).abs() < 1e-12);
    assert!((gp_c - 0.23186532924912262).abs() < 1e-12);

    let fb_b = cell(PipelineSchedule::OneFOneB, &block);
    let fb_p = cell(PipelineSchedule::OneFOneB, &per);
    let fb_c = cell(PipelineSchedule::OneFOneB, &cross);
    assert!((fb_b - 0.23980425031578934).abs() < 1e-12);
    assert!((fb_p - 0.2393018295438595).abs() < 1e-12);
    assert!((fb_c - 0.23013790016280686).abs() < 1e-12);

    // the headline: only the transition-aware packer beats both block
    // and per-layer packing once the pipeline overlaps layers
    assert!(gp_c < gp_b && gp_c < gp_p, "gpipe cross must win its row");
    assert!(fb_c < fb_b && fb_c < fb_p, "1f1b cross must win its row");
    // and pipelining beats layer-sequential under the winning placement
    assert!(gp_c < seq_c && fb_c < seq_c,
            "pipelined cross {gp_c}/{fb_c} vs layer-sequential {seq_c}");
    assert!(gp_b < seq_b, "gpipe beats layer-sequential from block too");
}

#[test]
fn pinned_live_break_even_row() {
    // block start, break-even policy, cross-layer candidates, D2H-priced
    // migrations on the m=4 GPipe pipeline — the report's live row
    let tables = model_tables();
    let cfg = model_config(MODEL_MICROBATCHES, PipelineSchedule::GPipe,
                           ReplacePolicy::BreakEven,
                           PlacementMode::CrossLayer,
                           Some(study_d2h_link()));
    let out = run_model_cell(&tables, &block_placements(), &cfg);
    assert!((out.total - 0.2322055117754384).abs() < 1e-12);
    assert_eq!(out.migrations, 2);
    let expect = [0.05871754753684207, 0.057894432252631536,
                  0.05784752355087714, 0.05774600843508767];
    assert_eq!(out.steps.len(), expect.len());
    for (st, e) in out.steps.iter().zip(expect) {
        assert!((st.makespan - e).abs() < 1e-12,
                "step {}: {} vs {e}", st.step, st.makespan);
    }
    assert_eq!(out.final_placements.len(), MODEL_LAYERS);
}

#[test]
fn single_layer_model_reduces_to_replace_timeline_at_study_scale() {
    // L = 1 / S = 1 / M = 1 over the study's layer-0 streams: the model
    // timeline must equal run_replace_timeline field-for-field with ==
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let tables: Vec<_> = model_tables().iter()
        .map(|step| step[0].clone())
        .collect();
    let model_tables: Vec<_> = tables.iter()
        .map(|t| vec![t.clone()])
        .collect();
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                 Strategy::Sequential);
    let initial = Placement::new(32, 32);
    for policy in [ReplacePolicy::Never, ReplacePolicy::EveryK { k: 2 },
                   ReplacePolicy::BreakEven] {
        let rcfg = ReplaceConfig {
            spec: spec.clone(),
            policy,
            bytes_per_expert: STUDY_BYTES_PER_EXPERT,
            h2d: study_h2d_link(),
            d2h_link: None,
            decay: 1.0,
        };
        let mcfg = ModelConfig {
            spec: ModelSpec {
                layers: vec![spec.clone()],
                stages: 1,
                microbatches: 1,
                schedule: PipelineSchedule::LayerSequential,
            },
            policy,
            bytes_per_expert: STUDY_BYTES_PER_EXPERT,
            h2d: study_h2d_link(),
            d2h: None,
            decay: 1.0,
            mode: PlacementMode::CrossLayer,
        };
        let r = run_replace_timeline(&base, &topo, STUDY_TOKEN_BYTES,
                                     &tables, &initial, &rcfg);
        let m = run_model_timeline(&base, &topo, STUDY_TOKEN_BYTES,
                                   &model_tables, &[initial.clone()], &mcfg);
        assert_eq!(r.total, m.total, "{policy:?}");
        assert_eq!(r.migrations, m.migrations);
        assert_eq!(r.steps.len(), m.steps.len());
        for (a, b) in r.steps.iter().zip(&m.steps) {
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.base_makespan, b.base_makespan);
            assert_eq!(a.migrated, b.migrated);
            assert_eq!(a.migration_bytes, b.migration_bytes);
            assert_eq!(a.migration_time, b.migration_time);
        }
        for e in 0..32 {
            assert_eq!(r.final_placement.device_of(e),
                       m.final_placements[0].device_of(e));
        }
    }
}

#[test]
fn infinite_bandwidth_d2h_is_bit_exact() {
    // a zero-latency, infinite-bandwidth source read-out adds spans of
    // zero duration: every makespan and decision must match d2h = None
    let tables = model_tables();
    let free = Some(LinkModel::new(0.0, f64::INFINITY));
    for d2h in [None, free] {
        let cfg = model_config(MODEL_MICROBATCHES, PipelineSchedule::GPipe,
                               ReplacePolicy::BreakEven,
                               PlacementMode::CrossLayer, d2h);
        let out = run_model_cell(&tables, &block_placements(), &cfg);
        // both branches land on the same priced-migration totals
        let cfg0 = model_config(MODEL_MICROBATCHES, PipelineSchedule::GPipe,
                                ReplacePolicy::BreakEven,
                                PlacementMode::CrossLayer, None);
        let base = run_model_cell(&tables, &block_placements(), &cfg0);
        assert_eq!(out.total, base.total);
        assert_eq!(out.migrations, base.migrations);
        for (a, b) in out.steps.iter().zip(&base.steps) {
            assert_eq!(a.makespan, b.makespan);
            assert_eq!(a.migration_time, b.migration_time);
        }
    }
}

#[test]
fn zero_transition_packer_reduces_to_per_layer_at_study_scale() {
    // with no observed transitions the cross-layer packer has no chain
    // signal, so co_placed == affinity-packed for the measured counts
    let tables0 = &model_tables()[0];
    for rt in tables0 {
        let mut est = AffinityEstimator::counting(32, 4);
        est.observe(rt, 32, 8);
        let packed = est.packed(32, 8);
        let empty = TransitionEstimator::counting(32);
        let co = co_placed(est.matrix(), &empty, &Placement::new(32, 32),
                           32, 8);
        for e in 0..32 {
            assert_eq!(co.device_of(e), packed.device_of(e));
        }
    }
}
