//! Acceptance tests for token-true, α-true chunked All-to-All:
//!
//! - total chunked comm time exceeds the unchunked time by exactly
//!   `(chunks - 1) · α` per phase under uniform routing — the launch
//!   latency is no longer amortized across chunks;
//! - chunks = 1 stays bit-exact with the unchunked model (and with the
//!   seed schedules, pinned independently by the golden corpus);
//! - per-chunk routed byte matrices partition the unchunked matrix, so
//!   skewed routing skews per-chunk traffic instead of averaging away;
//! - the legacy (`BlockCosts`) and topology-aware chunk arithmetic agree
//!   through the shared `cluster::a2a_chunk_time` helper.

use scmoe::cluster::{a2a_chunk_time, Scenario};
use scmoe::coordinator::costs::{MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::schedule::ChunkPipelining;
use scmoe::coordinator::spec::{CostModel, PhaseDir, PhaseScope, ScheduleSpec};
use scmoe::moe::Placement;
use scmoe::report::efficiency::{
    node_affine_routing, proxy_costs, topo_proxy_costs, xl_proxy_costs,
    xl_topo_proxy_costs,
};

/// (a) Uniform routing: summing a phase over its chunks recovers the
/// unchunked phase plus exactly one extra α per additional chunk — on
/// every preset, for dispatch and combine, intra and inter.
#[test]
fn chunked_phase_totals_exceed_unchunked_by_alpha_per_extra_chunk() {
    let k = 2usize;
    for sc in Scenario::extended() {
        for tc in [topo_proxy_costs(sc), xl_topo_proxy_costs(sc)] {
            for chunks in [2usize, 3, 4, 8] {
                let ca = tc.chunk_phases(k, chunks);
                let extra = (chunks - 1) as f64;
                for d in 0..tc.n_devices() {
                    let total: f64 = (0..chunks).map(|i| ca.disp_intra[i][d]).sum();
                    let expect =
                        tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, d, k)
                        + extra * tc.phase_alpha(PhaseDir::Dispatch,
                                                 PhaseScope::Intra, d, k);
                    assert!((total - expect).abs() < 1e-12,
                            "{} dev {d} x{chunks}: {total} vs {expect}",
                            sc.label());
                    let ctotal: f64 = (0..chunks).map(|i| ca.comb_intra[i][d]).sum();
                    let cexpect =
                        tc.phase(PhaseDir::Combine, PhaseScope::Intra, d, k)
                        + extra * tc.phase_alpha(PhaseDir::Combine,
                                                 PhaseScope::Intra, d, k);
                    assert!((ctotal - cexpect).abs() < 1e-12);
                }
                for nd in 0..tc.a2a_inter_k1.len() {
                    let total: f64 = (0..chunks).map(|i| ca.disp_inter[i][nd]).sum();
                    let expect =
                        tc.phase(PhaseDir::Dispatch, PhaseScope::Inter, nd, k)
                        + extra * tc.phase_alpha(PhaseDir::Dispatch,
                                                 PhaseScope::Inter, nd, k);
                    assert!((total - expect).abs() < 1e-12,
                            "{} node {nd} x{chunks}: {total} vs {expect}",
                            sc.label());
                }
            }
        }
    }
}

/// (a, legacy twin) The `BlockCosts` path charges the identical per-chunk
/// arithmetic through the shared helper: the two models cannot disagree.
#[test]
fn legacy_chunk_time_matches_shared_helper_and_alpha_total() {
    for sc in Scenario::extended() {
        for c in [proxy_costs(sc), xl_proxy_costs(sc)] {
            for k in [1usize, 2] {
                assert_eq!(c.a2a_chunk(k, 1), c.a2a(k), "chunks=1 identity");
                for chunks in [2usize, 4, 8] {
                    assert_eq!(c.a2a_chunk(k, chunks),
                               a2a_chunk_time(c.a2a(k), c.a2a_alpha(k), chunks));
                    let total = chunks as f64 * c.a2a_chunk(k, chunks);
                    let expect = c.a2a(k)
                        + (chunks - 1) as f64 * c.a2a_alpha(k);
                    assert!((total - expect).abs() < 1e-12,
                            "{}: {total} vs {expect}", sc.label());
                }
            }
        }
    }
}

/// (b) chunks = 1 keeps the seed semantics bit-exactly: the α
/// decomposition cannot perturb an unchunked schedule (every phase runs
/// whole), OverlapPipelined{1} is Overlap, and both pipelining models
/// coincide. The chunks=1 golden corpus lines pin the same property
/// against the seed's absolute span values.
#[test]
fn single_chunk_schedules_ignore_alpha_and_staging() {
    let tc = xl_topo_proxy_costs(Scenario::FourNodeA800IBx32);
    let mut no_alpha = tc.clone();
    no_alpha.a2a_intra_alpha_k1 = Vec::new();
    no_alpha.a2a_inter_alpha_k1 = Vec::new();
    for (kind, strat, slot) in [
        (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 1 }, 0),
        (MoEKind::ScMoE { k: 1 }, Strategy::OverlapPipelined { chunks: 1 }, 2),
    ] {
        let spec = ScheduleSpec::new(kind, strat).with_slot(slot);
        let a = spec.build(&tc).run();
        let b = spec.build(&no_alpha).run();
        let c = spec
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .run();
        assert_eq!(a.len(), b.len());
        for ((x, y), z) in a.iter().zip(&b).zip(&c) {
            assert_eq!(x.start, y.start, "{}: α leaked into chunks=1", x.label);
            assert_eq!(x.end, y.end);
            assert_eq!(x.start, z.start, "{}: staging leaked into chunks=1",
                       x.label);
            assert_eq!(x.end, z.end);
        }
    }
    // OverlapPipelined{1} builds the identical graph as Overlap
    let ovl = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
        .with_slot(2)
        .build(&tc)
        .run();
    let op1 = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                Strategy::OverlapPipelined { chunks: 1 })
        .with_slot(2)
        .build(&tc)
        .run();
    assert_eq!(ovl.len(), op1.len());
    for (x, y) in ovl.iter().zip(&op1) {
        assert_eq!((x.start, x.end), (y.start, y.end), "{}", x.label);
    }
}

/// (c) Token-true chunking: the per-chunk routed byte matrices sum to the
/// unchunked matrix entry-for-entry, for any chunk count, under a skewed
/// node-affine routing.
#[test]
fn per_chunk_routed_matrices_sum_to_unchunked() {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let rt = node_affine_routing(topo.n_devices, topo.devices_per_node,
                                 topo.n_devices, 64, 1, 7);
    let p = Placement::new(topo.n_devices, topo.n_devices);
    let full = rt.a2a_bytes_placed(&p, 8192);
    for chunks in [1usize, 2, 3, 7, 16] {
        let parts = rt.chunk(chunks);
        assert_eq!(parts.len(), chunks);
        let mut sum = vec![0usize; full.len()];
        for part in &parts {
            for (s, b) in sum.iter_mut().zip(part.a2a_bytes_placed(&p, 8192)) {
                *s += b;
            }
        }
        assert_eq!(sum, full, "chunks={chunks}");
        let kept: usize = parts.iter().map(|part| part.kept()).sum();
        assert_eq!(kept, rt.kept());
    }
}

/// Skewed routing must skew *per-chunk* phases: a routing whose remote
/// traffic all sits in the first half of the token range yields a chunk 0
/// with strictly more uplink time than chunk 1 — dividing whole phases by
/// the chunk count (the seed model) would make them equal.
#[test]
fn token_true_chunks_expose_routing_skew() {
    use scmoe::cluster::Topology;
    use scmoe::coordinator::costs::ComputeCosts;
    use scmoe::moe::RoutingTable;
    // 4 devices / 2 nodes; node 0's tokens (first half) all route to
    // node 1's experts, node 1's tokens stay node-local.
    let idx: Vec<i32> = vec![2, 3, 2, 3, 2, 3, 3, 2];
    let w = vec![1.0f32; 8];
    let rt = RoutingTable::build(&idx, &w, 8, 1, 4, 8);
    let topo = Topology {
        n_devices: 4,
        devices_per_node: 2,
        intra: scmoe::cluster::LinkModel::new(1e-6, 1e9),
        inter: Some(scmoe::cluster::LinkModel::new(1e-5, 1e8)),
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    };
    let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo, &rt,
                                     &Placement::new(4, 4), 4096);
    let ca = tc.chunk_phases(1, 2);
    // chunk 0 carries all of node 0's uplink traffic...
    assert!(ca.disp_inter[0][0] > 0.0);
    // ...and chunk 1 none of it (node 1's tokens are node-local)
    assert_eq!(ca.disp_inter[1][0], 0.0);
    assert_eq!(ca.disp_inter[1][1], 0.0);
    // combine mirrors: only chunk 0 returns traffic across the fabric
    assert!(ca.comb_inter[0][1] > 0.0);
    assert_eq!(ca.comb_inter[1][1], 0.0);
    // and the built schedule differs from the evenly-divided model
    let staged = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                   Strategy::Pipelined { chunks: 2 })
        .build(&tc)
        .makespan();
    assert!(staged > 0.0);
}
