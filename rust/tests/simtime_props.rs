//! Property tests for the DES engine over random task DAGs, plus the
//! exact single-device reduction of the topology-aware builders:
//!
//! - no two spans ever overlap on an exclusive resource;
//! - every task starts after all of its dependencies finish;
//! - the pair-schedule makespan is monotone non-decreasing in every
//!   operator cost (builder-level monotonicity — see
//!   `graham_anomaly_on_arbitrary_dags` for why arbitrary DAGs are
//!   deliberately excluded from this claim);
//! - topology-aware schedules with one modeled device reproduce the
//!   legacy single-device schedules bit-exactly (same spans, same
//!   makespan — not within a tolerance).

use scmoe::coordinator::costs::{BlockCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::schedule::build_pair_schedule;
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::simtime::{Resource, Sim};
use scmoe::util::propcheck::{check, gen};
use scmoe::util::rng::Rng;

/// One generated task: (resource, duration, deps).
type DagSpec = Vec<(Resource, f64, Vec<usize>)>;

fn rand_resource(rng: &mut Rng) -> Resource {
    match rng.below(7) {
        0 | 1 => Resource::Compute(rng.below(3)),
        2 | 3 => Resource::Comm(rng.below(2)),
        4 => Resource::Link(rng.below(2)),
        5 => Resource::H2D(0),
        _ => Resource::Free,
    }
}

/// Random DAG + a perturbation target: (tasks, target index, extra duration).
fn rand_dag(rng: &mut Rng) -> (DagSpec, usize, f64) {
    let n = gen::usize_in(rng, 5, 40);
    let mut tasks = Vec::with_capacity(n);
    for i in 0..n {
        let resource = rand_resource(rng);
        let duration = gen::f64_in(rng, 0.0, 2.0);
        let mut deps: Vec<usize> = Vec::new();
        if i > 0 {
            let n_deps = rng.below(3.min(i) + 1);
            for _ in 0..n_deps {
                let d = rng.below(i);
                if !deps.contains(&d) {
                    deps.push(d);
                }
            }
        }
        tasks.push((resource, duration, deps));
    }
    let target = rng.below(n);
    let delta = gen::f64_in(rng, 0.1, 1.5);
    (tasks, target, delta)
}

fn build(tasks: &DagSpec) -> Sim {
    let mut sim = Sim::new();
    for (i, (resource, duration, deps)) in tasks.iter().enumerate() {
        sim.add(format!("t{i}"), *resource, *duration, deps);
    }
    sim
}

#[test]
fn prop_exclusive_resources_never_overlap() {
    check("exclusive-no-overlap", 200, rand_dag, |(tasks, _, _)| {
        let spans = build(tasks).run();
        let mut by_resource: std::collections::BTreeMap<Resource, Vec<(f64, f64)>> =
            std::collections::BTreeMap::new();
        for s in &spans {
            if !matches!(s.resource, Resource::Free) {
                by_resource.entry(s.resource).or_default().push((s.start, s.end));
            }
        }
        for (res, mut intervals) in by_resource {
            intervals.sort_by(|a, b| {
                a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1))
            });
            for w in intervals.windows(2) {
                if w[1].0 < w[0].1 - 1e-12 {
                    return Err(format!(
                        "{res:?}: [{:.6}, {:.6}] overlaps [{:.6}, {:.6}]",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        Ok(())
    });
}

/// List scheduling with global readiness-order dispatch is NOT monotone in
/// task durations on arbitrary DAGs (Graham's scheduling anomalies):
/// lengthening a predecessor can flip the dispatch order on a contended
/// resource and *shorten* the makespan. This construction pins the
/// behavior so nobody "fixes" a monotonicity test by accident: P delays A
/// past B's readiness, letting B's long downstream chain start 9.5 units
/// earlier.
#[test]
fn graham_anomaly_on_arbitrary_dags() {
    let makespan_with_p = |p: f64| {
        let mut sim = Sim::new();
        let pp = sim.add("P", Resource::Compute(1), p, &[]);
        let q = sim.add("Q", Resource::Free, 0.5, &[]);
        let _a = sim.add("A", Resource::Compute(0), 10.0, &[pp]);
        let b = sim.add("B", Resource::Compute(0), 1.0, &[q]);
        let _c = sim.add("C", Resource::Comm(0), 20.0, &[b]);
        sim.makespan()
    };
    assert_eq!(makespan_with_p(0.0), 31.0);
    assert_eq!(makespan_with_p(2.0), 21.5); // longer P, shorter makespan
}

const COST_FIELDS: usize = 9;

fn bump_field(c: &BlockCosts, field: usize, delta: f64) -> BlockCosts {
    let mut c = c.clone();
    match field {
        0 => c.attn += delta,
        1 => c.mlp += delta,
        2 => c.se += delta,
        3 => c.gate += delta,
        4 => c.encode += delta,
        5 => c.decode += delta,
        6 => c.expert_k1 += delta,
        7 => c.a2a_k1 += delta,
        _ => c.a2a_alpha_k1 += delta,
    }
    c
}

fn monotone_configs() -> Vec<(MoEKind, Strategy, usize)> {
    let mut out = Vec::new();
    for kind in [
        MoEKind::Standard { k: 1 },
        MoEKind::Standard { k: 2 },
        MoEKind::Standard { k: 3 },
        MoEKind::SharedExpert,
        MoEKind::ScMoE { k: 1 },
        MoEKind::ScMoE { k: 2 },
    ] {
        out.push((kind, Strategy::Sequential, 0));
        out.push((kind, Strategy::Pipelined { chunks: 2 }, 0));
        if matches!(kind, MoEKind::ScMoE { .. }) {
            for slot in 0..4 {
                out.push((kind, Strategy::Overlap, slot));
                out.push((kind, Strategy::OverlapPipelined { chunks: 2 }, slot));
            }
        }
    }
    out
}

/// The schedules we actually build ARE monotone: making any operator more
/// expensive never shrinks any architecture × strategy makespan.
#[test]
fn prop_pair_makespan_monotone_in_every_op_cost() {
    check("pair-monotone", 120, |rng| {
        let c = rand_costs(rng);
        let field = rng.below(COST_FIELDS);
        let delta = gen::f64_in(rng, 0.05, 1.0);
        (c, field, delta)
    }, |(c, field, delta)| {
        let bumped = bump_field(c, *field, *delta);
        for (kind, strategy, slot) in monotone_configs() {
            let before = build_pair_schedule(c, kind, strategy, slot).makespan();
            let after = build_pair_schedule(&bumped, kind, strategy, slot).makespan();
            if after < before - 1e-9 {
                return Err(format!(
                    "{kind:?}/{strategy:?} slot {slot}: bumping field {field} \
                     by {delta:.4} shrank {before:.6} -> {after:.6}"
                ));
            }
        }
        Ok(())
    });
}

/// Fleet-level monotonicity: slowing one device's compute or one
/// device's intra-node A2A phase never shrinks the fleet makespan.
#[test]
fn prop_topo_fleet_makespan_monotone() {
    check("topo-monotone", 120, |rng| {
        let c = rand_costs(rng);
        let field = rng.below(COST_FIELDS);
        let delta = gen::f64_in(rng, 0.05, 1.0);
        let inter = gen::f64_in(rng, 0.0, 2.0);
        let dev = rng.below(4);
        (c, field, delta, inter, dev)
    }, |(c, field, delta, inter, dev)| {
        let base = TopoCosts {
            per_device: vec![c.clone(); 4],
            a2a_intra_k1: vec![c.a2a_k1; 4],
            a2a_inter_k1: vec![*inter; 2],
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: vec![c.a2a_alpha_k1; 4],
            a2a_inter_alpha_k1: vec![*inter / 16.0; 2],
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            expert_load: None,
            devices_per_node: 2,
        };
        let mut bumped = base.clone();
        if *field < 7 {
            let slowed = bump_field(&base.per_device[*dev], *field, *delta);
            bumped.per_device[*dev] = slowed;
        } else if *field == 7 {
            bumped.a2a_intra_k1[*dev] += *delta;
        } else {
            bumped.a2a_intra_alpha_k1[*dev] += *delta;
        }
        for (kind, strategy, slot) in monotone_configs() {
            let spec = ScheduleSpec::new(kind, strategy).with_slot(slot);
            let before = spec.build(&base).makespan();
            let after = spec.build(&bumped).makespan();
            if after < before - 1e-9 {
                return Err(format!(
                    "{kind:?}/{strategy:?} slot {slot}: device {dev} field {field} \
                     +{delta:.4} shrank the fleet makespan {before:.6} -> {after:.6}"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_every_task_scheduled_after_deps() {
    check("deps-respected", 100, rand_dag, |(tasks, _, _)| {
        let spans = build(tasks).run();
        for (i, (_, _, deps)) in tasks.iter().enumerate() {
            for &d in deps {
                if spans[i].start < spans[d].end - 1e-12 {
                    return Err(format!(
                        "task {i} starts {:.6} before dep {d} ends {:.6}",
                        spans[i].start, spans[d].end
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn link_resource_serializes_within_node_only() {
    let mut sim = Sim::new();
    sim.add("x0", Resource::Link(0), 2.0, &[]);
    sim.add("x1", Resource::Link(0), 2.0, &[]);
    sim.add("y0", Resource::Link(1), 2.0, &[]);
    let spans = sim.run();
    // same link serializes; the other node's link runs concurrently
    assert_eq!(spans[0].end, 2.0);
    assert_eq!(spans[1].start, 2.0);
    assert_eq!(spans[2].start, 0.0);
    assert_eq!(sim.makespan(), 4.0);
}

// ---------------------------------------------------------------------------
// Exact N=1 reduction of the topology-aware builders
// ---------------------------------------------------------------------------

fn rand_costs(rng: &mut Rng) -> BlockCosts {
    let mut c = BlockCosts {
        attn: gen::f64_in(rng, 0.1, 2.0),
        mlp: gen::f64_in(rng, 0.1, 2.0),
        se: gen::f64_in(rng, 0.1, 2.0),
        gate: gen::f64_in(rng, 0.01, 0.2),
        encode: gen::f64_in(rng, 0.01, 0.2),
        decode: gen::f64_in(rng, 0.01, 0.2),
        expert_k1: gen::f64_in(rng, 0.1, 2.0),
        a2a_k1: gen::f64_in(rng, 0.0, 3.0),
        a2a_alpha_k1: 0.0,
    };
    // α is a fraction of the one-way time: links spend 0-50% on latency
    c.a2a_alpha_k1 = c.a2a_k1 * gen::f64_in(rng, 0.0, 0.5);
    c
}

fn assert_identical(c: &BlockCosts, tc: &TopoCosts, kind: MoEKind,
                    strategy: Strategy, slot: usize) -> Result<(), String> {
    // both CostModel back ends, through the one ScheduleSpec entry point
    let legacy = build_pair_schedule(c, kind, strategy, slot);
    let topo = ScheduleSpec::new(kind, strategy).with_slot(slot).build(tc);
    let (ls, ts) = (legacy.run(), topo.run());
    if ls.len() != ts.len() {
        return Err(format!("{kind:?}/{strategy:?}: {} vs {} spans",
                           ls.len(), ts.len()));
    }
    for (a, b) in ls.iter().zip(&ts) {
        // bit-exact: same graph, same arithmetic — not a tolerance check
        if a.label != b.label || a.resource != b.resource
            || a.start != b.start || a.end != b.end
        {
            return Err(format!(
                "{kind:?}/{strategy:?} slot {slot}: span {:?}@{}..{} vs {:?}@{}..{}",
                a.label, a.start, a.end, b.label, b.start, b.end
            ));
        }
    }
    if legacy.makespan() != topo.makespan() {
        return Err(format!("{kind:?}/{strategy:?}: makespan drifted"));
    }
    Ok(())
}

#[test]
fn prop_topo_one_device_reduces_to_legacy_bit_exactly() {
    check("topo-n1-exact", 100, rand_costs, |c| {
        let tc = TopoCosts::from_block(c);
        let kinds = [
            MoEKind::Standard { k: 1 },
            MoEKind::Standard { k: 2 },
            MoEKind::Standard { k: 3 },
            MoEKind::SharedExpert,
            MoEKind::ScMoE { k: 1 },
            MoEKind::ScMoE { k: 2 },
        ];
        for kind in kinds {
            for strategy in [
                Strategy::Sequential,
                Strategy::Pipelined { chunks: 2 },
                Strategy::Pipelined { chunks: 4 },
            ] {
                assert_identical(c, &tc, kind, strategy, 0)?;
            }
            if matches!(kind, MoEKind::ScMoE { .. }) {
                for slot in 0..4 {
                    assert_identical(c, &tc, kind, Strategy::Overlap, slot)?;
                    assert_identical(c, &tc, kind,
                                     Strategy::OverlapPipelined { chunks: 3 }, slot)?;
                }
            }
        }
        Ok(())
    });
}
