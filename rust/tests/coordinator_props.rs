//! Property-based tests over the coordinator invariants (DESIGN.md §8),
//! using the in-tree propcheck kit (offline build: no proptest crate).

use scmoe::cluster::{a2a_time, LinkModel};
use scmoe::coordinator::adaptive::{
    choose_expert_slot, eq12_lower_bound, eq13_upper_bound,
};
use scmoe::coordinator::costs::{BlockCosts, MoEKind, Strategy};
use scmoe::coordinator::schedule::{backbone_time, build_pair_schedule};
use scmoe::moe::{decode, encode, RoutingTable};
use scmoe::simtime::Resource;
use scmoe::util::propcheck::{check, gen};
use scmoe::util::rng::Rng;

fn rand_costs(rng: &mut Rng) -> BlockCosts {
    let mut c = BlockCosts {
        attn: gen::f64_in(rng, 0.1, 2.0),
        mlp: gen::f64_in(rng, 0.1, 2.0),
        se: gen::f64_in(rng, 0.1, 2.0),
        gate: gen::f64_in(rng, 0.01, 0.2),
        encode: gen::f64_in(rng, 0.01, 0.2),
        decode: gen::f64_in(rng, 0.01, 0.2),
        expert_k1: gen::f64_in(rng, 0.1, 2.0),
        a2a_k1: gen::f64_in(rng, 0.0, 3.0),
        a2a_alpha_k1: 0.0,
    };
    // α is a fraction of the one-way time: links spend 0-50% on latency
    c.a2a_alpha_k1 = c.a2a_k1 * gen::f64_in(rng, 0.0, 0.5);
    c
}

// ---------------------------------------------------------------------------
// Routing invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_routing_conservation() {
    check("routing-conservation", 200, |r| gen::routing(r), |input| {
        let (idx, w, t, k, e) = input;
        let cap = 1 + (t * k) / e;
        let rt = RoutingTable::build(idx, w, *t, *k, *e, cap);
        // kept + dropped == demand
        if rt.kept() + rt.dropped != t * k {
            return Err(format!("kept {} + dropped {} != {}", rt.kept(), rt.dropped, t * k));
        }
        // no expert over capacity; load sums to kept
        if rt.load.iter().any(|&l| l > cap) {
            return Err("capacity violated".into());
        }
        if rt.load.iter().sum::<usize>() != rt.kept() {
            return Err("load histogram inconsistent".into());
        }
        // slots unique per expert
        let mut seen = std::collections::HashSet::new();
        for r_ in &rt.routes {
            if !seen.insert((r_.expert, r_.slot)) {
                return Err(format!("slot collision {:?}", (r_.expert, r_.slot)));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_encode_decode_roundtrip_identity_experts() {
    // With ample capacity and identity expert outputs, decode(encode(x))
    // recovers sum_k w_k * x per token (weights sum to 1 -> x itself).
    check("encode-decode-roundtrip", 100, |r| gen::routing(r), |input| {
        let (idx, w, t, k, e) = input;
        let d = 4usize;
        let cap = t * k; // ample
        let rt = RoutingTable::build(idx, w, *t, *k, *e, cap);
        let mut rng = Rng::new(42);
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.next_f32()).collect();
        let enc = encode(&rt, &tokens, d);
        let dec = decode(&rt, &enc, d);
        for (i, (a, b)) in dec.iter().zip(&tokens).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!("token elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_a2a_byte_conservation() {
    check("a2a-byte-conservation", 100, |r| gen::routing(r), |input| {
        let (idx, w, t, k, e) = input;
        let rt = RoutingTable::build(idx, w, *t, *k, *e, t * k);
        let n_dev = *e; // one expert per device
        let m = rt.a2a_bytes(n_dev, 16);
        let total: usize = m.iter().sum();
        if total != rt.kept() * 16 {
            return Err(format!("bytes {total} != kept {} * 16", rt.kept()));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Scheduler invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_chosen_slot_is_argmin() {
    check("slot-argmin", 100, rand_costs, |c| {
        let kind = MoEKind::ScMoE { k: 1 };
        let (slot, best) = choose_expert_slot(c, kind, Strategy::Overlap);
        for s in 0..4 {
            let t = build_pair_schedule(c, kind, Strategy::Overlap, s).makespan();
            if t < best - 1e-12 {
                return Err(format!("slot {slot} ({best}) beaten by {s} ({t})"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_overlap_within_analytic_bounds() {
    // The simulated MoE-exposed time respects Eq. 12/13 bounds.
    check("overlap-bounds", 100, rand_costs, |c| {
        let kind = MoEKind::ScMoE { k: 1 };
        let (_, makespan) = choose_expert_slot(c, kind, Strategy::Overlap);
        let serial_comp = backbone_time(c, kind)
            + c.gate + c.encode + c.expert(1) + c.decode;
        let exposed = makespan - serial_comp;
        // Eq. 13: exposed comm never exceeds T_disp + T_comb
        if exposed > 2.0 * c.a2a(1) + 1e-9 {
            return Err(format!("exposed {exposed} > upper bound {}", 2.0 * c.a2a(1)));
        }
        let _ = (eq12_lower_bound(c, kind), eq13_upper_bound(c, kind));
        // sanity: makespan at least the serial compute (compute is exclusive)
        if makespan < serial_comp - 1e-9 {
            return Err(format!("makespan {makespan} < serial compute {serial_comp}"));
        }
        Ok(())
    });
}

#[test]
fn prop_zero_comm_overlap_equals_serial_compute() {
    check("zero-comm", 50, rand_costs, |c| {
        let mut c = c.clone();
        c.a2a_k1 = 0.0;
        let kind = MoEKind::ScMoE { k: 1 };
        let (_, t) = choose_expert_slot(&c, kind, Strategy::Overlap);
        let serial = backbone_time(&c, kind) + c.gate + c.encode
            + c.expert(1) + c.decode;
        if (t - serial).abs() > 1e-9 {
            return Err(format!("zero-comm makespan {t} != serial {serial}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pipelining_cost_bounded_by_chunk_alpha() {
    // α-true chunking: each extra chunk message pays the launch latency
    // again, so pipelining is no longer free. It can still never cost
    // more than the added latency — (chunks-1)·α per one-way phase, two
    // phases per A2A — and on latency-free links (α = 0) the seed's
    // "pipelining never hurts" claim must keep holding exactly.
    check("pipe-alpha-bound", 100, rand_costs, |c| {
        for k in [1usize, 2] {
            let kind = MoEKind::Standard { k };
            let seq = build_pair_schedule(c, kind, Strategy::Sequential, 0).makespan();
            let mut free = c.clone();
            free.a2a_alpha_k1 = 0.0;
            let seq_free =
                build_pair_schedule(&free, kind, Strategy::Sequential, 0).makespan();
            for chunks in [2usize, 4] {
                let p = build_pair_schedule(c, kind,
                                            Strategy::Pipelined { chunks }, 0).makespan();
                let bound = seq + 2.0 * (chunks - 1) as f64 * c.a2a_alpha(k);
                if p > bound + 1e-9 {
                    return Err(format!(
                        "pipe{chunks} ({p}) exceeds seq + chunk-α bound ({bound})"));
                }
                let pf = build_pair_schedule(&free, kind,
                                             Strategy::Pipelined { chunks }, 0).makespan();
                if pf > seq_free + 1e-9 {
                    return Err(format!(
                        "α-free pipe{chunks} ({pf}) worse than seq ({seq_free})"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_compute_stream_exclusive() {
    check("compute-exclusive", 60, rand_costs, |c| {
        for (kind, strat) in [
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 3 }),
            (MoEKind::ScMoE { k: 1 }, Strategy::Overlap),
            (MoEKind::ScMoE { k: 2 }, Strategy::OverlapPipelined { chunks: 2 }),
        ] {
            let slot = if matches!(strat, Strategy::Overlap
                                   | Strategy::OverlapPipelined { .. }) {
                choose_expert_slot(c, kind, strat).0
            } else {
                0
            };
            let spans = build_pair_schedule(c, kind, strat, slot).run();
            let mut comp: Vec<_> = spans.iter()
                .filter(|s| matches!(s.resource, Resource::Compute(_)))
                .collect();
            comp.sort_by(|a, b| a.start.total_cmp(&b.start));
            for w in comp.windows(2) {
                if w[1].start < w[0].end - 1e-9 {
                    return Err(format!("compute overlap {} / {}", w[0].label, w[1].label));
                }
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Interconnect invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_a2a_time_monotone_in_bytes() {
    check("a2a-monotone", 100, |r| {
        let n = [2usize, 4, 8][r.below(3)];
        let bytes: Vec<usize> = (0..n * n).map(|_| r.below(1 << 20)).collect();
        (n, bytes)
    }, |(n, bytes)| {
        let link = LinkModel::new(1e-6, 1e9);
        let t1 = a2a_time(bytes, *n, *n, link, None);
        let doubled: Vec<usize> = bytes.iter().map(|b| b * 2).collect();
        let t2 = a2a_time(&doubled, *n, *n, link, None);
        if t2 < t1 - 1e-12 {
            return Err(format!("doubling bytes reduced time {t1} -> {t2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_faster_link_never_slower() {
    check("link-dominance", 100, |r| {
        let n = 4usize;
        let bytes: Vec<usize> = (0..16).map(|_| r.below(1 << 22)).collect();
        bytes
    }, |bytes| {
        let slow = LinkModel::new(10e-6, 1e9);
        let fast = LinkModel::new(1e-6, 10e9);
        let ts = a2a_time(bytes, 4, 4, slow, None);
        let tf = a2a_time(bytes, 4, 4, fast, None);
        if tf > ts + 1e-12 {
            return Err(format!("fast link slower: {tf} > {ts}"));
        }
        Ok(())
    });
}
