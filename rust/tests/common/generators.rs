//! Shared golden-corpus generators: every simulation the golden-timeline
//! snapshot pins, exposed as `(name, Sim)` pairs so both the snapshot
//! test (`golden_timelines.rs`) and the analysis-layer property suite
//! (`analyze_timeline.rs`) iterate the *identical* set of schedules —
//! the same set `tools/des_mirror/mirror2.py` replays in Python.
//!
//! Lives under `tests/common/` (outside the CI test-target glob) and is
//! included via `#[path]` so it never becomes a test binary of its own.
#![allow(dead_code)]

use scmoe::cluster::{ChaosSpec, LinkFault, LinkModel, Topology};
use scmoe::coordinator::costs::{BlockCosts, ComputeCosts, MoEKind, Strategy,
                                TopoCosts};
use scmoe::coordinator::model::{build_model_sim, model_layer_costs,
                                ModelSpec, PipelineSchedule};
use scmoe::coordinator::replace::{failover_placement, MigrationPlan};
use scmoe::coordinator::schedule::{build_pair_schedule, ChunkPipelining};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{phase_affine_routing, Placement, RoutingTable};
use scmoe::simtime::{Resource, Sim, Span};
use scmoe::util::rng::Rng;

pub fn dyadic_costs() -> BlockCosts {
    BlockCosts {
        attn: 1.0,
        mlp: 0.75,
        se: 0.75,
        gate: 0.0625,
        encode: 0.0625,
        decode: 0.0625,
        expert_k1: 0.5,
        a2a_k1: 0.8125,
        // 1/13 of the one-way time is launch latency: chunked entries pay
        // it per chunk, so pipe4 visibly stops dominating pipe2
        a2a_alpha_k1: 0.0625,
    }
}

/// 2 nodes × 2 devices; node 1 runs every compute op 2x slower.
pub fn dyadic_fleet() -> TopoCosts {
    let fast = dyadic_costs();
    let mut slow = dyadic_costs();
    slow.attn *= 2.0;
    slow.mlp *= 2.0;
    slow.se *= 2.0;
    slow.gate *= 2.0;
    slow.encode *= 2.0;
    slow.decode *= 2.0;
    slow.expert_k1 *= 2.0;
    TopoCosts {
        per_device: vec![fast.clone(), fast, slow.clone(), slow],
        a2a_intra_k1: vec![0.25; 4],
        a2a_inter_k1: vec![0.5; 2],
        a2a_intra_combine_k1: Vec::new(),
        a2a_inter_combine_k1: Vec::new(),
        a2a_intra_alpha_k1: vec![0.0625; 4],
        a2a_inter_alpha_k1: vec![0.125; 2],
        a2a_intra_combine_alpha_k1: Vec::new(),
        a2a_inter_combine_alpha_k1: Vec::new(),
        chunk_source: None,
        expert_load: None,
        devices_per_node: 2,
    }
}

/// Dyadic routed-placement scenario: 4 devices in 2 nodes with
/// power-of-two link constants, a node-affine routing table (node 0's
/// tokens pick experts {0, 2}; node 1's pick {1, 3}), and three expert
/// placements. Every duration is a dyadic rational, so the snapshot
/// format stays exact.
pub fn routed_table() -> RoutingTable {
    let indices: Vec<i32> = vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
    let weights = vec![1.0f32; 16];
    RoutingTable::build(&indices, &weights, 16, 1, 4, 16)
}

pub fn routed_topology() -> Topology {
    Topology {
        n_devices: 4,
        devices_per_node: 2,
        intra: LinkModel::new(0.0625, 1024.0),
        inter: Some(LinkModel::new(0.125, 512.0)),
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    }
}

pub fn routed_base_costs() -> ComputeCosts {
    ComputeCosts {
        attn: 1.0,
        mlp: 0.75,
        se: 0.75,
        gate: 0.0625,
        encode: 0.0625,
        decode: 0.0625,
        expert_k1: 0.5,
    }
}

pub fn routed_fleet(rt: &RoutingTable, placement: &Placement) -> TopoCosts {
    TopoCosts::from_routing(&routed_base_costs(), &routed_topology(), rt,
                            placement, 64)
}

/// Layer 1's routing: every token's corpus-table expert shifted by +1
/// mod 4 (a deterministic inter-layer transition, dyadic-exact).
pub fn rt0_shifted_indices() -> Vec<i32> {
    vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3]
        .into_iter()
        .map(|e| (e + 1) % 4)
        .collect()
}

pub fn resource_token(r: Resource) -> String {
    match r {
        Resource::Compute(d) => format!("c{d}"),
        Resource::Comm(d) => format!("m{d}"),
        Resource::Link(n) => format!("l{n}"),
        Resource::H2D(d) => format!("h{d}"),
        Resource::D2H(d) => format!("d{d}"),
        Resource::Free => "f".into(),
    }
}

pub fn render_spans(name: &str, mut spans: Vec<Span>) -> String {
    let makespan = spans.iter().fold(0.0f64, |m, s| m.max(s.end));
    spans.sort_by(|a, b| a.start.total_cmp(&b.start).then(a.id.cmp(&b.id)));
    let toks: Vec<String> = spans
        .iter()
        .map(|s| format!("{}@{}@{:.6}", s.label, resource_token(s.resource), s.start))
        .collect();
    format!("{name} | makespan {makespan:.6} | {}", toks.join(" "))
}

/// Every golden-corpus simulation, in corpus order, as `(name, Sim)` —
/// the snapshot test renders them, the analyze suite runs properties
/// over them. Must stay in lockstep with mirror2.py's corpus.
pub fn golden_sims() -> Vec<(String, Sim)> {
    let c = dyadic_costs();
    let mut sims: Vec<(String, Sim)> = Vec::new();
    let kinds = [
        MoEKind::Standard { k: 1 },
        MoEKind::Standard { k: 2 },
        MoEKind::Standard { k: 3 },
        MoEKind::SharedExpert,
        MoEKind::ScMoE { k: 1 },
        MoEKind::ScMoE { k: 2 },
    ];
    for kind in kinds {
        let strategies: Vec<Strategy> = match kind {
            MoEKind::Standard { .. } => vec![
                Strategy::Sequential,
                Strategy::Pipelined { chunks: 2 },
                Strategy::Pipelined { chunks: 4 },
            ],
            MoEKind::SharedExpert => vec![
                Strategy::Sequential,
                Strategy::Pipelined { chunks: 1 },
                Strategy::Pipelined { chunks: 2 },
            ],
            MoEKind::ScMoE { .. } => vec![
                Strategy::Sequential,
                Strategy::Pipelined { chunks: 2 },
            ],
        };
        for strategy in strategies {
            let name = format!("{}/{}", kind.label(), strategy.label());
            sims.push((name, build_pair_schedule(&c, kind, strategy, 0).sim));
        }
        if matches!(kind, MoEKind::ScMoE { .. }) {
            for slot in 0..4 {
                let s = build_pair_schedule(&c, kind, Strategy::Overlap, slot);
                sims.push((format!("{}/overlap-s{slot}", kind.label()), s.sim));
            }
            for slot in 0..4 {
                let s = build_pair_schedule(
                    &c, kind, Strategy::OverlapPipelined { chunks: 2 }, slot);
                sims.push((format!("{}/overlap+pipe2-s{slot}", kind.label()),
                           s.sim));
            }
        }
    }

    let tf = dyadic_fleet();
    sims.push(("fleet:Top2/seq".into(),
               ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Sequential)
                   .build(&tf)
                   .sim));
    sims.push(("fleet:Top2/pipe2".into(),
               ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Pipelined { chunks: 2 })
                   .build(&tf)
                   .sim));
    sims.push(("fleet:Top2/pipe2-chained".into(),
               ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                 Strategy::Pipelined { chunks: 2 })
                   .with_pipelining(ChunkPipelining::PhaseChained)
                   .build(&tf)
                   .sim));
    for slot in 0..4 {
        sims.push((format!("fleet:ScMoE/overlap-s{slot}"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Overlap)
                       .with_slot(slot)
                       .build(&tf)
                       .sim));
    }
    sims.push(("fleet:ScMoE/overlap+pipe2-s2".into(),
               ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                 Strategy::OverlapPipelined { chunks: 2 })
                   .with_slot(2)
                   .build(&tf)
                   .sim));

    let rt = routed_table();
    for (name, placement) in [
        ("block", Placement::new(4, 4)),
        ("affinity", Placement::affinity_packed(&rt, 4, 2)),
        ("skewed", Placement::imbalance_skewed(4, 4, 2)),
    ] {
        let tc = routed_fleet(&rt, &placement);
        sims.push((format!("routed:{name}/seq"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Sequential)
                       .build(&tc)
                       .sim));
        sims.push((format!("routed:{name}/overlap-s2"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Overlap)
                       .with_slot(2)
                       .build(&tc)
                       .sim));
        sims.push((format!("routed:{name}/overlap+pipe2-s2"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::OverlapPipelined { chunks: 2 })
                       .with_slot(2)
                       .build(&tc)
                       .sim));
        // token-true chunked expert compute: each chunk's Expert span is
        // proportional to its own kept copies on that device
        sims.push((format!("routed:{name}/pipe2"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Pipelined { chunks: 2 })
                       .build(&tc)
                       .sim));
    }

    // live re-placement migration steps: the routed block-placement
    // schedules with the block->affinity MigrationPlan's H2D transfers
    // overlapped in as dependency-free tasks on the h<dev> engines
    // (4096 B/expert over an alpha=0.125 beta=1024 H2D link -> 4.125 s
    // per moved expert). The pre-existing spans stay byte-identical to
    // the routed:block entries (mirror consistency_checks5).
    let block = Placement::new(4, 4);
    let affinity = Placement::affinity_packed(&rt, 4, 2);
    let plan = MigrationPlan::between(&block, &affinity, 4096);
    let h2d = LinkModel::new(0.125, 1024.0);
    let tc = routed_fleet(&rt, &block);
    for (name, spec) in [
        ("seq",
         ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential)),
        ("overlap-s2",
         ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
             .with_slot(2)),
        ("pipe2",
         ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                           Strategy::Pipelined { chunks: 2 })),
    ] {
        let mut sched = spec.build(&tc);
        plan.add_h2d_tasks(&mut sched.sim, &h2d);
        sims.push((format!("replace:block->affinity/{name}"), sched.sim));
    }

    // open-loop serving steps: phase_affine_routing batches priced on
    // the routed fleet under the block placement. serve:wait1/* pins
    // the serving loop's per-step traffic-seed advance (seeds 97..99,
    // uniform noise 0.25); serve:mixed pins the prefill/decode noise
    // split (8 exact prompt tokens + 8 decode tokens at 0.5).
    for s in 0..3u64 {
        let rt = phase_affine_routing(4, 2, 4, 16, 0, 0, 0.25, 0.25, 97 + s);
        let tc = routed_fleet(&rt, &block);
        sims.push((format!("serve:wait1/step{s}"),
                   ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Sequential)
                       .build(&tc)
                       .sim));
    }
    let rt = phase_affine_routing(4, 2, 4, 8, 8, 0, 0.0, 0.5, 98);
    let tc = routed_fleet(&rt, &block);
    sims.push(("serve:mixed/seq".into(),
               ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                 Strategy::Sequential)
                   .build(&tc)
                   .sim));

    // chaos goldens on the same dyadic routed fleet, all rng-free so
    // every span stays dyadic-exact: a persistent 2x straggler on device
    // 3, a degraded shared uplink (alpha x2, beta /4 ->
    // LinkModel(0.25, 128)), and a device-3 dropout whose failover plan
    // (E3 -> device 0, the lowest-id tie) overlaps the clean step as an
    // H2D task (mirror generate_chaos_lines7)
    let rt = routed_table();
    let topo = routed_topology();
    let base = routed_base_costs();
    let straggler = ChaosSpec { stragglers: vec![(3, 2.0)],
                                ..ChaosSpec::clean(0) };
    let tc = TopoCosts::from_routing(&base, &straggler.perturb(&topo, 0), &rt,
                                     &block, 64);
    sims.push(("chaos:straggler/seq".into(),
               ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                 Strategy::Sequential)
                   .build(&tc)
                   .sim));
    let degraded = ChaosSpec {
        link_faults: vec![LinkFault { node: None, alpha_mult: 2.0,
                                      beta_div: 4.0, flap: None }],
        ..ChaosSpec::clean(0)
    };
    let tc = TopoCosts::from_routing(&base, &degraded.perturb(&topo, 0), &rt,
                                     &block, 64);
    sims.push(("chaos:degraded-uplink/overlap-s2".into(),
               ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
                   .with_slot(2)
                   .build(&tc)
                   .sim));
    let failover = failover_placement(&block, 3);
    let plan = MigrationPlan::between(&block, &failover, 4096);
    let tc = TopoCosts::from_routing(&base, &topo, &rt, &block, 64);
    let mut sched = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                      Strategy::Sequential)
        .build(&tc);
    plan.add_h2d_tasks(&mut sched.sim, &h2d);
    sims.push(("chaos:dropout-recovery/seq".into(), sched.sim));

    // whole-model L-layer pipeline timelines (build_model_sim): layer 0
    // is the routed corpus table, layer 1 its +1-stride successor priced
    // from chained sources under the block placement. L2S2 lines put
    // layer 1 on stage 1's engines (c4..c7, m4..m7, l2..l3). The final
    // line pins source-side D2H pricing: the replace-corpus
    // block->affinity plan with each H2D write chained behind its d2h
    // read-out (4096 B/expert over alpha=0.0625 beta=2048 -> 2.0625 s
    // per moved expert on d<dev>). Mirror generate_model_lines8.
    let rt0 = routed_table();
    let idx1: Vec<i32> = rt0_shifted_indices();
    let rt1 = RoutingTable::build(&idx1, &vec![1.0f32; 16], 16, 1, 4, 16);
    let model_sim = |n_layers: usize, stages: usize, microbatches: usize,
                     schedule: PipelineSchedule| {
        let tabs: Vec<RoutingTable> =
            [rt0.clone(), rt1.clone()][..n_layers].to_vec();
        let ps = vec![Placement::new(4, 4); n_layers];
        let costs = model_layer_costs(&base, &topo, 64, &tabs, &ps,
                                      microbatches);
        let spec = ModelSpec {
            layers: vec![ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                           Strategy::Sequential); n_layers],
            stages,
            microbatches,
            schedule,
        };
        let (sim, _) = build_model_sim(&spec, &costs, 4, 2);
        sim
    };
    sims.push(("model:L1/seq-m1".into(),
               model_sim(1, 1, 1, PipelineSchedule::LayerSequential)));
    sims.push(("model:L2/seq-m1".into(),
               model_sim(2, 1, 1, PipelineSchedule::LayerSequential)));
    sims.push(("model:L2/gpipe-m2".into(),
               model_sim(2, 1, 2, PipelineSchedule::GPipe)));
    sims.push(("model:L2/1f1b-m2".into(),
               model_sim(2, 1, 2, PipelineSchedule::OneFOneB)));
    sims.push(("model:L2S2/gpipe-m2".into(),
               model_sim(2, 2, 2, PipelineSchedule::GPipe)));
    sims.push(("model:L2S2/layerseq-m2".into(),
               model_sim(2, 2, 2, PipelineSchedule::LayerSequential)));
    let affinity = Placement::affinity_packed(&rt0, 4, 2);
    let plan = MigrationPlan::between(&block, &affinity, 4096);
    let d2h = LinkModel::new(0.0625, 2048.0);
    let tc = routed_fleet(&rt0, &block);
    let mut sched = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                      Strategy::Sequential)
        .build(&tc);
    plan.add_transfer_tasks(&mut sched.sim, &h2d, Some(&d2h), 0);
    sims.push(("model:d2h-migration/seq".into(), sched.sim));
    sims
}

/// Seeded random task DAG exercising the engine edge cases the builder
/// corpus cannot reach: duplicate dependencies (legal — readiness counts
/// per occurrence), zero-duration tie pile-ups, [`Resource::Free`]
/// tasks interleaved everywhere, and random resource counts. Durations
/// are dyadic (multiples of 1/4) so ready-time collisions are common and
/// span comparisons stay exact across engines.
pub fn random_dag_sim(seed: u64) -> Sim {
    let mut rng = Rng::new(seed);
    let n = 10 + rng.below(121);
    let n_compute = 1 + rng.below(6);
    let n_comm = 1 + rng.below(4);
    let n_link = 1 + rng.below(3);
    let mut sim = Sim::new();
    for i in 0..n {
        let resource = match rng.below(10) {
            0..=2 => Resource::Compute(rng.below(n_compute)),
            3..=5 => Resource::Comm(rng.below(n_comm)),
            6 => Resource::Link(rng.below(n_link)),
            7 => Resource::H2D(rng.below(2)),
            8 => Resource::D2H(rng.below(2)),
            _ => Resource::Free,
        };
        let duration = if rng.below(4) == 0 {
            0.0
        } else {
            rng.below(32) as f64 * 0.25
        };
        let mut deps: Vec<usize> = Vec::new();
        if i > 0 {
            for _ in 0..rng.below(5) {
                deps.push(rng.below(i)); // duplicates are deliberate
            }
        }
        sim.add(format!("t{i}"), resource, duration, &deps);
    }
    sim
}

/// `count` random DAGs seeded `seed..seed+count`, as named sims.
pub fn random_dag_sims(count: usize, seed: u64) -> Vec<(String, Sim)> {
    (0..count)
        .map(|i| (format!("rand-dag-{i}"), random_dag_sim(seed + i as u64)))
        .collect()
}

/// Dyadic fleet cost model at arbitrary scale: `n_nodes` × `per_node`
/// devices, odd nodes 2x slower on compute, every compute and wire
/// constant a dyadic rational times `scale` (itself dyadic in every
/// caller, so spans stay exact). Shared by the equivalence tests and
/// `benches/des_engine.rs` so the bench prices exactly the graph family
/// the differential harness locks down.
pub fn fleet_costs_scaled(n_nodes: usize, per_node: usize,
                          scale: f64) -> TopoCosts {
    let base = dyadic_costs();
    let mut per_device = Vec::with_capacity(n_nodes * per_node);
    for node in 0..n_nodes {
        let slow = if node % 2 == 1 { 2.0 } else { 1.0 };
        for _ in 0..per_node {
            per_device.push(BlockCosts {
                attn: base.attn * slow * scale,
                mlp: base.mlp * slow * scale,
                se: base.se * slow * scale,
                gate: base.gate * slow * scale,
                encode: base.encode * slow * scale,
                decode: base.decode * slow * scale,
                expert_k1: base.expert_k1 * slow * scale,
                a2a_k1: base.a2a_k1,
                a2a_alpha_k1: base.a2a_alpha_k1,
            });
        }
    }
    TopoCosts {
        per_device,
        a2a_intra_k1: vec![0.25 * scale; n_nodes * per_node],
        a2a_inter_k1: vec![0.5 * scale; n_nodes],
        a2a_intra_combine_k1: Vec::new(),
        a2a_inter_combine_k1: Vec::new(),
        a2a_intra_alpha_k1: vec![0.0625; n_nodes * per_node],
        a2a_inter_alpha_k1: vec![0.125; n_nodes],
        a2a_intra_combine_alpha_k1: Vec::new(),
        a2a_inter_combine_alpha_k1: Vec::new(),
        chunk_source: None,
        expert_load: None,
        devices_per_node: per_node,
    }
}

/// The fleet-scale schedule sweep — the (kind, strategy) pairs the
/// replace-timeline and chunk-sweep studies price per step — as specs,
/// so callers pick the cost scale (tests build at scale 1.0; the bench
/// alternates scales to exercise warm re-pricing).
pub fn fleet_sweep_specs() -> Vec<(String, ScheduleSpec)> {
    let sc = MoEKind::ScMoE { k: 1 };
    let top2 = MoEKind::Standard { k: 2 };
    vec![
        ("sweep:Top2/seq".into(),
         ScheduleSpec::new(top2, Strategy::Sequential)),
        ("sweep:Top2/pipe2".into(),
         ScheduleSpec::new(top2, Strategy::Pipelined { chunks: 2 })),
        ("sweep:Top2/pipe4".into(),
         ScheduleSpec::new(top2, Strategy::Pipelined { chunks: 4 })),
        ("sweep:Top2/pipe8".into(),
         ScheduleSpec::new(top2, Strategy::Pipelined { chunks: 8 })),
        ("sweep:Top2/pipe2-chained".into(),
         ScheduleSpec::new(top2, Strategy::Pipelined { chunks: 2 })
             .with_pipelining(ChunkPipelining::PhaseChained)),
        ("sweep:ScMoE/seq".into(),
         ScheduleSpec::new(sc, Strategy::Sequential)),
        ("sweep:ScMoE/overlap-s2".into(),
         ScheduleSpec::new(sc, Strategy::Overlap).with_slot(2)),
        ("sweep:ScMoE/overlap+pipe2-s2".into(),
         ScheduleSpec::new(sc, Strategy::OverlapPipelined { chunks: 2 })
             .with_slot(2)),
        ("sweep:ScMoE/overlap+pipe4-s2".into(),
         ScheduleSpec::new(sc, Strategy::OverlapPipelined { chunks: 4 })
             .with_slot(2)),
    ]
}

/// The sweep built on an `n_nodes` × `per_node` fleet at scale 1.0.
pub fn fleet_sweep_sims(n_nodes: usize,
                        per_node: usize) -> Vec<(String, Sim)> {
    let tc = fleet_costs_scaled(n_nodes, per_node, 1.0);
    fleet_sweep_specs()
        .into_iter()
        .map(|(name, spec)| {
            (format!("{name}@{n_nodes}x{per_node}"), spec.build(&tc).sim)
        })
        .collect()
}
