//! §Perf measurement: single-step vs fused multi-step training throughput
//! (run with --nocapture to see the numbers; asserted loosely so CI noise
//! doesn't flake).

use std::path::Path;
use std::sync::Arc;

use scmoe::runtime::Engine;
use scmoe::train::Trainer;

#[test]
fn fused_steps_reduce_boundary_overhead() {
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                "/artifacts/quality_scmoe_micro"));
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(dir).unwrap();
    if !set.names().iter().any(|n| n.starts_with("train_step_")) {
        eprintln!("skipping: no fused artifact (rebuild artifacts)");
        return;
    }

    // single-step path
    let mut tr1 = Trainer::new(&set, 0).unwrap();
    tr1.train_step().unwrap(); // compile + warmup
    let t0 = std::time::Instant::now();
    for _ in 0..8 {
        tr1.train_step().unwrap();
    }
    let single = t0.elapsed().as_secs_f64() / 8.0;

    // fused path (train_step_4): 2 calls = 8 steps
    let mut tr2 = Trainer::new(&set, 0).unwrap();
    tr2.train_steps_fused(1).unwrap(); // compile + warmup
    let t0 = std::time::Instant::now();
    tr2.train_steps_fused(2).unwrap();
    let fused = t0.elapsed().as_secs_f64() / 8.0;

    println!("PERF single-step: {:.2} ms/step | fused x4: {:.2} ms/step | {:.2}x",
             single * 1e3, fused * 1e3, single / fused);
    // same learning signal: losses finite & comparable trajectories
    assert!(tr2.records.iter().all(|r| r.loss.is_finite()));
    // fused must not be dramatically slower (it should be faster; allow noise)
    assert!(fused < single * 1.2,
            "fused {fused} vs single {single} — boundary fusion regressed");
}
