//! Acceptance tests for the chaos scenario suite: the zero-perturbation
//! identity (a zero-magnitude ChaosSpec reduces bit-exactly to the clean
//! schedules and to `run_replace_timeline`), seeded jitter determinism,
//! dropout failover semantics, the C2R noise-0 reduction, and the pinned
//! robustness-study headlines on 32xA800-4node-IB (break-even failover
//! beats static placement under dropout; C2R's bounded fanout is immune
//! to the uplink fault at a pinned clean-path cost). Every pinned value
//! was minted through the validated DES mirror
//! (`tools/des_mirror/mirror2.py --chaos-study`).

use scmoe::cluster::{ChaosSpec, Dropout, LinkFault, LinkModel, Scenario,
                     Topology};
use scmoe::coordinator::costs::{ComputeCosts, MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::replace::{
    failover_placement, run_chaos_timeline, run_replace_timeline,
    ReplaceConfig, ReplacePolicy,
};
use scmoe::coordinator::schedule::PairSchedule;
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::moe::{c2r_routing, Placement, RoutingTable};
use scmoe::report::chaos::{
    c2r_study_tables, c2r_uplink_fault, chaos_scenarios, run_chaos_cell,
    tail_stats, CHAOS_DROP_STEP, C2R_NOISE,
};
use scmoe::report::efficiency::{drifting_node_affine_routing,
                                xl_compute_costs};
use scmoe::report::replace::{study_tables, STUDY_DRIFT_NOISE,
                             STUDY_DRIFT_SEED};

fn dyadic_topo() -> Topology {
    Topology {
        n_devices: 4,
        devices_per_node: 2,
        intra: LinkModel::new(0.0625, 1024.0),
        inter: Some(LinkModel::new(0.125, 512.0)),
        compute_scale: 1.0,
        device_scales: None,
        node_intra: None,
    }
}

fn dyadic_base() -> ComputeCosts {
    ComputeCosts {
        attn: 1.0,
        mlp: 0.75,
        se: 0.75,
        gate: 0.0625,
        encode: 0.0625,
        decode: 0.0625,
        expert_k1: 0.5,
    }
}

fn dyadic_cfg(policy: ReplacePolicy) -> ReplaceConfig {
    ReplaceConfig {
        spec: ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential),
        policy,
        bytes_per_expert: 4096,
        h2d: LinkModel::new(0.125, 1024.0),
        d2h_link: None,
        decay: 1.0,
    }
}

fn dyadic_tables(n: usize, seed0: u64) -> Vec<RoutingTable> {
    (0..n)
        .map(|s| drifting_node_affine_routing(4, 2, 4, 4, 0, 0.25,
                                              seed0 + s as u64))
        .collect()
}

/// A structurally non-trivial spec whose every magnitude is identity: a
/// 1.0x straggler, a 1.0x/1.0x uplink fault, and a *never-active* flap
/// fault (up == period) whose magnitudes would bite if the flap gate
/// ever let it through.
fn zero_spec(topo: &Topology) -> ChaosSpec {
    let mut spec = ChaosSpec::clean(9);
    spec.stragglers.push((topo.n_devices - 1, 1.0));
    spec.link_faults.push(LinkFault {
        node: Some(0),
        alpha_mult: 1.0,
        beta_div: 1.0,
        flap: None,
    });
    spec.link_faults.push(LinkFault {
        node: None,
        alpha_mult: 4.0,
        beta_div: 4.0,
        flap: Some((4, 4)),
    });
    spec
}

/// Span fingerprint (label, resource, start, end) in deterministic
/// order — `Span` has no `PartialEq`, so identity is asserted on this.
fn fingerprint(sched: &PairSchedule) -> Vec<(String, String, f64, f64)> {
    let mut spans = sched.run();
    spans.sort_by(|a, b| a.start.total_cmp(&b.start).then(a.id.cmp(&b.id)));
    spans
        .iter()
        .map(|s| (s.label.clone(), format!("{:?}", s.resource), s.start,
                  s.end))
        .collect()
}

fn device_map(p: &Placement) -> Vec<usize> {
    (0..p.n_experts).map(|e| p.device_of(e)).collect()
}

#[test]
fn zero_perturbation_reduces_to_clean_schedules() {
    // a zero-magnitude spec must leave every preset's every schedule
    // bit-identical to the clean `ScheduleSpec::build` timeline — same
    // spans, same starts, same ends, across strategy and placement
    let base = xl_compute_costs();
    for (i, sc) in Scenario::extended().into_iter().enumerate() {
        let topo = sc.topology();
        let (nd, dpn) = (topo.n_devices, topo.devices_per_node);
        let rt = drifting_node_affine_routing(nd, dpn, nd, 16, 0, 0.2,
                                              900 + i as u64);
        let spec = zero_spec(&topo);
        for step in 0..4 {
            let ptopo = spec.perturb(&topo, step);
            for placement in [Placement::new(nd, nd),
                              Placement::affinity_packed(&rt, nd, dpn)] {
                let clean = TopoCosts::from_routing(&base, &topo, &rt,
                                                    &placement, 64);
                let dirty = TopoCosts::from_routing(&base, &ptopo, &rt,
                                                    &placement, 64);
                for (strategy, slot) in [(Strategy::Sequential, 0),
                                         (Strategy::Overlap, 2)] {
                    let s = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                              strategy)
                        .with_slot(slot);
                    assert_eq!(fingerprint(&s.build(&clean)),
                               fingerprint(&s.build(&dirty)),
                               "{} step {step} {strategy:?}", sc.label());
                }
            }
        }
    }
}

#[test]
fn zero_chaos_timeline_is_bit_exact_replace_timeline() {
    // with a clean spec, run_chaos_timeline must be byte-for-byte
    // run_replace_timeline — every StepReport field, the total, the
    // migration count, and the final placement — under every policy
    let tables = dyadic_tables(6, 700);
    let initial = Placement::new(4, 4);
    let chaos = ChaosSpec::clean(0);
    assert!(chaos.is_zero());
    for policy in [ReplacePolicy::Never, ReplacePolicy::EveryK { k: 2 },
                   ReplacePolicy::BreakEven] {
        let cfg = dyadic_cfg(policy);
        let a = run_replace_timeline(&dyadic_base(), &dyadic_topo(), 64,
                                     &tables, &initial, &cfg);
        let b = run_chaos_timeline(&dyadic_base(), &dyadic_topo(), 64,
                                   &tables, &initial, &cfg, &chaos);
        assert_eq!(a.steps.len(), b.steps.len());
        for (x, y) in a.steps.iter().zip(&b.steps) {
            assert_eq!(x.step, y.step);
            assert_eq!(x.makespan, y.makespan); // bit-exact, no tolerance
            assert_eq!(x.base_makespan, y.base_makespan);
            assert_eq!(x.migrated, y.migrated);
            assert_eq!(x.migration_bytes, y.migration_bytes);
            assert_eq!(x.migration_time, y.migration_time);
        }
        assert_eq!(a.total, b.total);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(device_map(&a.final_placement),
                   device_map(&b.final_placement));
    }
}

#[test]
fn jitter_stream_is_seeded_and_forks_per_step() {
    // identical seeds perturb identically (byte-identical timelines);
    // distinct seeds and distinct steps perturb differently
    let topo = dyadic_topo();
    let spec = ChaosSpec { jitter: 0.25, ..ChaosSpec::clean(41) };
    let scales = |s: &ChaosSpec, step: usize| {
        s.perturb(&topo, step).device_scales.expect("jitter sets scales")
    };
    assert_eq!(scales(&spec, 2), scales(&spec, 2));
    let other = ChaosSpec { jitter: 0.25, ..ChaosSpec::clean(42) };
    assert_ne!(scales(&spec, 2), scales(&other, 2), "seed must matter");
    assert_ne!(scales(&spec, 2), scales(&spec, 3), "step must fork");

    // and the full timeline inherits the determinism: two identical runs
    // of a jittered stream produce bit-equal totals
    let tables = dyadic_tables(4, 300);
    let cfg = dyadic_cfg(ReplacePolicy::Never);
    let run = |chaos: &ChaosSpec| {
        run_chaos_timeline(&dyadic_base(), &dyadic_topo(), 64, &tables,
                           &Placement::new(4, 4), &cfg, chaos)
    };
    assert_eq!(run(&spec).total, run(&spec).total);
    assert_ne!(run(&spec).total, run(&other).total);
}

#[test]
fn failover_rebalances_to_least_loaded_survivors() {
    // block(4,4): expert 3 leaves dead device 3 for device 0 (all
    // survivors tie at load 1; lowest id wins)
    let p = failover_placement(&Placement::new(4, 4), 3);
    assert_eq!(device_map(&p), vec![0, 1, 2, 0]);
    // skewed start {d0: e0 e1 e2, d1: e3}, device 0 dies: the running
    // load update spreads the three orphans over the survivors instead
    // of dogpiling one
    let skew = Placement::custom(4, 3, vec![0, 0, 0, 1]);
    assert_eq!(device_map(&failover_placement(&skew, 0)), vec![2, 1, 2, 1]);
}

#[test]
fn dropout_fires_failover_and_prices_the_storm() {
    let tables = dyadic_tables(4, 300);
    let chaos = ChaosSpec {
        dropout: Some(Dropout { device: 3, at_step: 1 }),
        ..ChaosSpec::clean(0)
    };
    let out = run_chaos_timeline(&dyadic_base(), &dyadic_topo(), 64, &tables,
                                 &Placement::new(4, 4),
                                 &dyadic_cfg(ReplacePolicy::Never), &chaos);
    // the Never policy migrates exactly once: the forced failover
    assert_eq!(out.migrations, 1);
    assert!(out.steps[1].migrated, "failover fires at the dropout step");
    assert_eq!(out.steps[1].migration_bytes, 4096, "one expert moves");
    assert!(out.steps[1].makespan >= out.steps[1].base_makespan,
            "the recovery step absorbs the migration storm");
    for step in &out.steps {
        assert!(!step.migrated || step.step == 1);
    }
    // no expert remains on the dead device, from the dropout step on
    assert!(device_map(&out.final_placement).iter().all(|&d| d != 3),
            "final placement {:?} still uses the dead device",
            device_map(&out.final_placement));
}

#[test]
fn c2r_reduces_to_node_affine_at_zero_noise() {
    // at noise 0 the collaboration constraint never engages: the routed
    // experts (and hence the whole downstream cost model) are bit-equal
    // to drifting_node_affine_routing on the same seed
    for (regime, seed) in [(0usize, 3u64), (1, 11)] {
        let a = c2r_routing(4, 2, 8, 16, regime, 0.0, 2, seed);
        let b = drifting_node_affine_routing(4, 2, 8, 16, regime, 0.0, seed);
        let experts = |rt: &RoutingTable| -> Vec<usize> {
            rt.routes.iter().map(|r| r.expert).collect()
        };
        assert_eq!(experts(&a), experts(&b));
        assert_eq!(a.load, b.load);
    }
}

#[test]
fn chaos_study_dropout_headline_is_pinned() {
    // the acceptance headline: under the device-5 dropout, break-even
    // re-placement beats riding out the degraded static layout, because
    // re-learning repacks the post-failover placement. Totals, tails and
    // migration counts pinned via the mirror (--chaos-study).
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let scenarios = chaos_scenarios();
    let (name, drop_spec) = &scenarios[2];
    assert_eq!(*name, "dropout");

    let clean_static = run_chaos_cell(&tables, &block, Strategy::Sequential,
                                      0, ReplacePolicy::Never,
                                      &ChaosSpec::clean(0));
    assert_eq!(clean_static.total, 0.07555310486666666);
    assert_eq!(clean_static.migrations, 0);
    let clean_be = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                                  ReplacePolicy::BreakEven,
                                  &ChaosSpec::clean(0));
    assert_eq!(clean_be.total, 0.06617359183368421);
    assert_eq!(clean_be.migrations, 1);

    let stat = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                              ReplacePolicy::Never, drop_spec);
    assert_eq!(stat.total, 0.08656656125263158);
    assert_eq!(stat.migrations, 1, "the forced failover itself");
    assert!(stat.steps[CHAOS_DROP_STEP].migrated);
    let (med, p99, amp) = tail_stats(&stat);
    assert_eq!(med, 0.005365674582456141);
    // p99 is the recovery step: one 128 MiB expert over the 16 GB/s H2D
    // link, 10us alpha -> 0.008398608 s exactly
    assert_eq!(p99, 0.008398608);
    assert!(amp > 1.5, "dropout amplifies the tail: {amp}");

    let be = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                            ReplacePolicy::BreakEven, drop_spec);
    assert_eq!(be.total, 0.07914883020631579);
    assert_eq!(be.migrations, 2, "warmup re-pack + forced failover");
    assert!(be.total < stat.total,
            "break-even failover {} must beat static {}", be.total,
            stat.total);
}

#[test]
fn chaos_study_straggler_and_uplink_rows_are_pinned() {
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let affinity = Placement::affinity_packed(&tables[0], 32, 8);
    let scenarios = chaos_scenarios();
    let (sname, stragglers) = &scenarios[0];
    let (fname, flaky) = &scenarios[1];
    assert_eq!((*sname, *fname), ("stragglers", "flaky-uplink"));

    // stragglers: jitter + two slow devices stretch every step's barrier
    let s = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                           ReplacePolicy::Never, stragglers);
    assert_eq!(s.total, 0.13774594081477698);
    assert_eq!(s.migrations, 0);
    let (med, p99, _) = tail_stats(&s);
    assert_eq!(med, 0.008663534732679569);
    assert_eq!(p99, 0.008972875329324056);

    // flaky uplink: the block layout pays on every degraded step, while
    // the affinity placement's node-local routes never touch the faulted
    // uplink — its overlap-s2 total equals the clean run's
    let f = run_chaos_cell(&tables, &block, Strategy::Sequential, 0,
                           ReplacePolicy::Never, flaky);
    assert_eq!(f.total, 0.13553053665263157);
    let (med, p99, _) = tail_stats(&f);
    assert_eq!(med, 0.012102932305263159);
    assert_eq!(p99, 0.012381844266666667);
    let fa = run_chaos_cell(&tables, &affinity, Strategy::Sequential, 0,
                            ReplacePolicy::Never, flaky);
    assert_eq!(fa.total, 0.06423326860701754);
    let fo = run_chaos_cell(&tables, &affinity, Strategy::Overlap, 2,
                            ReplacePolicy::Never, flaky);
    assert_eq!(fo.total, 0.05842532894736842);
    let co = run_chaos_cell(&tables, &affinity, Strategy::Overlap, 2,
                            ReplacePolicy::Never, &ChaosSpec::clean(0));
    assert_eq!(fo.total, co.total,
               "node-local routes are immune to the uplink fault");
}

#[test]
fn chaos_study_c2r_headline_is_pinned() {
    // C2R's bounded fanout wins under chaos despite a pinned clean-path
    // cost: constrained routing is +22% slower on a healthy fleet, but a
    // persistent uplink fault (alpha x8, beta /16) cannot touch it at
    // all — its degraded run is bit-identical to its clean run — while
    // unconstrained node-affine routing at the same noise degrades 1.65x
    assert!(C2R_NOISE > 0.0, "the head-to-head needs real deviation");
    let fault = c2r_uplink_fault();
    let run = |tables: &[RoutingTable], chaos: &ChaosSpec| {
        let init = Placement::affinity_packed(&tables[0], 32, 8);
        run_chaos_cell(tables, &init, Strategy::Sequential, 0,
                       ReplacePolicy::Never, chaos)
    };
    let affine = c2r_study_tables(false);
    let a_clean = run(&affine, &ChaosSpec::clean(0));
    let a_deg = run(&affine, &fault);
    assert_eq!(a_clean.total, 0.06148941163578947);
    assert_eq!(a_deg.total, 0.10137539014385967);

    let c2r = c2r_study_tables(true);
    let c_clean = run(&c2r, &ChaosSpec::clean(0));
    let c_deg = run(&c2r, &fault);
    assert_eq!(c_clean.total, 0.07533669939789474);
    assert_eq!(c_deg.total, c_clean.total,
               "zero uplink exposure: degraded == clean, bit-exactly");
    assert!(c_clean.total > a_clean.total,
            "the constraint costs on the clean path");
    assert!(c_deg.total < a_deg.total,
            "and wins once the uplink degrades: {} vs {}", c_deg.total,
            a_deg.total);
}

#[test]
fn chaos_cells_are_deterministic() {
    // the full study cell is a pure function of its inputs: re-running
    // any jittered cell reproduces byte-identical step reports
    let tables = dyadic_tables(4, 300);
    let chaos = ChaosSpec {
        jitter: 0.2,
        stragglers: vec![(3, 1.5)],
        link_faults: vec![LinkFault {
            node: None,
            alpha_mult: 2.0,
            beta_div: 2.0,
            flap: Some((2, 1)),
        }],
        dropout: Some(Dropout { device: 1, at_step: 2 }),
        ..ChaosSpec::clean(77)
    };
    let cfg = dyadic_cfg(ReplacePolicy::BreakEven);
    let run = || {
        run_chaos_timeline(&dyadic_base(), &dyadic_topo(), 64, &tables,
                           &Placement::new(4, 4), &cfg, &chaos)
    };
    let (a, b) = (run(), run());
    assert_eq!(a.total, b.total);
    assert_eq!(a.migrations, b.migrations);
    for (x, y) in a.steps.iter().zip(&b.steps) {
        assert_eq!(x.makespan, y.makespan);
    }
}
