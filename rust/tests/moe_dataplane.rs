//! Round-trip tests for the MoE data plane: `decode(encode(x))` identity
//! under no-drop capacity, and exact dropped-token accounting when
//! capacity binds in `moe::dispatch` / `moe::router`.

use scmoe::moe::{decode, encode, RoutingTable};
use scmoe::util::propcheck::{check, gen};
use scmoe::util::rng::Rng;

// ---------------------------------------------------------------------------
// decode ∘ encode identity under no-drop capacity
// ---------------------------------------------------------------------------

#[test]
fn roundtrip_identity_k1_unit_weights_is_bitwise() {
    // k = 1, weight 1.0, ample capacity: decode(encode(x)) must return x
    // exactly (the copies are unscaled f32 moves, not arithmetic).
    let (t, e, d) = (16usize, 4usize, 8usize);
    let idx: Vec<i32> = (0..t).map(|i| (i % e) as i32).collect();
    let w = vec![1.0f32; t];
    let table = RoutingTable::build(&idx, &w, t, 1, e, t);
    let tokens: Vec<f32> = (0..t * d).map(|i| (i as f32).sin()).collect();
    let enc = encode(&table, &tokens, d);
    let dec = decode(&table, &enc, d);
    assert_eq!(dec, tokens, "k=1 unit-weight roundtrip must be bit-exact");
    assert_eq!(table.dropped, 0);
}

#[test]
fn prop_roundtrip_identity_under_no_drop() {
    // Random top-k routing with per-token weights summing to 1 and ample
    // capacity: identity experts make decode(encode(x)) recover x.
    check("dataplane-roundtrip", 150, |r| gen::routing(r), |input| {
        let (idx, w, t, k, e) = input;
        let d = 6usize;
        let table = RoutingTable::build(idx, w, *t, *k, *e, t * k);
        if table.dropped != 0 {
            return Err("ample capacity must never drop".into());
        }
        let mut rng = Rng::new(0xDA7A);
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.next_f32() - 0.5).collect();
        let enc = encode(&table, &tokens, d);
        let dec = decode(&table, &enc, d);
        for (i, (a, b)) in dec.iter().zip(&tokens).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!("elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

#[test]
fn encode_buffer_layout_and_padding() {
    // unused capacity slots stay zero after encode
    let idx = vec![0, 1];
    let w = vec![1.0f32, 1.0];
    let table = RoutingTable::build(&idx, &w, 2, 1, 2, 3);
    let tokens = vec![1.0f32, 2.0, 3.0, 4.0];
    let enc = encode(&table, &tokens, 2);
    assert_eq!(enc.len(), 2 * 3 * 2);
    assert_eq!(&enc[0..2], &[1.0, 2.0]); // expert0 slot0 = token0
    assert_eq!(&enc[2..6], &[0.0; 4]);   // expert0 slots 1..3 padded
    assert_eq!(&enc[6..8], &[3.0, 4.0]); // expert1 slot0 = token1
    assert_eq!(&enc[8..12], &[0.0; 4]);
}

// ---------------------------------------------------------------------------
// Exact dropped-token accounting when capacity binds
// ---------------------------------------------------------------------------

#[test]
fn fcfs_drop_accounting_is_exact() {
    // 6 tokens all routed to expert 0 with capacity 2: tokens 0 and 1 keep
    // their slots (FCFS), tokens 2..6 drop.
    let t = 6usize;
    let idx = vec![0i32; t];
    let w = vec![1.0f32; t];
    let table = RoutingTable::build(&idx, &w, t, 1, 2, 2);
    assert_eq!(table.kept(), 2);
    assert_eq!(table.dropped, 4);
    assert_eq!(table.demand, vec![6, 0]);
    assert_eq!(table.load, vec![2, 0]);

    let d = 3usize;
    let tokens: Vec<f32> = (0..t * d).map(|i| i as f32 + 1.0).collect();
    let enc = encode(&table, &tokens, d);
    let dec = decode(&table, &enc, d);
    // kept tokens round-trip exactly; dropped tokens decode to exact zeros
    assert_eq!(&dec[0..2 * d], &tokens[0..2 * d]);
    assert_eq!(&dec[2 * d..], &vec![0.0f32; 4 * d][..]);
}

#[test]
fn partial_drop_keeps_surviving_route_weights() {
    // token0 -> (e0 w=0.5, e1 w=0.5); token1 -> (e0 w=0.3, e2 w=0.7).
    // Capacity 1: token1's e0 route drops behind token0 (FCFS); its e2
    // route survives, so token1 decodes to exactly the surviving 0.7 * x.
    let idx = vec![0, 1, 0, 2];
    let w = vec![0.5f32, 0.5, 0.3, 0.7];
    let table = RoutingTable::build(&idx, &w, 2, 2, 3, 1);
    assert_eq!(table.kept(), 3);
    assert_eq!(table.dropped, 1);
    assert_eq!(table.load, vec![1, 1, 1]);

    let d = 2usize;
    let tokens = vec![10.0f32, 20.0, 30.0, 40.0];
    let enc = encode(&table, &tokens, d);
    let dec = decode(&table, &enc, d);
    // token0 keeps both routes: 0.5*x + 0.5*x = x (within f32 rounding)
    assert!((dec[0] - 10.0).abs() < 1e-4 && (dec[1] - 20.0).abs() < 1e-4);
    // token1 keeps only the 0.7 route: first-write path stores 0.7*x exactly
    assert_eq!(&dec[2..4], &[0.7 * 30.0, 0.7 * 40.0]);
}

#[test]
fn prop_drop_accounting_under_tight_capacity() {
    // With any capacity, FCFS guarantees load[e] == min(demand[e], cap),
    // kept == sum(load), dropped == demand - kept, and slots stay unique.
    check("dataplane-drop-accounting", 150, |r| {
        let (idx, w, t, k, e) = gen::routing(r);
        let cap = 1 + r.below(3); // deliberately binding
        (idx, w, t, k, e, cap)
    }, |input| {
        let (idx, w, t, k, e, cap) = input;
        let table = RoutingTable::build(idx, w, *t, *k, *e, *cap);
        for (ex, (&demand, &load)) in
            table.demand.iter().zip(&table.load).enumerate()
        {
            if load != demand.min(*cap) {
                return Err(format!(
                    "expert {ex}: load {load} != min(demand {demand}, cap {cap})"
                ));
            }
        }
        let kept: usize = table.load.iter().sum();
        if table.kept() != kept {
            return Err("kept() disagrees with load histogram".into());
        }
        if table.kept() + table.dropped != t * k {
            return Err(format!(
                "kept {} + dropped {} != demand {}",
                table.kept(), table.dropped, t * k
            ));
        }
        let mut seen = std::collections::HashSet::new();
        for route in &table.routes {
            if route.slot >= *cap {
                return Err(format!("slot {} beyond capacity {cap}", route.slot));
            }
            if !seen.insert((route.expert, route.slot)) {
                return Err("slot collision".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_dropped_tokens_decode_to_exact_zeros() {
    check("dataplane-dropped-zeros", 100, |r| {
        let (idx, w, t, k, e) = gen::routing(r);
        let cap = 1 + r.below(2);
        (idx, w, t, k, e, cap)
    }, |input| {
        let (idx, w, t, k, e, cap) = input;
        let d = 4usize;
        let table = RoutingTable::build(idx, w, *t, *k, *e, *cap);
        let mut rng = Rng::new(7);
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.next_f32() + 1.0).collect();
        let enc = encode(&table, &tokens, d);
        let dec = decode(&table, &enc, d);
        let mut has_route = vec![false; *t];
        for route in &table.routes {
            has_route[route.token] = true;
        }
        for (tok, &alive) in has_route.iter().enumerate() {
            let row = &dec[tok * d..(tok + 1) * d];
            if !alive && row.iter().any(|&v| v != 0.0) {
                return Err(format!("dropped token {tok} decoded non-zero {row:?}"));
            }
            if alive && row.iter().all(|&v| v == 0.0) {
                return Err(format!("routed token {tok} decoded to zeros"));
            }
        }
        Ok(())
    });
}

#[test]
fn a2a_bytes_conserved_under_drops() {
    // the byte matrix counts exactly the kept routes
    let idx = vec![0i32; 8];
    let w = vec![1.0f32; 8];
    let table = RoutingTable::build(&idx, &w, 8, 1, 4, 3);
    assert_eq!(table.kept(), 3);
    let m = table.a2a_bytes(4, 100);
    let total: usize = m.iter().sum();
    assert_eq!(total, 3 * 100);
}
