//! Integration: expert offloading driven by *real* gate selections from the
//! AOT infer_step artifact (not synthetic routing), plus paper-claim bands
//! for Fig. 10.

use std::path::Path;
use std::sync::Arc;

use scmoe::offload::{simulate_decode, Policy};
use scmoe::report::offload_report::{gpt2_moe_medium, gpt3_moe_xl};
use scmoe::runtime::{Engine, HostTensor};

#[test]
fn fig10_paper_bands() {
    for (name, cfg, mem_lo, mem_hi, blk_lo, blk_hi) in [
        ("medium", gpt2_moe_medium(), 0.40, 0.65, 0.5, 1.2),
        ("xl", gpt3_moe_xl(), 0.40, 0.70, 1.8, 3.0),
    ] {
        let gpu = simulate_decode(&cfg, None, 48, Policy::GpuOnly, 7);
        let blk = simulate_decode(&cfg, None, 48, Policy::Blocking, 7);
        let asy = simulate_decode(&cfg, None, 48, Policy::AsyncDeterminate, 7);

        let mem_cut = 1.0 - blk.peak_gpu_bytes as f64 / gpu.peak_gpu_bytes as f64;
        assert!((mem_lo..mem_hi).contains(&mem_cut),
                "{name}: memory cut {mem_cut}");

        let added_blocking = blk.block_latency / gpu.block_latency - 1.0;
        assert!((blk_lo..blk_hi).contains(&added_blocking),
                "{name}: blocking added {added_blocking}");

        // async strictly reduces the added overhead and hides part of the
        // migration (the determinate-early-issue property)
        assert!(asy.block_latency < blk.block_latency, "{name}: async wins");
        assert!(asy.exposed_migration < blk.exposed_migration);
        // async never changes which experts run: peak identical
        assert_eq!(asy.peak_gpu_bytes, blk.peak_gpu_bytes);
    }
}

#[test]
fn real_gate_selections_drive_offload() {
    let dir = Path::new(concat!(env!("CARGO_MANIFEST_DIR"),
                                "/artifacts/quality_scmoe_micro"));
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Arc::new(Engine::cpu().unwrap());
    let set = engine.open(dir).unwrap();
    let cfg = &set.manifest.config;

    // init params, run infer_step, extract per-layer expert selections
    let init = set.get("init").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(3)]).unwrap();
    let infer = set.get("infer_step").unwrap();
    let tokens = HostTensor::i32(
        vec![cfg.batch_size, cfg.seq_len],
        (0..cfg.batch_size * cfg.seq_len).map(|i| (i % 251) as i32).collect());
    let mut inputs = params;
    inputs.push(tokens);
    let out = infer.run(&inputs).unwrap();
    let sel = &out[1]; // [n_moe, T, k]
    assert_eq!(sel.shape.len(), 3);
    let (n_moe, t, k) = (sel.shape[0], sel.shape[1], sel.shape[2]);
    let sel_i = sel.as_i32().unwrap();

    // reshape into per-token selections (tokens become decode steps)
    let take = t.min(16);
    let mut selections = Vec::new();
    for tok in 0..take {
        let mut per_layer = Vec::new();
        for l in 0..n_moe {
            let mut experts = Vec::new();
            for kk in 0..k {
                let e = sel_i[(l * t + tok) * k + kk];
                assert!((0..cfg.n_experts as i32).contains(&e),
                        "selection out of range: {e}");
                experts.push(e as usize);
            }
            per_layer.push(experts);
        }
        selections.push(per_layer);
    }

    let mut ocfg = gpt2_moe_medium();
    ocfg.n_moe_layers = n_moe;
    ocfg.n_experts = cfg.n_experts;
    ocfg.k = k;
    let blk = simulate_decode(&ocfg, Some(&selections), take, Policy::Blocking, 1);
    let asy = simulate_decode(&ocfg, Some(&selections), take,
                              Policy::AsyncDeterminate, 1);
    assert!(asy.block_latency <= blk.block_latency);
    assert!(asy.exposed_migration <= blk.exposed_migration);
}
