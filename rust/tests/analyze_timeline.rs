//! Property + golden suite for the `analyze` layer, run over the *same*
//! corpus of simulations the golden-timeline snapshot pins
//! (`tests/common/generators.rs`), so every property is checked on every
//! schedule shape the repo can produce: pair, fleet, routed, replace
//! (H2D), serve, chaos, and whole-model pipelines.
//!
//! The analyze golden lines (`golden/analyze.txt`) and the Chrome-trace
//! golden (`golden/trace_fleet.json`) are minted by
//! `tools/des_mirror/mirror2.py --emit`, which re-derives critical path,
//! slack, attribution, and overlap from its independent Python DES.

#[path = "common/generators.rs"]
mod generators;

use std::collections::BTreeSet;

use generators::golden_sims;
use scmoe::analyze::{attribute, chrome_trace, comm_overlap, critical_path,
                     makespan_with_zeroed, slack, utilization};
use scmoe::cluster::Scenario;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::report::efficiency::xl_topo_proxy_costs;
use scmoe::simtime::{makespan, Resource, Sim};

const GOLDEN_ANALYZE: &str = include_str!("golden/analyze.txt");
const GOLDEN_TRACE: &str = include_str!("golden/trace_fleet.json");

/// Corpus devices-per-node: every multi-device corpus sim (fleet,
/// routed, replace, serve, chaos, model) models 2 devices per node.
const CORPUS_DPN: usize = 2;

fn analyze_line(name: &str, sim: &Sim) -> String {
    let run = sim.run_traced();
    let path = critical_path(&run);
    let path_len: f64 = path
        .iter()
        .map(|&i| run.spans[i].end - run.spans[i].start)
        .sum();
    let a = attribute(&run);
    let ov = comm_overlap(&run.spans, CORPUS_DPN);
    format!(
        "{name} | crit {} {path_len:.6} | attr {:.6} {:.6} {:.6} {:.6} \
         {:.6} {:.6} | comm {:.6} {:.6}",
        path.len(), a.backbone, a.expert, a.dispatch, a.combine,
        a.migration, a.idle, ov.total, ov.hidden
    )
}

#[test]
fn traced_run_spans_equal_plain_run_on_every_generator() {
    for (name, sim) in golden_sims() {
        let plain = sim.run();
        let traced = sim.run_traced();
        assert_eq!(plain.len(), traced.spans.len(), "{name}");
        for (p, t) in plain.iter().zip(&traced.spans) {
            assert_eq!(p.id, t.id, "{name}");
            assert_eq!(p.label, t.label, "{name}");
            assert_eq!(p.resource, t.resource, "{name}");
            assert_eq!(p.start.to_bits(), t.start.to_bits(), "{name}");
            assert_eq!(p.end.to_bits(), t.end.to_bits(), "{name}");
        }
    }
}

#[test]
fn critical_path_length_equals_makespan_on_every_generator() {
    for (name, sim) in golden_sims() {
        let run = sim.run_traced();
        let ms = makespan(&run.spans);
        let path = critical_path(&run);
        let len: f64 = path
            .iter()
            .map(|&i| run.spans[i].end - run.spans[i].start)
            .sum();
        assert!((len - ms).abs() < 1e-9,
                "{name}: critical path {len} != makespan {ms}");
        // the blocking chain is time-contiguous: each hop starts exactly
        // where its predecessor finished
        for w in path.windows(2) {
            assert_eq!(run.spans[w[0]].end.to_bits(),
                       run.spans[w[1]].start.to_bits(), "{name}");
        }
    }
}

#[test]
fn attribution_partitions_makespan_exactly() {
    for (name, sim) in golden_sims() {
        let run = sim.run_traced();
        let a = attribute(&run);
        assert!((a.categorized() + a.idle - a.makespan).abs() < 1e-12,
                "{name}");
        assert!(a.idle.abs() < 1e-9,
                "{name}: work-conserving engine must leave no idle on the \
                 critical path, got {}", a.idle);
    }
}

#[test]
fn hidden_plus_exposed_equals_total_comm() {
    for (name, sim) in golden_sims() {
        let ov = comm_overlap(&sim.run(), CORPUS_DPN);
        assert!(ov.hidden >= 0.0 && ov.hidden <= ov.total + 1e-12, "{name}");
        assert!((ov.hidden + ov.exposed() - ov.total).abs() < 1e-12,
                "{name}");
        let f = ov.hidden_fraction();
        assert!((0.0..=1.0 + 1e-12).contains(&f), "{name}: {f}");
    }
}

/// Zeroing any positive-slack task's duration never changes the
/// makespan, holding the realized execution order fixed (the order slack
/// is defined over — see `makespan_with_zeroed`: naively *re-running*
/// the engine instead hits a genuine list-scheduling anomaly on the
/// `Top1/pipe2` corpus timeline). The `None` replay doubles as a
/// soundness check that the realized edge set reproduces the makespan
/// bit-exactly.
#[test]
fn zeroing_a_positive_slack_task_never_changes_makespan() {
    for (name, sim) in golden_sims() {
        let run = sim.run_traced();
        let ms = makespan(&run.spans);
        assert_eq!(makespan_with_zeroed(&sim, &run, None).to_bits(),
                   ms.to_bits(), "{name}: replay must be exact");
        let slacks = slack(&sim, &run);
        for (i, sl) in slacks.iter().enumerate() {
            if *sl <= 1e-9 || sim.tasks()[i].duration == 0.0 {
                continue;
            }
            let ms2 = makespan_with_zeroed(&sim, &run, Some(i));
            assert!((ms2 - ms).abs() < 1e-9,
                    "{name}: zeroing slack-{sl} task {i} ({}) moved the \
                     makespan {ms} -> {ms2}", sim.tasks()[i].label);
        }
    }
}

#[test]
fn utilization_in_unit_interval_on_all_presets() {
    let ovl = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
    for sc in Scenario::extended() {
        let tc = xl_topo_proxy_costs(sc);
        let (slot, _) = ovl.choose_slot(&tc);
        let spans = ovl.with_slot(slot).build(&tc).run();
        for u in utilization(&spans) {
            assert!(u.utilization >= 0.0 && u.utilization <= 1.0 + 1e-12,
                    "{}: {:?} utilization {}", sc.label(), u.resource,
                    u.utilization);
            assert!(!matches!(u.resource, Resource::Free));
        }
    }
}

#[test]
fn adaptive_overlap_hides_more_comm_than_sequential_on_4node_ib() {
    let tc = xl_topo_proxy_costs(Scenario::FourNodeA800IBx32);
    let dpn = tc.n_devices() / tc.n_nodes();
    let seq = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                Strategy::Sequential)
        .build(&tc)
        .run();
    let ovl = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
    let (slot, _) = ovl.choose_slot(&tc);
    let adaptive = ovl.with_slot(slot).build(&tc).run();
    let h_seq = comm_overlap(&seq, dpn).hidden_fraction();
    let h_adp = comm_overlap(&adaptive, dpn).hidden_fraction();
    assert!(h_adp > h_seq,
            "adaptive overlap must hide strictly more comm: {h_adp} vs \
             {h_seq}");
}

#[test]
fn analyze_lines_match_golden_snapshots() {
    let golden: Vec<&str> = GOLDEN_ANALYZE
        .lines()
        .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
        .collect();
    let current: Vec<String> = golden_sims()
        .iter()
        .map(|(name, sim)| analyze_line(name, sim))
        .collect();
    assert_eq!(
        golden.len(),
        current.len(),
        "golden/analyze.txt has {} lines, current build produces {} — \
         regenerate via mirror2.py --emit deliberately",
        golden.len(),
        current.len()
    );
    let mut diffs = Vec::new();
    for (g, c) in golden.iter().zip(&current) {
        if g != c {
            diffs.push(format!("- {g}\n+ {c}"));
        }
    }
    assert!(diffs.is_empty(),
            "{} analyze line(s) drifted:\n{}", diffs.len(), diffs.join("\n"));
}

#[test]
fn chrome_trace_matches_golden_fleet_trace() {
    let (name, sim) = golden_sims()
        .into_iter()
        .find(|(n, _)| n == "fleet:ScMoE/overlap-s2")
        .expect("fleet corpus entry");
    let run = sim.run_traced();
    let trace = chrome_trace(&sim, &run, CORPUS_DPN);
    assert_eq!(trace.as_str(), GOLDEN_TRACE.trim_end_matches('\n'),
               "{name}: Chrome trace drifted from golden/trace_fleet.json");
}

#[test]
fn critical_spans_marked_in_rendered_timeline() {
    let (_, sim) = golden_sims()
        .into_iter()
        .find(|(n, _)| n == "fleet:ScMoE/overlap-s2")
        .unwrap();
    let run = sim.run_traced();
    let crit: BTreeSet<usize> = critical_path(&run).into_iter().collect();
    let marked =
        scmoe::coordinator::timeline::render_marked(&run.spans, 100, &crit);
    assert!(marked.contains('#'));
    assert_eq!(scmoe::coordinator::timeline::render_marked(
                   &run.spans, 100, &BTreeSet::new()),
               scmoe::coordinator::timeline::render(&run.spans, 100));
}
