//! Golden-timeline regression: every MoEKind × Strategy combination in
//! `coordinator::schedule` is pinned as a span-order + makespan snapshot
//! (rust/tests/golden/timelines.txt), so schedule refactors cannot
//! silently reorder the Fig. 6 timelines.
//!
//! Costs are dyadic rationals (exact in binary floating point), so every
//! start/makespan formats exactly at six decimals and comparisons are
//! deterministic across platforms.
//!
//! The simulations themselves live in `tests/common/generators.rs`,
//! shared with the analysis-layer property suite (`analyze_timeline.rs`)
//! so both run over the identical corpus.

#[path = "common/generators.rs"]
mod generators;

use generators::{golden_sims, render_spans};

const GOLDEN: &str = include_str!("golden/timelines.txt");

fn generate_lines() -> Vec<String> {
    golden_sims()
        .into_iter()
        .map(|(name, sim)| render_spans(&name, sim.run()))
        .collect()
}

#[test]
fn timelines_match_golden_snapshots() {
    let golden: Vec<&str> = GOLDEN
        .lines()
        .filter(|l| !l.starts_with('#') && !l.trim().is_empty())
        .collect();
    let current = generate_lines();
    assert_eq!(
        golden.len(),
        current.len(),
        "golden has {} lines, current build produces {} — regenerate \
         rust/tests/golden/timelines.txt deliberately if the config set changed",
        golden.len(),
        current.len()
    );
    let mut diffs = Vec::new();
    for (g, c) in golden.iter().zip(&current) {
        if g != c {
            diffs.push(format!("- {g}\n+ {c}"));
        }
    }
    assert!(
        diffs.is_empty(),
        "{} timeline(s) drifted from the golden snapshots:\n{}",
        diffs.len(),
        diffs.join("\n")
    );
}

#[test]
fn golden_file_covers_every_kind_and_strategy() {
    // meta-test: the snapshot corpus really spans the full matrix
    for needle in [
        "Top1/", "Top2/", "Top3/", "Top1+SE1/", "ScMoE/", "ScMoE-2/",
        "/seq", "/pipe1", "/pipe2", "/pipe4", "/overlap-s0", "/overlap-s3",
        "/overlap+pipe2-s0", "fleet:", "fleet:Top2/pipe2-chained",
        "fleet:ScMoE/overlap+pipe2-s2", "routed:block/", "routed:affinity/",
        "routed:skewed/", "routed:skewed/overlap+pipe2-s2",
        "routed:skewed/pipe2", "replace:block->affinity/seq",
        "replace:block->affinity/overlap-s2", "replace:block->affinity/pipe2",
        "serve:wait1/step0", "serve:wait1/step2", "serve:mixed/seq",
        "chaos:straggler/seq", "chaos:degraded-uplink/overlap-s2",
        "chaos:dropout-recovery/seq", "model:L1/seq-m1", "model:L2/seq-m1",
        "model:L2/gpipe-m2", "model:L2/1f1b-m2", "model:L2S2/gpipe-m2",
        "model:L2S2/layerseq-m2", "model:d2h-migration/seq",
    ] {
        assert!(GOLDEN.contains(needle), "golden corpus is missing {needle}");
    }
}
