//! DES scheduling-engine throughput: tasks scheduled per second on graphs
//! shaped like real multi-pair model schedules.

mod common;

use common::Bench;
use scmoe::simtime::{Resource, Sim};

fn build_chain_graph(pairs: usize, chunks: usize) -> Sim {
    let mut sim = Sim::new();
    let mut prev = None;
    for p in 0..pairs {
        let deps: Vec<_> = prev.into_iter().collect();
        let attn = sim.add(format!("attn{p}"), Resource::Compute(0), 1.0, &deps);
        let gate = sim.add("gate", Resource::Compute(0), 0.1, &[attn]);
        let mut tail = attn;
        for c in 0..chunks {
            let d = sim.add(format!("d{c}"), Resource::Comm(0), 0.5, &[gate]);
            let e = sim.add(format!("e{c}"), Resource::Compute(0), 0.5, &[d, tail]);
            let _ = sim.add(format!("c{c}"), Resource::Comm(0), 0.5, &[e]);
            tail = e;
        }
        let out = sim.add("decode", Resource::Compute(0), 0.1, &[tail]);
        prev = Some(out);
    }
    sim
}

/// Multi-device graph shaped like the topology-aware pair schedules: per
/// device compute/comm streams, per-node shared links, A2A barriers.
fn build_fleet_graph(pairs: usize, devices: usize, per_node: usize) -> Sim {
    let mut sim = Sim::new();
    let nodes = devices / per_node;
    let mut prev: Vec<Option<usize>> = vec![None; devices];
    for _ in 0..pairs {
        let mut enc = Vec::with_capacity(devices);
        for d in 0..devices {
            let deps: Vec<_> = prev[d].into_iter().collect();
            let attn = sim.add("attn", Resource::Compute(d), 1.0, &deps);
            enc.push(sim.add("enc", Resource::Compute(d), 0.1, &[attn]));
        }
        let mut disp = Vec::with_capacity(devices + nodes);
        for d in 0..devices {
            disp.push(sim.add("a2a", Resource::Comm(d), 0.4, &[enc[d]]));
        }
        // single-node topologies have no inter-node phase (matches the
        // real builders, which emit Link tasks only when a2a_inter exists)
        if nodes >= 2 {
            for n in 0..nodes {
                let deps: Vec<_> =
                    (n * per_node..(n + 1) * per_node).map(|d| enc[d]).collect();
                disp.push(sim.add("a2a-x", Resource::Link(n), 0.6, &deps));
            }
        }
        for d in 0..devices {
            let e = sim.add("expert", Resource::Compute(d), 0.5, &disp);
            prev[d] = Some(sim.add("dec", Resource::Compute(d), 0.1, &[e]));
        }
    }
    sim
}

fn main() {
    let b = Bench::new("des_engine");
    for (pairs, chunks) in [(12usize, 2usize), (48, 4), (96, 8)] {
        let sim = build_chain_graph(pairs, chunks);
        let n = sim.len();
        let t = b.measure(&format!("{n} tasks ({pairs} pairs x {chunks} chunks)"),
                          100, 5, || {
            std::hint::black_box(sim.run());
        });
        println!("  -> {:.2} M tasks/s", n as f64 / t / 1e6);
    }
    for (pairs, devices, per_node) in [(12usize, 8usize, 8usize), (12, 16, 8), (12, 32, 8)] {
        let sim = build_fleet_graph(pairs, devices, per_node);
        let n = sim.len();
        let t = b.measure(
            &format!("{n} tasks (fleet: {pairs} pairs x {devices} dev / {per_node} per node)"),
            50, 5, || {
                std::hint::black_box(sim.run());
            });
        println!("  -> {:.2} M tasks/s", n as f64 / t / 1e6);
    }
}
