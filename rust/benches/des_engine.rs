//! DES scheduling-engine throughput and warm-start speedup.
//!
//! All workloads come from `tests/common/generators.rs` — the same
//! generator corpus the differential harness (`engine_equivalence.rs`)
//! and the warm-start suite (`warm_start.rs`) lock down, so every graph
//! timed here is one whose fast-engine results are proven bit-identical
//! to the reference engine.
//!
//! The headline comparison reproduces the two hot evaluation loops of
//! the studies at fleet scale (32 nodes × 4 devices/node), re-pricing
//! the same schedule shapes under 8 alternating cost models:
//!
//! - `reference-cold` — the pre-PR status quo: cold `spec.build` per
//!   evaluation plus the retained reference engine
//!   (`Sim::run_traced_reference`);
//! - `fast-cold` — cold build, per-resource ready-queue engine;
//! - `fast-warm` — `SimArena` warm start: skeleton re-priced in place,
//!   cached dependents index, shared run buffers.
//!
//! The `>= 10x` warm-vs-status-quo target (ROADMAP "DES raw speed") is
//! pinned as an assertion on both headline workloads.

mod common;

#[path = "../tests/common/generators.rs"]
mod generators;

use common::Bench;
use generators::{fleet_costs_scaled, fleet_sweep_specs, golden_sims,
                 random_dag_sims};
use scmoe::coordinator::costs::{MoEKind, Strategy, TopoCosts};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::simtime::{makespan, SimArena};

const NODES: usize = 32;
const PER_NODE: usize = 4;
/// Dyadic duration scales the cost models alternate through — every
/// rebuild re-prices the identical skeleton under a different model.
const SCALES: [f64; 8] = [1.0, 1.25, 1.5, 0.75, 2.0, 0.5, 1.75, 0.875];

fn scaled_models(nodes: usize, per_node: usize) -> Vec<TopoCosts> {
    SCALES
        .iter()
        .map(|&s| fleet_costs_scaled(nodes, per_node, s))
        .collect()
}

/// Time the three evaluation paths over `specs` × 8 cost models and
/// return (reference_cold, fast_cold, fast_warm) seconds per sweep.
fn compare(b: &Bench, label: &str, specs: &[ScheduleSpec],
           models: &[TopoCosts], iters: usize) -> (f64, f64, f64) {
    let t_ref = b.measure(&format!("{label}/reference-cold"), iters, 5, || {
        for spec in specs {
            for tc in models {
                let sched = spec.build(tc);
                let traced = sched.sim.run_traced_reference();
                std::hint::black_box(makespan(&traced.spans));
            }
        }
    });
    let t_cold = b.measure(&format!("{label}/fast-cold"), iters, 5, || {
        for spec in specs {
            for tc in models {
                std::hint::black_box(spec.build(tc).makespan());
            }
        }
    });
    let mut arena = SimArena::new();
    let t_warm = b.measure(&format!("{label}/fast-warm"), iters, 5, || {
        for spec in specs {
            for tc in models {
                spec.build_into(tc, &mut arena);
                std::hint::black_box(arena.makespan());
            }
        }
    });
    println!("  -> warm vs reference-cold: {:.1}x   warm vs fast-cold: {:.1}x",
             t_ref / t_warm, t_cold / t_warm);
    (t_ref, t_cold, t_warm)
}

fn main() {
    let b = Bench::new("des_engine");

    // raw fast-engine throughput over the locked-down corpora
    let corpus = golden_sims();
    let corpus_tasks: usize = corpus.iter().map(|(_, s)| s.len()).sum();
    let t = b.measure(&format!("golden corpus ({corpus_tasks} tasks)"),
                      20, 5, || {
        for (_, sim) in &corpus {
            std::hint::black_box(sim.makespan());
        }
    });
    println!("  -> {:.2} M tasks/s", corpus_tasks as f64 / t / 1e6);

    let dags = random_dag_sims(50, 42);
    let dag_tasks: usize = dags.iter().map(|(_, s)| s.len()).sum();
    let t = b.measure(&format!("random DAGs ({dag_tasks} tasks)"), 20, 5, || {
        for (_, sim) in &dags {
            std::hint::black_box(sim.makespan());
        }
    });
    println!("  -> {:.2} M tasks/s", dag_tasks as f64 / t / 1e6);

    let sweep = generators::fleet_sweep_sims(NODES, PER_NODE);
    let sweep_tasks: usize = sweep.iter().map(|(_, s)| s.len()).sum();
    let t = b.measure(
        &format!("fleet sweep ({sweep_tasks} tasks, {NODES}x{PER_NODE})"),
        10, 5, || {
            for (_, sim) in &sweep {
                std::hint::black_box(sim.makespan());
            }
        });
    println!("  -> {:.2} M tasks/s", sweep_tasks as f64 / t / 1e6);

    // headline: the replace-timeline step loop — one schedule shape
    // re-priced per step under drifting costs (what every policy step
    // and break-even probe does)
    let models = scaled_models(NODES, PER_NODE);
    let replace_specs = [ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                           Strategy::Sequential)];
    let (t_ref, _, t_warm) =
        compare(&b, "replace-step", &replace_specs, &models, 10);
    let replace_speedup = t_ref / t_warm;

    // headline: the chunk-sweep loop — the full strategy sweep re-priced
    // under alternating models (what the chunk-count studies evaluate)
    let sweep_specs: Vec<ScheduleSpec> =
        fleet_sweep_specs().into_iter().map(|(_, s)| s).collect();
    let (t_ref, _, t_warm) =
        compare(&b, "chunk-sweep", &sweep_specs, &models, 3);
    let sweep_speedup = t_ref / t_warm;

    println!("\nwarm-start speedup vs pre-PR status quo: replace-step \
              {replace_speedup:.1}x, chunk-sweep {sweep_speedup:.1}x \
              (target >= 10x)");
    assert!(replace_speedup >= 10.0,
            "replace-step warm start regressed below 10x: \
             {replace_speedup:.1}x");
    assert!(sweep_speedup >= 10.0,
            "chunk-sweep warm start regressed below 10x: {sweep_speedup:.1}x");
}
