//! DES scheduling-engine throughput: tasks scheduled per second on graphs
//! shaped like real multi-pair model schedules.

mod common;

use common::Bench;
use scmoe::simtime::{Resource, Sim};

fn build_chain_graph(pairs: usize, chunks: usize) -> Sim {
    let mut sim = Sim::new();
    let mut prev = None;
    for p in 0..pairs {
        let deps: Vec<_> = prev.into_iter().collect();
        let attn = sim.add(format!("attn{p}"), Resource::Compute(0), 1.0, &deps);
        let gate = sim.add("gate", Resource::Compute(0), 0.1, &[attn]);
        let mut tail = attn;
        for c in 0..chunks {
            let d = sim.add(format!("d{c}"), Resource::Comm(0), 0.5, &[gate]);
            let e = sim.add(format!("e{c}"), Resource::Compute(0), 0.5, &[d, tail]);
            let _ = sim.add(format!("c{c}"), Resource::Comm(0), 0.5, &[e]);
            tail = e;
        }
        let out = sim.add("decode", Resource::Compute(0), 0.1, &[tail]);
        prev = Some(out);
    }
    sim
}

fn main() {
    let b = Bench::new("des_engine");
    for (pairs, chunks) in [(12usize, 2usize), (48, 4), (96, 8)] {
        let sim = build_chain_graph(pairs, chunks);
        let n = sim.len();
        let t = b.measure(&format!("{n} tasks ({pairs} pairs x {chunks} chunks)"),
                          100, 5, || {
            std::hint::black_box(sim.run());
        });
        println!("  -> {:.2} M tasks/s", n as f64 / t / 1e6);
    }
}
