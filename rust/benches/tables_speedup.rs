//! Regenerates the speedup columns of Tables 2/3/4 (paper §4.2) and times
//! the adaptive slot search.

mod common;

use common::Bench;
use scmoe::cluster::Scenario;
use scmoe::coordinator::adaptive::choose_expert_slot;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::report::efficiency::{gpt_proxy_costs, speedup_tables};

fn main() {
    let args = scmoe::util::cli::Args::default();
    speedup_tables(&args).unwrap();

    let b = Bench::new("tables_speedup");
    let c = gpt_proxy_costs(Scenario::NvlinkA800x8);
    b.measure("adaptive slot search (4 DES runs)", 500, 5, || {
        std::hint::black_box(choose_expert_slot(&c, MoEKind::ScMoE { k: 1 },
                                                Strategy::Overlap));
    });
}
