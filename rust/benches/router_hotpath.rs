//! L3 hot-path micro-benchmarks: routing-table construction and
//! encode/decode layout transforms at serving-realistic shapes.
//! These are the coordinator-side operations on the per-layer critical
//! path (§Perf target: L3 must not be the bottleneck).

mod common;

use common::Bench;
use scmoe::moe::{decode_into, encode_into, RoutingTable};
use scmoe::util::rng::Rng;

fn setup(t: usize, k: usize, e: usize) -> (Vec<i32>, Vec<f32>) {
    let mut rng = Rng::new(1);
    let mut idx = Vec::with_capacity(t * k);
    let mut w = Vec::with_capacity(t * k);
    for _ in 0..t {
        for _ in 0..k {
            idx.push(rng.below(e) as i32);
            w.push(rng.next_f32());
        }
    }
    (idx, w)
}

fn main() {
    let b = Bench::new("router_hotpath");
    for (t, k, e, d) in [(4096usize, 2usize, 8usize, 1024usize),
                         (16384, 2, 64, 1024),
                         (4096, 1, 8, 1024)] {
        let (idx, w) = setup(t, k, e);
        let cap = (t * k * 2) / e;
        b.measure(&format!("RoutingTable::build t={t} k={k} E={e}"), 20, 5, || {
            std::hint::black_box(RoutingTable::build(&idx, &w, t, k, e, cap));
        });

        let table = RoutingTable::build(&idx, &w, t, k, e, cap);
        let mut rng = Rng::new(2);
        let tokens: Vec<f32> = (0..t * d).map(|_| rng.next_f32()).collect();
        let mut enc = vec![0.0f32; e * cap * d];
        let mut dec = vec![0.0f32; t * d];
        b.measure(&format!("encode t={t} d={d}"), 10, 5, || {
            encode_into(&table, &tokens, d, &mut enc);
            std::hint::black_box(&enc);
        });
        b.measure(&format!("decode t={t} d={d}"), 10, 5, || {
            decode_into(&table, &enc, d, &mut dec);
            std::hint::black_box(&dec);
        });
        // tokens/sec summary for the 4096-token case
        let tt = b.measure(&format!("encode+decode roundtrip t={t} d={d}"), 10, 5, || {
            encode_into(&table, &tokens, d, &mut enc);
            decode_into(&table, &enc, d, &mut dec);
            std::hint::black_box(&dec);
        });
        println!("  -> {:.1} M tokens/s through the data plane",
                 t as f64 / tt / 1e6);
    }
}
