//! Shared micro-bench harness (offline build: no criterion). Provides
//! median-of-N timing with warmup and a stable report format that
//! `cargo bench` prints.

use std::time::Instant;

pub struct Bench {
    name: &'static str,
}

impl Bench {
    pub fn new(name: &'static str) -> Bench {
        println!("\n=== bench: {name} ===");
        Bench { name }
    }

    /// Time `f` with `iters` iterations per sample, `samples` samples;
    /// prints and returns the median per-iteration seconds.
    pub fn measure<F: FnMut()>(&self, label: &str, iters: usize, samples: usize,
                               mut f: F) -> f64 {
        // warmup
        f();
        let mut per_iter = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..iters {
                f();
            }
            per_iter.push(t0.elapsed().as_secs_f64() / iters as f64);
        }
        per_iter.sort_by(|a, b| a.total_cmp(b));
        let med = per_iter[samples / 2];
        let (val, unit) = if med >= 1e-3 {
            (med * 1e3, "ms")
        } else if med >= 1e-6 {
            (med * 1e6, "us")
        } else {
            (med * 1e9, "ns")
        };
        println!("{:<40} {:>10.3} {}/iter  ({} iters x {} samples)",
                 format!("{}/{label}", self.name), val, unit, iters, samples);
        med
    }
}
