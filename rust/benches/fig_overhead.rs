//! Regenerates Fig. 1 + Fig. 8 (per-pair overhead across scenarios and
//! configurations) and times the DES while at it.

mod common;

use common::Bench;
use scmoe::cluster::Scenario;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::schedule::build_pair_schedule_auto;
use scmoe::report::efficiency::proxy_costs;

fn main() {
    // the actual figures
    let args = scmoe::util::cli::Args::default();
    scmoe::report::efficiency::fig1(&args).unwrap();
    scmoe::report::efficiency::fig8(&args).unwrap();

    // bench: schedule build + simulate cost per pair
    let b = Bench::new("fig_overhead");
    for sc in Scenario::all() {
        let c = proxy_costs(sc);
        b.measure(&format!("build+sim pair ({})", sc.label()), 200, 5, || {
            let s = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 },
                                             Strategy::Overlap);
            std::hint::black_box(s.makespan());
        });
    }
}
