//! Regenerates Fig. 10 (offloading) and times the decode simulator.

mod common;

use common::Bench;
use scmoe::offload::{simulate_decode, Policy};
use scmoe::report::offload_report::{fig10, gpt2_moe_medium};

fn main() {
    let args = scmoe::util::cli::Args::default();
    fig10(&args).unwrap();

    let b = Bench::new("offload");
    let cfg = gpt2_moe_medium();
    for policy in [Policy::Blocking, Policy::AsyncDeterminate,
                   Policy::Speculative { accuracy: 0.85 }] {
        b.measure(&format!("simulate 64 tokens ({})", policy.label()), 50, 5, || {
            std::hint::black_box(simulate_decode(&cfg, None, 64, policy, 1));
        });
    }
}
