//! DES-driven efficiency experiments: Fig. 1, Fig. 6, Fig. 8 and the
//! speedup columns of Tables 2-4.

use anyhow::Result;

use crate::cluster::{Scenario, Topology};
use crate::coordinator::adaptive::overlap_fraction;
use crate::coordinator::costs::{BlockCosts, ComputeCosts, MoEKind, Strategy, TopoCosts};
use crate::coordinator::schedule::{backbone_time, ChunkPipelining};
use crate::coordinator::spec::ScheduleSpec;
use crate::coordinator::timeline;
use crate::moe::{Placement, RoutingTable};
use crate::util::cli::Args;
use crate::util::rng::Rng;
use crate::util::stats::fmt_secs;

/// SwinV2-MoE-S proxy shape parameters (Fig. 1/8 workload).
pub fn proxy_costs(scenario: Scenario) -> BlockCosts {
    let base = ComputeCosts::swin_proxy();
    let topo = scenario.topology();
    BlockCosts::from_topology(&base, &topo, 4096, 384, 1.25)
}

/// SwinV2-MoE-S proxy on the topology-aware fleet model: per-device
/// compute durations + per-link All-to-All phases for the full preset.
pub fn topo_proxy_costs(scenario: Scenario) -> TopoCosts {
    let base = ComputeCosts::swin_proxy();
    let topo = scenario.topology();
    TopoCosts::from_topology(&base, &topo, 4096, 384, 1.25)
}

/// GPT2-MoE-Medium proxy (Table 3/4 workload): d_model = 1024 tokens
/// (4 KB each), heavier experts; comm share on NVLink ≈ 25% of MoE time —
/// between the Swin NVLink (15%) and PCIe (60%) bands, reflecting the
/// larger per-token payload of the language model.
pub fn gpt_proxy_costs(scenario: Scenario) -> BlockCosts {
    let base = ComputeCosts {
        attn: 1.20e-3,
        mlp: 1.00e-3,
        se: 1.00e-3,
        gate: 0.08e-3,
        encode: 0.06e-3,
        decode: 0.06e-3,
        expert_k1: 1.10e-3,
    };
    let topo = scenario.topology();
    BlockCosts::from_topology(&base, &topo, 640, 4096, 2.0)
}

/// GPT3-MoE-XL proxy (Table 4): d_model = 2048 (8 KB tokens), heavier
/// experts; comm ≈ 33% of MoE time on NVLink at this payload.
pub fn xl_proxy_costs(scenario: Scenario) -> BlockCosts {
    let base = xl_compute_costs();
    let topo = scenario.topology();
    BlockCosts::from_topology(&base, &topo, 640, 8192, 2.0)
}

/// GPT3-MoE-XL compute-op durations (seconds, A30-relative scale 1.0) —
/// the shared source of truth for the XL proxy; also consumed by the
/// placement example and tests so recalibrations stay in sync.
pub fn xl_compute_costs() -> ComputeCosts {
    ComputeCosts {
        attn: 1.40e-3,
        mlp: 1.20e-3,
        se: 1.20e-3,
        gate: 0.09e-3,
        encode: 0.07e-3,
        decode: 0.07e-3,
        expert_k1: 1.40e-3,
    }
}

/// GPT3-MoE-XL proxy on the topology-aware fleet model. The heavy 8 KB
/// token payload makes the All-to-All phases rival the backbone window,
/// which is where the adaptive expert slot genuinely diverges across
/// topology presets (PCIe/2-node prefer the earliest slot; NVLink-class
/// and heterogeneous fleets keep the post-attention slot).
pub fn xl_topo_proxy_costs(scenario: Scenario) -> TopoCosts {
    let base = xl_compute_costs();
    let topo = scenario.topology();
    TopoCosts::from_topology(&base, &topo, 640, 8192, 2.0)
}

/// Seeded node-affine routing table: every token picks `k` distinct
/// experts from its source node's affinity group
/// `{e : e % n_nodes == node}`, with tokens split evenly over devices in
/// index order and capacity sized so nothing drops.
///
/// This is the routing family where expert placement matters most: under
/// the block layout each affinity group is scattered across all nodes
/// (heavy uplink traffic), while `Placement::affinity_packed` makes every
/// route node-local and drives the inter-node phase times to exactly zero.
/// Deterministic for a given seed (splitmix64 stream).
pub fn node_affine_routing(n_devices: usize, devices_per_node: usize,
                           n_experts: usize, tokens_per_device: usize,
                           k: usize, seed: u64) -> RoutingTable {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    assert!(n_experts % n_nodes == 0, "experts must divide into nodes");
    let group = n_experts / n_nodes;
    assert!(k <= group, "k must fit inside one affinity group");
    let n_tokens = n_devices * tokens_per_device;
    let mut rng = Rng::new(seed);
    let mut indices = Vec::with_capacity(n_tokens * k);
    let weights = vec![1.0f32; n_tokens * k];
    for t in 0..n_tokens {
        let node = (t / tokens_per_device) / devices_per_node;
        let first = rng.below(group);
        indices.push((node + n_nodes * first) as i32);
        // remaining group members, ordered from first+1 wrapping around;
        // drawing by index keeps all k picks distinct for any k <= group
        let mut rest: Vec<usize> = (1..group).map(|o| (first + o) % group).collect();
        for _ in 1..k {
            let idx = rest.remove(rng.below(rest.len()));
            indices.push((node + n_nodes * idx) as i32);
        }
    }
    RoutingTable::build(&indices, &weights, n_tokens, k, n_experts, n_tokens)
}

/// Seeded *drifting* node-affine routing (k = 1) for multi-step
/// re-placement studies: each token picks an expert from its node's
/// affinity group, except that with probability `noise` it picks a
/// uniformly random expert instead — so the affinity structure is stable
/// but every step's table differs (ExFlow's iteration-to-iteration
/// stability with measurement noise). `regime` rotates the node→group
/// mapping: node `n` is affine to group `(n + regime) % n_nodes`, so
/// bumping the regime mid-stream models a routing-regime shift that
/// invalidates a learned placement. Deterministic per seed (splitmix64);
/// capacity is sized so nothing drops.
pub fn drifting_node_affine_routing(n_devices: usize, devices_per_node: usize,
                                    n_experts: usize,
                                    tokens_per_device: usize, regime: usize,
                                    noise: f64, seed: u64) -> RoutingTable {
    // the single-phase special case of the serving traffic generator:
    // with n_tokens divisible by n_devices the source-device clamp is a
    // no-op and equal noise makes the phase split irrelevant, so the
    // splitmix64 draw stream is identical token for token
    crate::moe::phase_affine_routing(n_devices, devices_per_node, n_experts,
                                     n_devices * tokens_per_device, 0, regime,
                                     noise, noise, seed)
}

/// Training-iteration costs: forward + backward. Backward roughly doubles
/// compute (recompute + grads) and repeats both All-to-Alls for gradients.
pub fn train_costs(c: &BlockCosts) -> BlockCosts {
    BlockCosts {
        attn: c.attn * 3.0,
        mlp: c.mlp * 3.0,
        se: c.se * 3.0,
        gate: c.gate * 3.0,
        encode: c.encode * 2.0,
        decode: c.decode * 2.0,
        expert_k1: c.expert_k1 * 3.0,
        a2a_k1: c.a2a_k1 * 2.0,
        a2a_alpha_k1: c.a2a_alpha_k1 * 2.0,
    }
}

/// Fig. 1: MLP vs top-2/top-1 MoE time breakdown per scenario.
pub fn fig1(_args: &Args) -> Result<()> {
    println!("== Fig. 1: MoE block overhead breakdown (per Block pair) ==");
    println!("{:<16} {:>10} {:>12} {:>12} {:>12} {:>9}",
             "scenario", "MLP", "MoE-comp", "A2A", "MoE-total", "comm%");
    for sc in Scenario::all() {
        let c = proxy_costs(sc);
        for k in [2usize, 1] {
            let a2a = 2.0 * c.a2a(k);
            let comp = c.gate + c.encode + c.decode + c.expert(k);
            let total = comp + a2a;
            println!("{:<16} {:>10} {:>12} {:>12} {:>12} {:>8.0}%  (top-{k})",
                     sc.label(), fmt_secs(c.mlp), fmt_secs(comp),
                     fmt_secs(a2a), fmt_secs(total), 100.0 * a2a / total);
        }
    }
    println!("\npaper bands: PCIe ≈ 60% | NVLink ≈ 15% | 2-node → ~50% (top-2)");
    Ok(())
}

/// Fig. 6: operator timelines for each architecture × strategy.
pub fn fig6(args: &Args) -> Result<()> {
    let sc = Scenario::parse(&args.str_or("scenario", "pcie")).unwrap_or(Scenario::PcieA30x8);
    let c = proxy_costs(sc);
    let width = args.usize_or("width", 100);
    println!("== Fig. 6: timelines ({}) ==", sc.label());
    let rows: Vec<(&str, MoEKind, Strategy)> = vec![
        ("Standard MoE (sequential)", MoEKind::Standard { k: 2 }, Strategy::Sequential),
        ("Standard MoE (pipelining)", MoEKind::Standard { k: 2 },
         Strategy::Pipelined { chunks: 2 }),
        ("Shared-expert MoE", MoEKind::SharedExpert, Strategy::Pipelined { chunks: 1 }),
        ("ScMoE (overlapping)", MoEKind::ScMoE { k: 1 }, Strategy::Overlap),
        ("ScMoE (overlapping+pipelining)", MoEKind::ScMoE { k: 1 },
         Strategy::OverlapPipelined { chunks: 2 }),
    ];
    for (label, kind, strat) in rows {
        let s = ScheduleSpec::new(kind, strat).adaptive().build(&c);
        println!("\n--- {label} ---");
        print!("{}", timeline::render(&s.run(), width));
    }
    Ok(())
}

/// Fig. 8: per-pair overhead across scenarios and configurations.
pub fn fig8(_args: &Args) -> Result<()> {
    println!("== Fig. 8: overhead per Block-MLP + Block-MoE pair ==");
    let configs: Vec<(&str, MoEKind, Strategy)> = vec![
        ("Top2",     MoEKind::Standard { k: 2 }, Strategy::Sequential),
        ("Top2-P",   MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 2 }),
        ("Top1",     MoEKind::Standard { k: 1 }, Strategy::Sequential),
        ("Top1-P",   MoEKind::Standard { k: 1 }, Strategy::Pipelined { chunks: 2 }),
        ("Top1+SE1", MoEKind::SharedExpert,      Strategy::Pipelined { chunks: 1 }),
        ("ScMoE",    MoEKind::ScMoE { k: 1 },    Strategy::Overlap),
        ("ScMoE-P",  MoEKind::ScMoE { k: 1 },    Strategy::OverlapPipelined { chunks: 2 }),
    ];
    for sc in Scenario::all() {
        let c = proxy_costs(sc);
        println!("\n--- {} ---", sc.label());
        let base = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                     Strategy::Sequential)
            .build(&c)
            .makespan();
        for (label, kind, strat) in &configs {
            let t = ScheduleSpec::new(*kind, *strat).adaptive().build(&c).makespan();
            let bar_len = (40.0 * t / base) as usize;
            println!("{:<10} {:>10}  {:>5.2}x  {}",
                     label, fmt_secs(t), base / t, "#".repeat(bar_len));
        }
        let ov = overlap_fraction(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        println!("ScMoE overlap fraction: {:.0}%", ov * 100.0);
    }
    Ok(())
}

/// Topology-aware fleet report: for every preset (including the extended
/// multi-node IB and heterogeneous topologies), simulate the whole device
/// fleet and compare the sequential top-2 baseline against the ScMoE
/// overlap with its per-topology adaptive expert slot.
pub fn topo_report(args: &Args) -> Result<()> {
    let width = args.usize_or("width", 0);
    let workloads: [(&str, fn(Scenario) -> TopoCosts); 2] = [
        ("SwinV2 proxy", topo_proxy_costs),
        ("GPT3-XL proxy", xl_topo_proxy_costs),
    ];
    for (wname, costs_of) in workloads {
        println!("== topology-aware fleet schedules ({wname}) ==");
        println!("{:<18} {:>4} {:>6} {:>12} {:>12} {:>8} {:>6}",
                 "preset", "dev", "nodes", "top2-seq", "scmoe-ovl", "speedup", "slot");
        for sc in Scenario::extended() {
            let tc = costs_of(sc);
            let base = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                         Strategy::Sequential)
                .build(&tc)
                .makespan();
            let kind = MoEKind::ScMoE { k: 1 };
            let spec = ScheduleSpec::new(kind, Strategy::Overlap);
            let (slot, overlap) = spec.choose_slot(&tc);
            println!("{:<18} {:>4} {:>6} {:>12} {:>12} {:>7.2}x {:>6}",
                     sc.label(), tc.n_devices(), tc.n_nodes(),
                     fmt_secs(base), fmt_secs(overlap), base / overlap, slot + 1);
            if width > 0 {
                let s = spec.with_slot(slot).build(&tc);
                print!("{}", timeline::render(&s.run(), width));
            }
        }
        println!();
    }
    println!("slot = adaptive expert location (1..4, Eq. 11) chosen per topology");

    routed_placement_study(args);
    load_skew_study(args);
    chunk_sweep_study(args);
    Ok(())
}

/// Chunk-count sweep on the 4-node IB preset (GPT3-XL payload): every
/// chunk pays its own launch latency (`α + bytes/chunks/β` per phase), so
/// deep chunking stops being free — the sweep exposes the optimum instead
/// of monotonically rewarding more chunks as the amortized model did.
/// `staged` columns use the MoNTA-style intra/inter pipeline (chunk i's
/// uplink behind that node's intra tasks, overlapping chunk i+1's intra
/// phase); `chained` serializes consecutive chunks' phases and is strictly
/// slower at every chunk count.
fn chunk_sweep_study(args: &Args) {
    let sc = Scenario::FourNodeA800IBx32;
    let tc = xl_topo_proxy_costs(sc);
    let kind = MoEKind::ScMoE { k: 1 };
    let max_chunks = args.usize_or("max-chunks", 16);
    println!("\n== chunk sweep ({}, GPT3-XL payload) ==", sc.label());
    println!("{:<7} {:>12} {:>13} {:>12} {:>12} {:>6}",
             "chunks", "pipe-staged", "pipe-chained", "ovl-staged",
             "ovl-chained", "slot");
    let mut chunks = 1usize;
    while chunks <= max_chunks {
        let pipe = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                     Strategy::Pipelined { chunks });
        let staged = pipe.build(&tc).makespan();
        let chained = pipe
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .makespan();
        let ospec = ScheduleSpec::new(kind, Strategy::OverlapPipelined { chunks });
        let (slot, ovl_staged) = ospec.choose_slot(&tc);
        let ovl_chained = ospec
            .with_slot(slot)
            .with_pipelining(ChunkPipelining::PhaseChained)
            .build(&tc)
            .makespan();
        println!("{:<7} {:>12} {:>13} {:>12} {:>12} {:>6}",
                 chunks, fmt_secs(staged), fmt_secs(chained),
                 fmt_secs(ovl_staged), fmt_secs(ovl_chained), slot + 1);
        chunks *= 2;
    }
    println!("per-chunk α is paid by every chunk message, so deep chunking \
              has a real cost;");
    println!("staged = MoNTA intra/inter pipelining; chained = consecutive \
              chunks' phases serialized");
}

/// The routed placement study's `(label, costs)` rows on one topology
/// (GPT3-XL payload, 8 KiB tokens, node-affine routing from `seed`): the
/// uniform byte-matrix model vs actual routed bytes under block,
/// affinity-packed (ExFlow-style) and imbalance-skewed expert placements.
/// Shared by `scmoe report topo` and `timeline_explorer --placement` so
/// the table and the rendered timelines can never drift apart.
pub fn placement_study_rows(topo: &Topology, tokens_per_device: usize,
                            seed: u64) -> Vec<(&'static str, TopoCosts)> {
    let base = xl_compute_costs();
    let token_bytes = 8192;
    let rt = node_affine_routing(topo.n_devices, topo.devices_per_node,
                                 topo.n_devices, tokens_per_device, 1, seed);
    vec![
        ("uniform (no routing)",
         TopoCosts::from_topology(&base, topo, tokens_per_device,
                                  token_bytes, 2.0)),
        ("routed + block",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::new(topo.n_devices, topo.n_devices),
                                 token_bytes)),
        ("routed + affinity-packed",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::affinity_packed(
                                     &rt, topo.n_devices, topo.devices_per_node),
                                 token_bytes)),
        ("routed + skewed (2/dev)",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::imbalance_skewed(
                                     topo.n_devices, topo.n_devices, 2),
                                 token_bytes)),
    ]
}

/// Routed-traffic placement study on the 4-node IB preset (GPT3-XL
/// payload): contrast the uniform byte-matrix model against actual routed
/// bytes under block, affinity-packed (ExFlow-style) and imbalance-skewed
/// expert placements. Affinity packing the node-affine routing drives the
/// uplink phases to exactly zero. Phase columns report the worst phase
/// over *both* A2A directions (dispatch and combine) — skewed layouts
/// make them asymmetric.
fn routed_placement_study(args: &Args) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let kind = MoEKind::ScMoE { k: 1 };
    let seed = args.u64_or("seed", 7);
    let tokens_per_device = args.usize_or("tokens", 640);

    let rows = placement_study_rows(&topo, tokens_per_device, seed);
    println!("== routed placement study ({}, GPT3-XL payload, seed {seed}) ==",
             sc.label());
    println!("{:<26} {:>11} {:>11} {:>12} {:>12} {:>6}",
             "placement", "intra-max", "inter-max", "scmoe-seq",
             "scmoe-ovl", "slot");
    for (name, tc) in &rows {
        // worst phase across dispatch AND combine directions
        let intra_max = tc.a2a_intra_k1.iter()
            .chain(tc.a2a_intra_combine_k1.iter())
            .fold(0.0f64, |m, &t| m.max(t));
        let inter_max = tc.a2a_inter_k1.iter()
            .chain(tc.a2a_inter_combine_k1.iter())
            .fold(0.0f64, |m, &t| m.max(t));
        let seq = ScheduleSpec::new(kind, Strategy::Sequential)
            .build(tc)
            .makespan();
        let (slot, ovl) =
            ScheduleSpec::new(kind, Strategy::Overlap).choose_slot(tc);
        println!("{:<26} {:>11} {:>11} {:>12} {:>12} {:>6}",
                 name, fmt_secs(intra_max), fmt_secs(inter_max),
                 fmt_secs(seq), fmt_secs(ovl), slot + 1);
    }
    println!("routing: node-affine (each token's experts live in its node's \
              affinity group);");
    println!("affinity packing makes every route node-local, so the uplink \
              phases are exactly 0");
    println!("note: the uniform row carries capacity_factor 2.0 headroom; \
              compare the routed rows");
    println!("      against each other for placement-only effects \
              (seq + phase columns)");
}

/// The load-skew study's `(label, costs)` rows on one topology (GPT3-XL
/// payload, node-affine routing from `seed`): the balanced block layout
/// against imbalance-skewed layouts packing 2 and 4 experts per device.
/// Shared by `scmoe report topo` and `timeline_explorer --skew` so the
/// table and the rendered timelines can never drift apart.
pub fn load_skew_study_rows(topo: &Topology, tokens_per_device: usize,
                            seed: u64) -> Vec<(&'static str, TopoCosts)> {
    let base = xl_compute_costs();
    let token_bytes = 8192;
    let rt = node_affine_routing(topo.n_devices, topo.devices_per_node,
                                 topo.n_devices, tokens_per_device, 1, seed);
    vec![
        ("routed + block",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::new(topo.n_devices, topo.n_devices),
                                 token_bytes)),
        ("routed + skewed (2/dev)",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::imbalance_skewed(
                                     topo.n_devices, topo.n_devices, 2),
                                 token_bytes)),
        ("routed + skewed (4/dev)",
         TopoCosts::from_routing(&base, topo, &rt,
                                 &Placement::imbalance_skewed(
                                     topo.n_devices, topo.n_devices, 4),
                                 token_bytes)),
    ]
}

/// Load-skew study on the 4-node IB preset (GPT3-XL payload): the same
/// node-affine routing priced with the per-device `ExpertLoad` scaling
/// on ("load-true") and off ("naive", the pre-redesign model that charged
/// every device the balanced capacity batch). A skewed placement keeps
/// every source device's *send* phases roughly balanced while piling all
/// expert compute onto the loaded device prefix — under the naive model
/// such comm-balanced-but-compute-overloaded layouts scored nearly as
/// fast as truly balanced ones; load-true pricing stretches the hot
/// devices' Expert spans (and with them the fleet barrier), which also
/// reorders seq-vs-overlap comparisons across placements.
fn load_skew_study(args: &Args) {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let kind = MoEKind::ScMoE { k: 1 };
    let seed = args.u64_or("seed", 7);
    let tokens_per_device = args.usize_or("tokens", 640);

    let rows = load_skew_study_rows(&topo, tokens_per_device, seed);
    println!("\n== load-skew study ({}, GPT3-XL payload, seed {seed}) ==",
             sc.label());
    println!("{:<24} {:>8} {:>11} {:>11} {:>11} {:>11}",
             "placement", "load-imb", "seq-naive", "seq-true", "ovl-naive",
             "ovl-true");
    let mut makespans = Vec::new();
    for (name, tc) in &rows {
        let mut naive = tc.clone();
        naive.expert_load = None;
        let imb = tc.expert_load.as_ref().map_or(1.0, |l| l.imbalance());
        let seq = ScheduleSpec::new(kind, Strategy::Sequential);
        let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
        let seq_n = seq.build(&naive).makespan();
        let seq_t = seq.build(tc).makespan();
        let (_, ovl_n) = ovl.choose_slot(&naive);
        let (_, ovl_t) = ovl.choose_slot(tc);
        println!("{:<24} {:>7.2}x {:>11} {:>11} {:>11} {:>11}",
                 name, imb, fmt_secs(seq_n), fmt_secs(seq_t),
                 fmt_secs(ovl_n), fmt_secs(ovl_t));
        makespans.push((seq_n, seq_t, ovl_n, ovl_t));
    }
    let (block_seq_n, block_seq_t, ..) = makespans[0];
    let (.., skew_ovl_n, skew_ovl_t) = makespans[1];
    println!("naive = pre-load model (every device charged the balanced \
              capacity batch)");
    // data-driven callout: print what the numbers actually say for this
    // seed/token count (the default seed-7/640-token flip is pinned in
    // rust/tests/load_scaling.rs)
    let rel = |a: f64, b: f64| if a < b { "<" } else { ">=" };
    println!("reordering probe, skewed(2/dev) overlap vs block sequential: \
              naive {} {} {}; load-true {} {} {}",
             fmt_secs(skew_ovl_n), rel(skew_ovl_n, block_seq_n),
             fmt_secs(block_seq_n), fmt_secs(skew_ovl_t),
             rel(skew_ovl_t, block_seq_t), fmt_secs(block_seq_t));
    if skew_ovl_n < block_seq_n && skew_ovl_t > block_seq_t {
        println!("  -> the comparison flips: overloading half the fleet no \
                  longer wins once loads are priced");
    }
}

/// Speedup columns of Tables 2 (PCIe), 3 (NVLink) and 4 (NVLink, more
/// activated experts), plus §4.2.4's ScMoE-2 vs top-2 cost ratio.
pub fn speedup_tables(_args: &Args) -> Result<()> {
    let rows: Vec<(&str, MoEKind, Strategy)> = vec![
        ("Standard top-2 MoE", MoEKind::Standard { k: 2 }, Strategy::Sequential),
        ("Standard top-1 MoE", MoEKind::Standard { k: 1 }, Strategy::Sequential),
        ("Shared-Expert MoE",  MoEKind::SharedExpert,      Strategy::Pipelined { chunks: 1 }),
        ("ScMoE",              MoEKind::ScMoE { k: 1 },    Strategy::Overlap),
        ("Standard top-3 MoE", MoEKind::Standard { k: 3 }, Strategy::Sequential),
        ("ScMoE-2",            MoEKind::ScMoE { k: 2 },    Strategy::Overlap),
    ];
    for (table, sc, proxy) in [("Table 2 (SwinV2 proxy)", Scenario::PcieA30x8, 0),
                               ("Table 3 (GPT2-Medium proxy)", Scenario::NvlinkA800x8, 1),
                               ("Table 4 (GPT3-XL proxy)", Scenario::NvlinkA800x8, 2)] {
        let c_inf = match proxy {
            0 => proxy_costs(sc),
            1 => gpt_proxy_costs(sc),
            _ => xl_proxy_costs(sc),
        };
        let c_tr = train_costs(&c_inf);
        let base = ScheduleSpec::new(MoEKind::Standard { k: 2 },
                                     Strategy::Sequential);
        let base_inf = base.build(&c_inf).makespan();
        let base_tr = base.build(&c_tr).makespan();
        println!("\n== {table} — {} ==", sc.label());
        println!("{:<22} {:>12} {:>12}", "model", "train", "inference");
        for (label, kind, strat) in &rows {
            let spec = ScheduleSpec::new(*kind, *strat).adaptive();
            let ti = spec.build(&c_inf).makespan();
            let tt = spec.build(&c_tr).makespan();
            println!("{:<22} {:>11.2}x {:>11.2}x", label, base_tr / tt, base_inf / ti);
        }
        let _ = backbone_time(&c_inf, MoEKind::ScMoE { k: 1 });
    }
    println!("\npaper: Table2 ScMoE 1.43x/1.66x (PCIe); Table3 1.12x/1.17x (NVLink);");
    println!("       Table4 ScMoE-2 vs top-2: 1.05x train / 1.08x inference");
    Ok(())
}
