//! The open-loop serving study (`scmoe report serve`): where is the
//! throughput–latency knee, and what moves it?
//!
//! A seeded Poisson request stream (prefill + multi-step decode) drives
//! [`run_serve`] on the 32xA800-4node-IB preset with the GPT3-XL payload.
//! The sweep crosses offered load × schedule strategy (sequential vs
//! adaptive overlap) × placement policy (static block layout vs PR 5's
//! break-even online re-placement): below the knee p50 tracks the
//! no-queue service time, past it queueing blows the tail up by an order
//! of magnitude, and both the overlap strategy and online re-placement
//! shift the knee right by shortening every step. A second table holds
//! the batching policies (wait-k / deadline / token-budget) at mid load.
//!
//! Every pinned number in `rust/tests/serve_loop.rs` and
//! `docs/STUDIES.md` is minted through the DES mirror
//! (`tools/des_mirror/mirror2.py --serve-study`, PR6 model). The same
//! constants are exported so `timeline_explorer --serve` renders the
//! identical runs.

use anyhow::Result;

use crate::cluster::Scenario;
use crate::coordinator::costs::{MoEKind, Strategy};
use crate::coordinator::replace::ReplacePolicy;
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::Placement;
use crate::serve::{
    poisson_arrivals, run_serve, trace_arrivals, BatchPolicy, Request,
    ServeConfig, ServeOutcome, TrafficProfile,
};
use crate::util::cli::Args;
use crate::util::stats::fmt_secs;

use super::efficiency::xl_compute_costs;
use super::replace::{study_h2d_link, STUDY_BYTES_PER_EXPERT};

/// Requests per serving run.
pub const SERVE_REQUESTS: usize = 64;
/// Prompt tokens per request (one prefill step's contribution).
pub const SERVE_PREFILL_TOKENS: usize = 2048;
/// Decode iterations per request after prefill.
pub const SERVE_DECODE_STEPS: usize = 4;
/// Tokens each active decode request contributes per step.
pub const SERVE_DECODE_TOKENS: usize = 64;
/// Payload bytes per routed token copy (GPT3-XL, 8 KiB).
pub const SERVE_TOKEN_BYTES: usize = 8192;
/// Bernoulli-grid tick for the Poisson arrival stream (dyadic so the
/// arrival instants are bit-identical in the Python mirror).
pub const SERVE_TICK: f64 = 1.0 / 2048.0;
/// Arrival-stream seed.
pub const SERVE_SEED: u64 = 31;
/// Traffic (routing-stream) base seed; step `s` draws from seed + s.
pub const SERVE_TRAFFIC_SEED: u64 = 311;
/// Per-token random-routing probability for prompt tokens.
pub const SERVE_PREFILL_NOISE: f64 = 0.05;
/// Per-token random-routing probability for generated tokens.
pub const SERVE_DECODE_NOISE: f64 = 0.25;
/// Token budget of the sweep's batch policy.
pub const SERVE_BUDGET: usize = 6144;
/// Latency target for goodput and the knee (seconds) — tight enough
/// that the sequential strategy misses it at the top swept load while
/// overlap holds it, so the knee discriminates between strategies.
pub const SERVE_SLO: f64 = 0.030;
/// Fixed overlap expert slot on the 4-node IB preset (the adaptive
/// choice for the XL payload, pinned so every step prices one build).
pub const SERVE_OVERLAP_SLOT: usize = 2;
/// Offered loads swept (requests per second).
pub const SERVE_LOADS: [f64; 3] = [120.0, 240.0, 480.0];

/// Mixed-shape column: prompt tokens / decode steps of the short shape.
pub const HETERO_SHORT_PREFILL: usize = 1024;
/// Short-shape decode steps.
pub const HETERO_SHORT_DECODE: usize = 2;
/// Long-shape prompt tokens.
pub const HETERO_LONG_PREFILL: usize = 4096;
/// Long-shape decode steps.
pub const HETERO_LONG_DECODE: usize = 8;

/// The swept arrival stream at one offered load.
pub fn serve_requests(rate: f64) -> Vec<Request> {
    poisson_arrivals(SERVE_REQUESTS, rate, SERVE_TICK, SERVE_PREFILL_TOKENS,
                     SERVE_DECODE_STEPS, SERVE_SEED)
}

/// Heterogeneous request shapes through [`trace_arrivals`]: the same
/// Poisson instants as [`serve_requests`], remapped to alternating
/// short (1024-token prompt / 2 decode steps) and long (4096 / 8)
/// shapes by arrival index.
pub fn hetero_requests(rate: f64) -> Vec<Request> {
    let trace: Vec<(f64, usize, usize)> = serve_requests(rate)
        .iter()
        .enumerate()
        .map(|(i, r)| {
            if i % 2 == 0 {
                (r.arrival, HETERO_SHORT_PREFILL, HETERO_SHORT_DECODE)
            } else {
                (r.arrival, HETERO_LONG_PREFILL, HETERO_LONG_DECODE)
            }
        })
        .collect();
    trace_arrivals(&trace)
}

/// The study's schedule spec for a strategy (overlap pins its slot).
pub fn serve_spec(strategy: Strategy) -> ScheduleSpec {
    let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, strategy);
    match strategy {
        Strategy::Overlap => spec.with_slot(SERVE_OVERLAP_SLOT),
        _ => spec,
    }
}

/// The study's [`ServeConfig`] for one cell of the sweep.
pub fn serve_config(strategy: Strategy, batching: BatchPolicy,
                    policy: ReplacePolicy) -> ServeConfig {
    ServeConfig {
        spec: serve_spec(strategy),
        batching,
        policy,
        decay: 1.0,
        bytes_per_expert: STUDY_BYTES_PER_EXPERT,
        h2d: study_h2d_link(),
        token_bytes: SERVE_TOKEN_BYTES,
        decode_tokens: SERVE_DECODE_TOKENS,
        n_experts: 32,
        traffic: TrafficProfile {
            regime: 0,
            shift_at: None,
            prefill_noise: SERVE_PREFILL_NOISE,
            decode_noise: SERVE_DECODE_NOISE,
            seed: SERVE_TRAFFIC_SEED,
        },
    }
}

/// Run one cell: offered load × strategy × batching × placement policy
/// on the 4-node IB preset from the uniform block placement.
pub fn run_serve_cell(rate: f64, strategy: Strategy, batching: BatchPolicy,
                      policy: ReplacePolicy) -> ServeOutcome {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let requests = serve_requests(rate);
    run_serve(&base, &topo, &requests, &Placement::new(32, 32),
              &serve_config(strategy, batching, policy))
}

/// Run one mixed-shape cell: as [`run_serve_cell`] but over the
/// [`hetero_requests`] trace.
pub fn run_hetero_cell(rate: f64, strategy: Strategy, batching: BatchPolicy,
                       policy: ReplacePolicy) -> ServeOutcome {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let requests = hetero_requests(rate);
    run_serve(&base, &topo, &requests, &Placement::new(32, 32),
              &serve_config(strategy, batching, policy))
}

/// The throughput–latency knee: the largest swept load whose p99 stays
/// within the SLO (`None` when even the lightest load misses it).
pub fn knee_load(cells: &[(f64, ServeOutcome)]) -> Option<f64> {
    cells
        .iter()
        .filter(|(_, o)| o.p99() <= SERVE_SLO)
        .map(|(rate, _)| *rate)
        .fold(None, |m: Option<f64>, r| Some(m.map_or(r, |m| m.max(r))))
}

fn policy_label(policy: ReplacePolicy) -> &'static str {
    match policy {
        ReplacePolicy::Never => "static",
        _ => "replace",
    }
}

/// `scmoe report serve` — the load sweep plus the batching-policy table.
pub fn serve_report(_args: &Args) -> Result<()> {
    let sc = Scenario::FourNodeA800IBx32;
    println!("== open-loop serving study ({}, GPT3-XL payload) ==", sc.label());
    println!("{} requests/run: prefill {} tok + {} decode steps x {} tok; \
              {} B tokens",
             SERVE_REQUESTS, SERVE_PREFILL_TOKENS, SERVE_DECODE_STEPS,
             SERVE_DECODE_TOKENS, SERVE_TOKEN_BYTES);
    println!("batching {}; SLO {}; online replace moves {} MiB/expert over \
              a {:.0} GB/s H2D link",
             BatchPolicy::TokenBudget { budget: SERVE_BUDGET }.label(),
             fmt_secs(SERVE_SLO), STUDY_BYTES_PER_EXPERT >> 20,
             study_h2d_link().beta / 1e9);

    println!("\n-- load sweep: offered req/s x strategy x placement policy --");
    println!("{:>5} {:<8} {:<8} {:>6} {:>10} {:>10} {:>8} {:>8} {:>5}",
             "load", "strategy", "policy", "steps", "p50", "p99", "req/s",
             "goodput", "migr");
    let budget = BatchPolicy::TokenBudget { budget: SERVE_BUDGET };
    for strategy in [Strategy::Sequential, Strategy::Overlap] {
        for policy in [ReplacePolicy::Never, ReplacePolicy::BreakEven] {
            let mut cells = Vec::new();
            for rate in SERVE_LOADS {
                let out = run_serve_cell(rate, strategy, budget, policy);
                println!("{:>5.0} {:<8} {:<8} {:>6} {:>10} {:>10} {:>8.1} \
                          {:>8.1} {:>5}",
                         rate, strategy.label(), policy_label(policy),
                         out.steps.len(), fmt_secs(out.p50()),
                         fmt_secs(out.p99()), out.throughput(),
                         out.goodput(SERVE_SLO), out.migrations);
                cells.push((rate, out));
            }
            match knee_load(&cells) {
                Some(r) => println!("      {} / {}: knee at {:.0} req/s \
                                     (largest load with p99 <= SLO)",
                                    strategy.label(), policy_label(policy), r),
                None => println!("      {} / {}: saturated at every swept load",
                                 strategy.label(), policy_label(policy)),
            }
        }
    }

    println!("\n-- batching policies at {:.0} req/s (seq, replace) --",
             SERVE_LOADS[1]);
    println!("{:<14} {:>6} {:>10} {:>10} {:>8} {:>8}",
             "policy", "steps", "p50", "p99", "req/s", "goodput");
    for batching in [BatchPolicy::WaitK { k: 2 },
                     BatchPolicy::Deadline { window: 0.008 },
                     budget] {
        let out = run_serve_cell(SERVE_LOADS[1], Strategy::Sequential,
                                 batching, ReplacePolicy::BreakEven);
        println!("{:<14} {:>6} {:>10} {:>10} {:>8.1} {:>8.1}",
                 batching.label(), out.steps.len(), fmt_secs(out.p50()),
                 fmt_secs(out.p99()), out.throughput(), out.goodput(SERVE_SLO));
    }
    println!("\n-- mixed request shapes: alternating {}tok/{}step and \
              {}tok/{}step (budget batching) --",
             HETERO_SHORT_PREFILL, HETERO_SHORT_DECODE, HETERO_LONG_PREFILL,
             HETERO_LONG_DECODE);
    println!("{:>5} {:<8} {:<8} {:>6} {:>10} {:>10} {:>8} {:>8} {:>5}",
             "load", "strategy", "policy", "steps", "p50", "p99", "req/s",
             "goodput", "migr");
    for strategy in [Strategy::Sequential, Strategy::Overlap] {
        for policy in [ReplacePolicy::Never, ReplacePolicy::BreakEven] {
            for rate in SERVE_LOADS {
                let out = run_hetero_cell(rate, strategy, budget, policy);
                println!("{:>5.0} {:<8} {:<8} {:>6} {:>10} {:>10} {:>8.1} \
                          {:>8.1} {:>5}",
                         rate, strategy.label(), policy_label(policy),
                         out.steps.len(), fmt_secs(out.p50()),
                         fmt_secs(out.p99()), out.throughput(),
                         out.goodput(SERVE_SLO), out.migrations);
            }
        }
    }
    println!("      the SLO bifurcates by shape: the short half completes \
              within it, the long");
    println!("      half never does, so goodput saturates at half of \
              throughput at every load");

    println!("\npast the knee the queue never drains: p99 grows with run \
              length while p50 stays");
    println!("near the no-queue service time; overlap and online \
              re-placement both shift the");
    println!("knee right by shortening every step");
    Ok(())
}
