//! The chaos robustness study (`scmoe report chaos`): which placement ×
//! schedule × replace policy stays *robust* — not merely fast — when the
//! fleet misbehaves?
//!
//! Three fault scenarios on the 32xA800-4node-IB preset (GPT3-XL
//! payload, the live re-placement study's constants), each driven by
//! [`run_chaos_timeline`] over the drift study's seeded routing stream:
//!
//! - **stragglers** — 10% per-step compute jitter on every device plus
//!   two persistent stragglers (device 3 at 1.5x, device 17 at 2.0x);
//! - **flaky-uplink** — the shared InfiniBand uplink flaps on a 4-step
//!   cycle (2 healthy steps, then 2 with α×8 and β/8);
//! - **dropout** — device 5 fails at step 4; its expert fails over to
//!   the least-loaded survivor and the migration storm overlaps the
//!   recovery step's H2D engines.
//!
//! The study tabulates each cell's makespan distribution (median, p99,
//! tail amplification p99/median) and totals. Headlines (pinned in
//! `rust/tests/chaos_suite.rs`, minted via
//! `tools/des_mirror/mirror2.py --chaos-study`): under dropout the
//! break-even policy beats static placement (79.1 vs 86.6 ms over 16
//! steps) because re-learning repacks the post-failover layout; under
//! the flaky uplink the affinity placement is nearly immune (64.2 vs
//! 135.5 ms static-block) since node-local routes never touch the
//! faulted link.
//!
//! A second head-to-head folds in C2R (arXiv:2504.01337): collaboration-
//! constrained routing bounds every token to its node's affinity group,
//! so a persistent uplink fault (α×8, β/16) cannot touch it at all — its
//! degraded timeline is *bit-identical* to its clean one (75.3 ms) —
//! while unconstrained node-affine routing at the same 15% noise pays
//! 61.5 → 101.4 ms. The clean-path cost of the constraint (+23%) is the
//! price of that immunity.

use anyhow::Result;

use crate::cluster::{ChaosSpec, Dropout, LinkFault, Scenario};
use crate::coordinator::costs::{MoEKind, Strategy};
use crate::coordinator::replace::{
    run_chaos_timeline, ReplaceOutcome, ReplacePolicy,
};
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::{c2r_routing, Placement, RoutingTable};
use crate::util::cli::Args;
use crate::util::stats::{fmt_secs, percentile};

use super::efficiency::{drifting_node_affine_routing, xl_compute_costs};
use super::replace::{
    study_config, study_tables, STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED,
    STUDY_STEPS, STUDY_TOKENS_PER_DEVICE, STUDY_TOKEN_BYTES,
};

/// Max fractional per-device compute slowdown per step (stragglers
/// scenario).
pub const CHAOS_JITTER: f64 = 0.10;
/// Jitter stream seed.
pub const CHAOS_JITTER_SEED: u64 = 77;
/// Persistent `(device, slowdown)` stragglers.
pub const CHAOS_STRAGGLERS: [(usize, f64); 2] = [(3, 1.5), (17, 2.0)];
/// Flaky-uplink α multiplier while degraded.
pub const CHAOS_FLAP_ALPHA: f64 = 8.0;
/// Flaky-uplink β divisor while degraded.
pub const CHAOS_FLAP_BETA: f64 = 8.0;
/// Flap schedule: healthy 2 steps, degraded 2, period 4.
pub const CHAOS_FLAP: (usize, usize) = (4, 2);
/// Dropout scenario: the failing device.
pub const CHAOS_DROP_DEVICE: usize = 5;
/// Dropout scenario: the step it fails at.
pub const CHAOS_DROP_STEP: usize = 4;
/// C2R head-to-head: per-token deviation probability.
pub const C2R_NOISE: f64 = 0.15;
/// C2R head-to-head: collaboration width (experts per group a deviating
/// token may pick from).
pub const C2R_COLLAB: usize = 1;
/// C2R head-to-head: persistent uplink fault α multiplier.
pub const C2R_UPLINK_ALPHA: f64 = 8.0;
/// C2R head-to-head: persistent uplink fault β divisor.
pub const C2R_UPLINK_BETA: f64 = 16.0;

/// The three named fault scenarios of the study grid.
pub fn chaos_scenarios() -> Vec<(&'static str, ChaosSpec)> {
    vec![
        ("stragglers", ChaosSpec {
            seed: CHAOS_JITTER_SEED,
            jitter: CHAOS_JITTER,
            stragglers: CHAOS_STRAGGLERS.to_vec(),
            link_faults: Vec::new(),
            dropout: None,
        }),
        ("flaky-uplink", ChaosSpec {
            link_faults: vec![LinkFault {
                node: None,
                alpha_mult: CHAOS_FLAP_ALPHA,
                beta_div: CHAOS_FLAP_BETA,
                flap: Some(CHAOS_FLAP),
            }],
            ..ChaosSpec::clean(0)
        }),
        ("dropout", ChaosSpec {
            dropout: Some(Dropout { device: CHAOS_DROP_DEVICE,
                                    at_step: CHAOS_DROP_STEP }),
            ..ChaosSpec::clean(0)
        }),
    ]
}

/// The persistent uplink fault of the C2R head-to-head (α×8, β/16 on
/// the shared inter-node link, every step).
pub fn c2r_uplink_fault() -> ChaosSpec {
    ChaosSpec {
        link_faults: vec![LinkFault {
            node: None,
            alpha_mult: C2R_UPLINK_ALPHA,
            beta_div: C2R_UPLINK_BETA,
            flap: None,
        }],
        ..ChaosSpec::clean(0)
    }
}

/// One routing table per study step for the C2R head-to-head, at the
/// head-to-head's noise level: the collaboration-constrained stream when
/// `constrained`, the unconstrained node-affine stream otherwise (same
/// seeds, so the comparison isolates the constraint).
pub fn c2r_study_tables(constrained: bool) -> Vec<RoutingTable> {
    (0..STUDY_STEPS)
        .map(|s| {
            let seed = STUDY_DRIFT_SEED + s as u64;
            if constrained {
                c2r_routing(32, 8, 32, STUDY_TOKENS_PER_DEVICE, 0, C2R_NOISE,
                            C2R_COLLAB, seed)
            } else {
                drifting_node_affine_routing(32, 8, 32,
                                             STUDY_TOKENS_PER_DEVICE, 0,
                                             C2R_NOISE, seed)
            }
        })
        .collect()
}

/// One grid cell: a chaos timeline over a table stream on the 4-node IB
/// preset with the replace study's payload constants (8 KiB tokens,
/// 128 MiB experts over the 16 GB/s H2D link).
pub fn run_chaos_cell(tables: &[RoutingTable], initial: &Placement,
                      strategy: Strategy, slot: usize,
                      policy: ReplacePolicy,
                      chaos: &ChaosSpec) -> ReplaceOutcome {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let mut cfg = study_config(policy, 1.0);
    cfg.spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, strategy)
        .with_slot(slot);
    run_chaos_timeline(&base, &topo, STUDY_TOKEN_BYTES, tables, initial, &cfg,
                       chaos)
}

/// `(median, p99, p99/median)` over an outcome's per-step makespans —
/// the tail-amplification row of the study table.
pub fn tail_stats(out: &ReplaceOutcome) -> (f64, f64, f64) {
    let ms: Vec<f64> = out.steps.iter().map(|s| s.makespan).collect();
    let med = percentile(&ms, 50.0);
    let p99 = percentile(&ms, 99.0);
    (med, p99, p99 / med)
}

/// `scmoe report chaos` — the robustness grid plus the C2R head-to-head.
pub fn chaos_report(_args: &Args) -> Result<()> {
    let sc = Scenario::FourNodeA800IBx32;
    println!("== chaos robustness study ({}, GPT3-XL payload) ==", sc.label());
    println!("{} steps, {} tokens/dev, {} B tokens; drift noise {:.0}%, \
              seed {}",
             STUDY_STEPS, STUDY_TOKENS_PER_DEVICE, STUDY_TOKEN_BYTES,
             STUDY_DRIFT_NOISE * 100.0, STUDY_DRIFT_SEED);
    println!("faults: jitter {:.0}% (seed {}), stragglers d3 1.5x + d17 \
              2.0x, uplink flap a*{:.0} b/{:.0} on 2-of-4 steps, dropout \
              d{} at step {}",
             CHAOS_JITTER * 100.0, CHAOS_JITTER_SEED, CHAOS_FLAP_ALPHA,
             CHAOS_FLAP_BETA, CHAOS_DROP_DEVICE, CHAOS_DROP_STEP);

    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let placements = [("block", Placement::new(32, 32)),
                      ("affinity", Placement::affinity_packed(&tables[0], 32, 8))];
    let strategies = [("seq", Strategy::Sequential, 0),
                      ("overlap-s2", Strategy::Overlap, 2)];
    let policies = [ReplacePolicy::Never, ReplacePolicy::BreakEven];
    let mut scenarios = vec![("clean", ChaosSpec::clean(0))];
    scenarios.extend(chaos_scenarios());
    for (sname, spec) in &scenarios {
        println!("\n-- {sname} --");
        println!("{:<9} {:<11} {:<11} {:>10} {:>10} {:>6} {:>11} {:>4}",
                 "placement", "strategy", "policy", "median", "p99", "amp",
                 "total", "mig");
        for (pname, init) in &placements {
            for (tname, strategy, slot) in &strategies {
                for policy in policies {
                    let out = run_chaos_cell(&tables, init, *strategy, *slot,
                                             policy, spec);
                    let (med, p99, amp) = tail_stats(&out);
                    println!("{:<9} {:<11} {:<11} {:>10} {:>10} {:>5.2}x \
                              {:>11} {:>4}",
                             pname, tname, policy.label(), fmt_secs(med),
                             fmt_secs(p99), amp, fmt_secs(out.total),
                             out.migrations);
                }
            }
        }
    }

    let drop_spec = &scenarios[3].1;
    let block = &placements[0].1;
    let stat = run_chaos_cell(&tables, block, Strategy::Sequential, 0,
                              ReplacePolicy::Never, drop_spec);
    let be = run_chaos_cell(&tables, block, Strategy::Sequential, 0,
                            ReplacePolicy::BreakEven, drop_spec);
    println!("\ndropout headline: break-even failover {} beats static \
              placement {} ({:.2}x) —",
             fmt_secs(be.total), fmt_secs(stat.total), stat.total / be.total);
    println!("re-learning repacks the post-failover layout instead of \
              living with it");

    println!("\n-- C2R collaboration-constrained routing vs node-affine \
              (noise {:.0}%, collab {}) --",
             C2R_NOISE * 100.0, C2R_COLLAB);
    println!("persistent uplink fault a*{:.0} b/{:.0}; seq, never, \
              affinity-packed on each router's own step-0 table",
             C2R_UPLINK_ALPHA, C2R_UPLINK_BETA);
    println!("{:<8} {:>11} {:>11}", "router", "clean", "degraded");
    let fault = c2r_uplink_fault();
    let mut totals = Vec::new();
    for (rname, constrained) in [("affine", false), ("c2r", true)] {
        let tbl = c2r_study_tables(constrained);
        let init = Placement::affinity_packed(&tbl[0], 32, 8);
        let clean = run_chaos_cell(&tbl, &init, Strategy::Sequential, 0,
                                   ReplacePolicy::Never,
                                   &ChaosSpec::clean(0));
        let deg = run_chaos_cell(&tbl, &init, Strategy::Sequential, 0,
                                 ReplacePolicy::Never, &fault);
        println!("{:<8} {:>11} {:>11}", rname, fmt_secs(clean.total),
                 fmt_secs(deg.total));
        totals.push((clean.total, deg.total));
    }
    println!("c2r headline: the constraint costs {:.0}% on the clean path \
              but bounds fanout to",
             (totals[1].0 / totals[0].0 - 1.0) * 100.0);
    println!("node-local routes — zero uplink exposure, so its degraded \
              run is bit-identical");
    println!("to its clean run while unconstrained routing degrades \
              {:.2}x", totals[0].1 / totals[0].0);
    Ok(())
}
