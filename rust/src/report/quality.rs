//! Quality experiments: real training runs through the AOT artifacts.
//!
//! Each table row = one architecture trained from scratch on the bundled
//! corpus (lm presets) or the synthetic classification proxy (cls presets),
//! evaluated on held-out data. Artifact directories follow the aot.py
//! naming scheme `quality_<arch>_<preset>`; build them with
//! `make artifacts-quality PRESET=<preset> ARCHS=a,b,c`.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::runtime::Engine;
use crate::train::{TrainOptions, Trainer};
use crate::util::cli::Args;

pub fn artifacts_root() -> PathBuf {
    std::env::var("SCMOE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| {
            PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
        })
}

pub struct QualityRun {
    pub arch: String,
    pub eval_loss: f32,
    pub ppl: f32,
    pub acc: f32,
    pub steps: usize,
    pub param_count: usize,
    pub mean_step_secs: f64,
}

/// Train one architecture for `steps` steps and evaluate.
pub fn run_quality(engine: &Arc<Engine>, arch: &str, preset: &str,
                   steps: usize, eval_batches: usize,
                   log_csv: Option<PathBuf>, stats_csv: Option<PathBuf>)
    -> Result<QualityRun> {
    let dir = artifacts_root().join(format!("quality_{arch}_{preset}"));
    if !dir.join("manifest.json").exists() {
        anyhow::bail!(
            "artifacts missing: {} — build with\n  \
             cd python && python -m compile.aot --profile quality \
             --arch {arch} --preset {preset} --out-root ../artifacts",
            dir.display());
    }
    let set = engine.open(&dir).context("opening artifact set")?;
    let mut tr = Trainer::new(&set, 0)?;
    let opts = TrainOptions {
        steps,
        eval_every: 0,
        eval_batches,
        verbose: false,
        log_csv,
        stats_csv,
        ..Default::default()
    };
    tr.run(&opts)?;
    let ev = tr.evaluate(eval_batches)?;
    let mean_step = tr.records.iter().map(|r| r.secs).sum::<f64>()
        / tr.records.len().max(1) as f64;
    Ok(QualityRun {
        arch: arch.to_string(),
        eval_loss: ev.loss,
        ppl: ev.ppl,
        acc: ev.acc,
        steps,
        param_count: set.manifest.param_count,
        mean_step_secs: mean_step,
    })
}

/// Generic architecture-comparison table (Tables 2/3/4/6/7 quality columns).
pub fn table_archs(args: &Args, archs: &[&str], title: &str) -> Result<()> {
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 60);
    let eval_batches = args.usize_or("eval-batches", 4);
    let engine = Arc::new(Engine::cpu()?);
    println!("== {title}: quality comparison ({preset}, {steps} steps) ==");
    println!("{:<14} {:>10} {:>8} {:>8} {:>10} {:>10}",
             "arch", "eval loss", "ppl", "acc", "params", "s/step");
    for arch in archs {
        match run_quality(&engine, arch, &preset, steps, eval_batches, None, None) {
            Ok(r) => println!("{:<14} {:>10.4} {:>8.2} {:>8.3} {:>10} {:>10.2}",
                              r.arch, r.eval_loss, r.ppl, r.acc, r.param_count,
                              r.mean_step_secs),
            Err(e) => println!("{arch:<14} SKIPPED: {e}"),
        }
    }
    Ok(())
}

/// Table 1: shortcut position ablation (Pos-1/2/3) + analytic overlap
/// windows.
pub fn table1(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 60);
    let engine = Arc::new(Engine::cpu()?);
    println!("== Table 1: ScMoE shortcut-position ablation ==");
    println!("{:<14} {:>10} {:>8}   overlap window", "position", "eval loss", "ppl");
    let rows = [("scmoe_pos1", "T_Atten + T_SE"),
                ("scmoe", "T_Atten + T_SE + T_MLP"),
                ("scmoe_pos3", "2*T_Atten + T_SE + T_MLP")];
    for (arch, window) in rows {
        match run_quality(&engine, arch, &preset, steps, 4, None, None) {
            Ok(r) => println!("{:<14} {:>10.4} {:>8.2}   {window}",
                              arch, r.eval_loss, r.ppl),
            Err(e) => println!("{arch:<14} SKIPPED: {e}"),
        }
    }
    Ok(())
}

/// Table 5: shared-expert-gate ablation. Requires artifacts built with
/// `--arch <a>` plus variants without the SE gate (suffix `_nosegate`,
/// built by the Makefile's artifacts-ablation target).
pub fn table5(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 60);
    let engine = Arc::new(Engine::cpu()?);
    println!("== Table 5: SE-Gate ablation ({preset}) ==");
    println!("{:<18} {:>12} {:>14}", "arch", "with gate", "without gate");
    for arch in ["scmoe", "shared"] {
        let with = run_quality(&engine, arch, &preset, steps, 4, None, None);
        let without = run_quality(&engine, &format!("{arch}_nosegate"), &preset,
                                  steps, 4, None, None);
        let f = |r: Result<QualityRun>| match r {
            Ok(q) => format!("{:.4}", q.eval_loss),
            Err(_) => "missing".to_string(),
        };
        println!("{:<18} {:>12} {:>14}", arch, f(with), f(without));
    }
    Ok(())
}

/// Fig. 9: validation loss curves per architecture (CSV output).
pub fn fig9(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 100);
    let out = PathBuf::from(args.str_or("out", "reports"));
    std::fs::create_dir_all(&out).ok();
    let engine = Arc::new(Engine::cpu()?);
    println!("== Fig. 9: training curves -> {}/fig9_<arch>.csv ==", out.display());
    for arch in ["top2", "shared", "scmoe"] {
        let csv = out.join(format!("fig9_{arch}.csv"));
        match run_quality(&engine, arch, &preset, steps, 4, Some(csv.clone()), None) {
            Ok(r) => println!("{arch}: final eval loss {:.4} (ppl {:.2}) -> {}",
                              r.eval_loss, r.ppl, csv.display()),
            Err(e) => println!("{arch}: SKIPPED: {e}"),
        }
    }
    Ok(())
}

/// Fig. 11: shortcut-connection instrumentation (repeat-selection fraction,
/// L2 distance, gating scores) logged during a ScMoE training run.
pub fn fig11(args: &Args) -> Result<()> {
    let preset = args.str_or("preset", "micro");
    let steps = args.usize_or("steps", 100);
    let out = PathBuf::from(args.str_or("out", "reports"));
    std::fs::create_dir_all(&out).ok();
    let engine = Arc::new(Engine::cpu()?);
    let arch = args.str_or("arch", "scmoe");
    let csv = out.join(format!("fig11_{arch}.csv"));
    println!("== Fig. 11: shortcut analysis ({arch}) -> {} ==", csv.display());
    let r = run_quality(&engine, &arch, &preset, steps, 4, None, Some(csv.clone()))?;
    println!("final eval loss {:.4}; stats series written to {}",
             r.eval_loss, csv.display());
    // summarize the last row
    let text = std::fs::read_to_string(&csv)?;
    if let Some(last) = text.lines().last() {
        println!("last stats row: {last}");
    }
    Ok(())
}
