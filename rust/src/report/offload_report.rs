//! Fig. 10: peak GPU memory + MoE block latency under expert offloading.

use anyhow::Result;

use crate::cluster::LinkModel;
use crate::offload::{simulate_decode, DecodeCosts, OffloadConfig, Policy};
use crate::util::cli::Args;
use crate::util::stats::{fmt_bytes, fmt_secs};

/// Parameter-count models of the paper's two offloading subjects, derived
/// from Appendix Table 8 shapes (f32 bytes). The H2D migration-to-compute
/// ratio is calibrated to the paper's measured blocking overhead (+80% for
/// Medium, +240% for XL — Fig. 10b); the async-migration savings then
/// *emerge* from the ScMoE overlap window, they are not fitted.
pub fn gpt2_moe_medium() -> OffloadConfig {
    offload_config(24, 1024, 4096, 8, 2, 0.8)
}

pub fn gpt3_moe_xl() -> OffloadConfig {
    offload_config(24, 2048, 8192, 8, 2, 2.4)
}

fn offload_config(n_layers: usize, d: usize, f: usize, e: usize, k: usize,
                  blocking_ratio: f64) -> OffloadConfig {
    let expert_bytes = (d * f + f + f * d + d) * 4;
    let n_moe = n_layers / 2;
    // resident: embeddings + attention + LN + dense MLPs + shared experts
    let attn_block = (4 * d * d + 4 * d + 4 * d) * 4;
    let mlp_block = expert_bytes;
    let resident = 50257 * d * 4                 // embeddings (GPT-2 vocab)
        + n_layers * attn_block
        + (n_layers - n_moe) * mlp_block         // dense blocks
        + n_moe * mlp_block;                     // shared experts stay on GPU
    // per-token decode costs on a single A30: memory-bound GEMV; scale with
    // bytes touched (≈ params of the op) over A30 HBM bandwidth (~933 GB/s
    // effective ~600).
    let bw = 600e9;
    let costs = DecodeCosts {
        attn: (4 * d * d) as f64 * 4.0 / bw,
        mlp: (2 * d * f) as f64 * 4.0 / bw,
        se: (2 * d * f) as f64 * 4.0 / bw,
        gate: (d * e) as f64 * 4.0 / bw + 2e-6,
        expert: k as f64 * (2 * d * f) as f64 * 4.0 / bw,
    };
    // calibrate H2D so blocking migration adds `blocking_ratio` x pair time
    let pair = costs.attn * 2.0 + costs.mlp + costs.se + costs.gate + costs.expert;
    let mig_target = blocking_ratio * pair;
    let beta = (k * expert_bytes) as f64 / mig_target;
    OffloadConfig {
        n_moe_layers: n_moe,
        static_buffers: true,
        n_experts: e,
        k,
        expert_bytes,
        resident_bytes: resident,
        h2d: LinkModel::new(15e-6, beta),
        costs,
    }
}

pub fn fig10(args: &Args) -> Result<()> {
    let tokens = args.usize_or("tokens", 64);
    println!("== Fig. 10: memory-limited inference (single A30 proxy) ==");
    for (name, cfg) in [("GPT2-MoE-Medium", gpt2_moe_medium()),
                        ("GPT3-MoE-XL", gpt3_moe_xl())] {
        println!("\n--- {name} (expert = {}, resident = {}) ---",
                 fmt_bytes(cfg.expert_bytes as f64),
                 fmt_bytes(cfg.resident_bytes as f64));
        let gpu = simulate_decode(&cfg, None, tokens, Policy::GpuOnly, 42);
        let blk = simulate_decode(&cfg, None, tokens, Policy::Blocking, 42);
        let asy = simulate_decode(&cfg, None, tokens, Policy::AsyncDeterminate, 42);
        let spec = simulate_decode(&cfg, None, tokens,
                                   Policy::Speculative { accuracy: 0.85 }, 42);
        println!("{:<18} {:>12} {:>14} {:>16}", "policy", "peak GPU", "block latency",
                 "exposed migr.");
        for r in [&gpu, &blk, &asy, &spec] {
            println!("{:<18} {:>12} {:>14} {:>16}",
                     r.policy.label(),
                     fmt_bytes(r.peak_gpu_bytes as f64),
                     fmt_secs(r.block_latency),
                     fmt_secs(r.exposed_migration));
        }
        let mem_cut = 100.0 * (1.0 - blk.peak_gpu_bytes as f64 / gpu.peak_gpu_bytes as f64);
        let extra_blocking = blk.block_latency / gpu.block_latency - 1.0;
        let extra_async = asy.block_latency / gpu.block_latency - 1.0;
        let cut = if extra_blocking > 0.0 {
            100.0 * (1.0 - extra_async / extra_blocking)
        } else {
            0.0
        };
        println!("peak memory reduction: {mem_cut:.0}%   \
                  migration overhead cut by async: {cut:.0}%");
    }
    println!("\npaper: −50%/−60% peak memory; blocking adds +80%/+240% latency;");
    println!("       async migration cuts the added cost by 75%/25%");
    Ok(())
}
