//! The whole-model study (`scmoe report model`): does pipeline-parallel
//! depth change which placements and schedules win?
//!
//! A 4-layer model on the 32xA800-4node-IB preset (GPT3-XL payload,
//! 8 KiB tokens, 2 pipeline stages): layer 0 routes every token
//! uniformly (its home node predicts nothing), while each deeper layer
//! follows a near-deterministic `+5 mod 32` expert transition from its
//! predecessor (noise 5%) — correlated inter-layer routing of the kind
//! ExFlow measures. Because a deterministic expert→expert permutation
//! propagates any home-affinity tilt perfectly, per-layer packing
//! co-places chains by accident whenever layer 0 is node-affine; with
//! the home signal flat, the measured transition matrix is the *only*
//! signal that sees the chains, so ExFlow-style cross-layer co-placement
//! ([`PlacementMode::CrossLayer`]) strictly beats independent per-layer
//! affinity packing on the total L-layer makespan, which per-layer
//! packing cannot reliably beat block under at all.
//!
//! The grid crosses placement (block / per-layer / cross-layer) ×
//! pipeline schedule (layer-sequential / GPipe / 1F1B) × microbatches
//! (1 / 4). At M = 1 every schedule degenerates to the same graph; at
//! M = 4 both pipelined schedules strictly beat layer-sequential by
//! overlapping layer-l±1 expert compute with layer-l All-to-All across
//! stages. A live row runs the break-even policy from the block
//! placement with source-side D2H pricing (32 GB/s read-out feeding the
//! 16 GB/s H2D write per move).
//!
//! Every pinned number is minted through the DES mirror
//! (`tools/des_mirror/mirror2.py --model-study`, PR8 model) and pinned
//! in `rust/tests/model_timeline.rs`. The same scenario constants are
//! exported so `timeline_explorer --model` renders the identical runs.

use anyhow::Result;

use crate::cluster::{LinkModel, Scenario};
use crate::coordinator::costs::{MoEKind, Strategy};
use crate::coordinator::model::{
    run_model_timeline, ModelConfig, ModelOutcome, ModelSpec,
    PipelineSchedule, PlacementMode,
};
use crate::coordinator::replace::ReplacePolicy;
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::{
    co_placed, correlated_layer_routing, phase_affine_routing,
    AffinityEstimator, Placement, RoutingTable, TransitionEstimator,
};
use crate::util::cli::Args;
use crate::util::stats::fmt_secs;

use super::efficiency::xl_compute_costs;
use super::replace::{study_h2d_link, STUDY_BYTES_PER_EXPERT,
                     STUDY_TOKEN_BYTES, STUDY_TOKENS_PER_DEVICE};

/// Model depth (layers).
pub const MODEL_LAYERS: usize = 4;
/// Pipeline stages the layers divide into.
pub const MODEL_STAGES: usize = 2;
/// Microbatches in the pipelined grid column.
pub const MODEL_MICROBATCHES: usize = 4;
/// Steps per study timeline.
pub const MODEL_STEPS: usize = 4;
/// Base seed (step `s`, layer `l` draws from seed + 100·s + l).
pub const MODEL_SEED: u64 = 211;
/// Layer-0 per-token random-routing probability: 1.0 — fully uniform,
/// so home-anchored affinity counts are flat and only the inter-layer
/// transition carries placement signal (see the module doc).
pub const MODEL_NOISE: f64 = 1.0;
/// Deep-layer transition noise (tokens that scatter off the chain).
pub const MODEL_CORR_NOISE: f64 = 0.05;
/// Inter-layer expert stride: layer l+1 routes to `(e + 5) mod 32`.
pub const MODEL_STRIDE: usize = 5;

/// The modeled device-to-host read-out link of the live row (NVLink-C2C
/// class, faster than the H2D write so the pipeline stays H2D-bound).
pub fn study_d2h_link() -> LinkModel {
    LinkModel::new(10e-6, 32e9)
}

/// One row of per-layer routing tables per step: layer 0 uniform,
/// deeper layers chained by [`correlated_layer_routing`].
pub fn model_tables() -> Vec<Vec<RoutingTable>> {
    (0..MODEL_STEPS)
        .map(|s| {
            let seed0 = MODEL_SEED + 100 * s as u64;
            let mut row = vec![phase_affine_routing(
                32, 8, 32, 32 * STUDY_TOKENS_PER_DEVICE, 0, 0, MODEL_NOISE,
                MODEL_NOISE, seed0)];
            for l in 1..MODEL_LAYERS {
                let next = correlated_layer_routing(
                    &row[l - 1], 32, MODEL_STRIDE, MODEL_CORR_NOISE,
                    seed0 + l as u64);
                row.push(next);
            }
            row
        })
        .collect()
}

/// Warm-started per-layer and cross-layer placements from the step-0
/// tables (counting estimators, one observation each) — the static
/// endpoints of the grid.
pub fn model_grid_placements(tables0: &[RoutingTable])
                             -> (Vec<Placement>, Vec<Placement>) {
    let mut ests: Vec<AffinityEstimator> = (0..MODEL_LAYERS)
        .map(|_| AffinityEstimator::counting(32, 4))
        .collect();
    for (l, rt) in tables0.iter().enumerate() {
        ests[l].observe(rt, 32, 8);
    }
    let mut trans: Vec<TransitionEstimator> = (0..MODEL_LAYERS - 1)
        .map(|_| TransitionEstimator::counting(32))
        .collect();
    for l in 0..MODEL_LAYERS - 1 {
        trans[l].observe(&tables0[l], &tables0[l + 1]);
    }
    let per: Vec<Placement> = ests.iter().map(|e| e.packed(32, 8)).collect();
    let mut cross = vec![ests[0].packed(32, 8)];
    for l in 1..MODEL_LAYERS {
        let prev = cross[l - 1].clone();
        cross.push(co_placed(ests[l].matrix(), &trans[l - 1], &prev, 32, 8));
    }
    (per, cross)
}

/// The study's [`ModelSpec`]: sequential ScMoE at every layer (the
/// strategy where placement effects are largest), 2 pipeline stages.
pub fn model_spec(microbatches: usize,
                  schedule: PipelineSchedule) -> ModelSpec {
    ModelSpec {
        layers: vec![ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                       Strategy::Sequential); MODEL_LAYERS],
        stages: MODEL_STAGES,
        microbatches,
        schedule,
    }
}

/// The study's [`ModelConfig`] for one cell.
pub fn model_config(microbatches: usize, schedule: PipelineSchedule,
                    policy: ReplacePolicy, mode: PlacementMode,
                    d2h: Option<LinkModel>) -> ModelConfig {
    ModelConfig {
        spec: model_spec(microbatches, schedule),
        policy,
        bytes_per_expert: STUDY_BYTES_PER_EXPERT,
        h2d: study_h2d_link(),
        d2h,
        decay: 1.0,
        mode,
    }
}

/// Run one cell over the study tables on the 4-node IB preset.
pub fn run_model_cell(tables: &[Vec<RoutingTable>], initial: &[Placement],
                      cfg: &ModelConfig) -> ModelOutcome {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    run_model_timeline(&base, &topo, STUDY_TOKEN_BYTES, tables, initial, cfg)
}

/// `scmoe report model` — the placement × schedule × microbatch grid
/// plus the live break-even row.
pub fn model_report(_args: &Args) -> Result<()> {
    let sc = Scenario::FourNodeA800IBx32;
    println!("== whole-model pipeline study ({}, GPT3-XL payload) ==",
             sc.label());
    println!("{} layers / {} stages, {} steps x {} tokens; layer 0 uniform, \
              deeper layers +{} mod 32 at {:.0}% noise",
             MODEL_LAYERS, MODEL_STAGES, MODEL_STEPS,
             32 * STUDY_TOKENS_PER_DEVICE, MODEL_STRIDE,
             MODEL_CORR_NOISE * 100.0);

    let tables = model_tables();
    let (per, cross) = model_grid_placements(&tables[0]);
    let block: Vec<Placement> = (0..MODEL_LAYERS)
        .map(|_| Placement::new(32, 32))
        .collect();

    println!("\n-- total {}-layer makespan: placement x schedule x \
              microbatches --", MODEL_LAYERS);
    println!("{:>3} {:<10} {:<12} {:>12}", "m", "schedule", "placement",
             "total");
    for m in [1, MODEL_MICROBATCHES] {
        for schedule in [PipelineSchedule::LayerSequential,
                         PipelineSchedule::GPipe,
                         PipelineSchedule::OneFOneB] {
            for (name, initial) in [("block", &block), ("per-layer", &per),
                                    ("cross-layer", &cross)] {
                let cfg = model_config(m, schedule, ReplacePolicy::Never,
                                       PlacementMode::PerLayer, None);
                let out = run_model_cell(&tables, initial, &cfg);
                println!("{:>3} {:<10} {:<12} {:>12}", m, schedule.label(),
                         name, fmt_secs(out.total));
            }
        }
    }
    println!("at m = 1 every schedule builds the same graph; at m = {} the \
              pipelined schedules", MODEL_MICROBATCHES);
    println!("overlap layer-l A2A with layer-l±1 expert compute across \
              stages, and only the");
    println!("transition-aware cross-layer packer sees the inter-layer \
              chains (per-layer");
    println!("affinity counts are flat when the home node predicts nothing)");

    println!("\n-- live re-placement: block start, break-even policy, \
              cross-layer candidates --");
    let cfg = model_config(MODEL_MICROBATCHES, PipelineSchedule::GPipe,
                           ReplacePolicy::BreakEven,
                           PlacementMode::CrossLayer,
                           Some(study_d2h_link()));
    let out = run_model_cell(&tables, &block, &cfg);
    println!("{:<5} {:>12} {:>12} {:>10}", "step", "makespan", "base", "d2h+h2d");
    for st in &out.steps {
        println!("{:<4}{} {:>12} {:>12} {:>10}",
                 st.step, if st.migrated { "*" } else { " " },
                 fmt_secs(st.makespan), fmt_secs(st.base_makespan),
                 if st.migrated { fmt_secs(st.migration_time) }
                 else { "-".into() });
    }
    println!("totals: {} over {} steps; {} migration(s), each D2H read-out \
              ({:.0} GB/s) feeding",
             fmt_secs(out.total), MODEL_STEPS, out.migrations,
             study_d2h_link().beta / 1e9);
    println!("its H2D write ({:.0} GB/s) on the owning stage's engines",
             study_h2d_link().beta / 1e9);
    Ok(())
}
