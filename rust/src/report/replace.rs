//! The live re-placement study (`scmoe report replace`): when does
//! migrating to a measured-affinity placement pay for itself?
//!
//! Two scenarios on the 32xA800-4node-IB preset (GPT3-XL payload, 8 KiB
//! tokens), both driven by [`run_replace_timeline`] over seeded
//! [`drifting_node_affine_routing`] streams:
//!
//! - **A (stable drift)** — node-affine routing with 5% per-token noise,
//!   counting estimator, starting from the uniform block placement. The
//!   break-even policy migrates once at step 0 (128 MiB/expert over a
//!   16 GB/s H2D link stretches that step), then every later step runs
//!   node-local; the cumulative makespan crosses below static-uniform at
//!   a pinned break-even step count.
//! - **B (regime shift)** — the node→group affinity rotates at step 8
//!   under 15% noise (EWMA decay 0.5). Eager every-step re-placement
//!   churns (a migration nearly every step, each repaying little), while
//!   the break-even threshold migrates exactly twice: once at warmup and
//!   once after the shift — strictly beating both eager and never.
//!
//! Every pinned number is minted through the DES mirror
//! (`tools/des_mirror/mirror2.py --study`, PR5 model) and pinned in
//! `rust/tests/replace_timeline.rs`. The same scenario constants are
//! exported so `timeline_explorer --replace` renders the identical runs.

use anyhow::Result;

use crate::cluster::{LinkModel, Scenario};
use crate::coordinator::costs::{MoEKind, Strategy};
use crate::coordinator::replace::{
    run_replace_timeline, ReplaceConfig, ReplaceOutcome, ReplacePolicy,
};
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::{Placement, RoutingTable};
use crate::util::cli::Args;
use crate::util::stats::fmt_secs;

use super::efficiency::{drifting_node_affine_routing, xl_compute_costs};

/// Steps per study timeline.
pub const STUDY_STEPS: usize = 16;
/// Step at which scenario B's routing regime rotates.
pub const STUDY_SHIFT_STEP: usize = 8;
/// Tokens per device per step (matches the routed placement study).
pub const STUDY_TOKENS_PER_DEVICE: usize = 640;
/// Payload bytes per routed token copy (GPT3-XL, 8 KiB).
pub const STUDY_TOKEN_BYTES: usize = 8192;
/// Parameter bytes per migrated expert (128 MiB — a GPT3-XL-class FFN
/// expert in bf16).
pub const STUDY_BYTES_PER_EXPERT: usize = 128 * 1024 * 1024;
/// Scenario A per-token noise / base seed.
pub const STUDY_DRIFT_NOISE: f64 = 0.05;
/// Scenario A base seed (step s draws from seed + s).
pub const STUDY_DRIFT_SEED: u64 = 11;
/// Scenario B per-token noise / base seed / estimator decay.
pub const STUDY_SHIFT_NOISE: f64 = 0.15;
/// Scenario B base seed.
pub const STUDY_SHIFT_SEED: u64 = 211;
/// Scenario B estimator decay (EWMA; scenario A uses counting = 1.0).
pub const STUDY_SHIFT_DECAY: f64 = 0.5;

/// The modeled host-to-device migration link (PCIe-gen4-class 16 GB/s).
pub fn study_h2d_link() -> LinkModel {
    LinkModel::new(10e-6, 16e9)
}

/// One routing table per step: drifting node-affine routing on the
/// 32-device fleet, with the regime rotated from `shift_at` onward.
pub fn study_tables(noise: f64, seed0: u64,
                    shift_at: Option<usize>) -> Vec<RoutingTable> {
    (0..STUDY_STEPS)
        .map(|s| {
            let regime = match shift_at {
                Some(at) if s >= at => 1,
                _ => 0,
            };
            drifting_node_affine_routing(32, 8, 32, STUDY_TOKENS_PER_DEVICE,
                                         regime, noise, seed0 + s as u64)
        })
        .collect()
}

/// The study's [`ReplaceConfig`]: sequential ScMoE steps (the strategy
/// where placement effects are largest), the pinned per-expert bytes and
/// H2D link.
pub fn study_config(policy: ReplacePolicy, decay: f64) -> ReplaceConfig {
    ReplaceConfig {
        spec: ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential),
        policy,
        bytes_per_expert: STUDY_BYTES_PER_EXPERT,
        h2d: study_h2d_link(),
        d2h_link: None,
        decay,
    }
}

/// Run one policy over a study table stream from the uniform block
/// placement on the 4-node IB preset.
pub fn run_study(tables: &[RoutingTable], policy: ReplacePolicy,
                 decay: f64) -> ReplaceOutcome {
    let topo = Scenario::FourNodeA800IBx32.topology();
    let base = xl_compute_costs();
    let initial = Placement::new(32, 32);
    run_replace_timeline(&base, &topo, STUDY_TOKEN_BYTES, tables, &initial,
                         &study_config(policy, decay))
}

/// First step count (1-based) at which the replacing run's cumulative
/// makespan drops strictly below the static run's; `None` if it never
/// does within the timeline.
pub fn break_even_step(static_run: &ReplaceOutcome,
                       replace_run: &ReplaceOutcome) -> Option<usize> {
    let mut cum_s = 0.0f64;
    let mut cum_r = 0.0f64;
    for (a, b) in static_run.steps.iter().zip(&replace_run.steps) {
        cum_s += a.makespan;
        cum_r += b.makespan;
        if cum_r < cum_s {
            return Some(a.step + 1);
        }
    }
    None
}

/// `M`/`.` per step: which steps fired a migration.
pub fn migration_marks(outcome: &ReplaceOutcome) -> String {
    outcome.steps.iter().map(|s| if s.migrated { 'M' } else { '.' }).collect()
}

/// `scmoe report replace` — both scenarios, tabulated.
pub fn replace_report(_args: &Args) -> Result<()> {
    let sc = Scenario::FourNodeA800IBx32;
    println!("== live re-placement study ({}, GPT3-XL payload) ==",
             sc.label());
    println!("{} steps, {} tokens/dev, {} B tokens; migrations move {} MiB \
              per expert over a {:.0} GB/s H2D link",
             STUDY_STEPS, STUDY_TOKENS_PER_DEVICE, STUDY_TOKEN_BYTES,
             STUDY_BYTES_PER_EXPERT >> 20, study_h2d_link().beta / 1e9);

    println!("\n-- scenario A: stable drift (noise {:.0}%, counting \
              estimator, seed {}) --",
             STUDY_DRIFT_NOISE * 100.0, STUDY_DRIFT_SEED);
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let static_run = run_study(&tables, ReplacePolicy::Never, 1.0);
    let replace_run = run_study(&tables, ReplacePolicy::BreakEven, 1.0);
    println!("{:<5} {:>12} {:>12} {:>10} {:>12} {:>12}",
             "step", "static", "replace", "h2d", "cum-static", "cum-replace");
    let mut cum_s = 0.0f64;
    let mut cum_r = 0.0f64;
    for (a, b) in static_run.steps.iter().zip(&replace_run.steps) {
        cum_s += a.makespan;
        cum_r += b.makespan;
        println!("{:<4}{} {:>12} {:>12} {:>10} {:>12} {:>12}",
                 a.step, if b.migrated { "*" } else { " " },
                 fmt_secs(a.makespan), fmt_secs(b.makespan),
                 if b.migrated { fmt_secs(b.migration_time) } else { "-".into() },
                 fmt_secs(cum_s), fmt_secs(cum_r));
    }
    match break_even_step(&static_run, &replace_run) {
        Some(n) => println!("break-even: migrate-then-run strictly beats \
                             static-uniform from step {n} on"),
        None => println!("break-even: not reached within {STUDY_STEPS} steps"),
    }
    println!("totals: static {} | replace {} ({:.2}x); {} migration(s)",
             fmt_secs(static_run.total), fmt_secs(replace_run.total),
             static_run.total / replace_run.total, replace_run.migrations);

    println!("\n-- scenario B: regime shift at step {} (noise {:.0}%, EWMA \
              decay {}, seed {}) --",
             STUDY_SHIFT_STEP, STUDY_SHIFT_NOISE * 100.0, STUDY_SHIFT_DECAY,
             STUDY_SHIFT_SEED);
    let tables = study_tables(STUDY_SHIFT_NOISE, STUDY_SHIFT_SEED,
                              Some(STUDY_SHIFT_STEP));
    println!("{:<12} {:>12} {:>11}  {:<16}",
             "policy", "total", "migrations", "timeline");
    for policy in [ReplacePolicy::Never, ReplacePolicy::EveryK { k: 1 },
                   ReplacePolicy::BreakEven] {
        let run = run_study(&tables, policy, STUDY_SHIFT_DECAY);
        println!("{:<12} {:>12} {:>11}  {}",
                 policy.label(), fmt_secs(run.total), run.migrations,
                 migration_marks(&run));
    }
    println!("eager re-placement churns under drift noise (a migration \
              nearly every step, each");
    println!("repaying little); the break-even threshold migrates once at \
              warmup and once after");
    println!("the shift, strictly beating both eager and static");
    Ok(())
}
