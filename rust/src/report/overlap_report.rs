//! `scmoe report overlap` — the analysis layer's headline study: for each
//! architecture × strategy on the 4-node IB fleet (GPT3-XL payload), where
//! did the makespan actually go (critical-path attribution), and how much
//! All-to-All hid behind compute (hidden-comm fraction)?
//!
//! The grid makes the paper's overlap claim quantitative: the sequential
//! baseline's dispatch/combine phases sit on the critical path almost
//! whole, while the adaptive ScMoE schedule's hidden fraction rises and
//! the exposed A2A attribution collapses into backbone compute. One
//! replace row (the drift study's migration step) shows H2D traffic
//! entering the attribution, and one whole-model row adds the per-stage
//! pipeline bubble view. Every number printed here is minted by
//! `tools/des_mirror/mirror2.py --overlap-study` and pinned in
//! docs/STUDIES.md.

use anyhow::Result;

use crate::analyze::{attribute, comm_overlap, critical_path, stage_bubbles,
                     utilization};
use crate::cluster::Scenario;
use crate::coordinator::costs::{MoEKind, Strategy, TopoCosts};
use crate::coordinator::model::{build_model_sim, model_layer_costs,
                                PipelineSchedule};
use crate::coordinator::replace::{MigrationPlan, ReplacePolicy};
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::{AffinityEstimator, Placement};
use crate::report::efficiency::{xl_compute_costs, xl_topo_proxy_costs};
use crate::report::model_report::{model_grid_placements, model_spec,
                                  model_tables, MODEL_MICROBATCHES,
                                  MODEL_STAGES};
use crate::report::replace::{study_config, study_tables, STUDY_DRIFT_NOISE,
                             STUDY_DRIFT_SEED, STUDY_TOKEN_BYTES};
use crate::simtime::{Resource, Sim};
use crate::util::cli::Args;

/// One grid row: attribution (ms), hidden-comm %, mean compute
/// utilization %, critical-path task count.
fn print_row(name: &str, sim: &Sim, devices_per_node: usize) {
    let run = sim.run_traced();
    let a = attribute(&run);
    let ov = comm_overlap(&run.spans, devices_per_node);
    let crit = critical_path(&run).len();
    let comps: Vec<f64> = utilization(&run.spans)
        .iter()
        .filter(|u| matches!(u.resource, Resource::Compute(_)))
        .map(|u| u.utilization)
        .collect();
    let cu = comps.iter().sum::<f64>() / comps.len() as f64;
    println!(
        "{:<26} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>8.3} {:>6.1}% \
         {:>6.1}% {:>5}",
        name, a.makespan * 1e3, a.backbone * 1e3, a.expert * 1e3,
        a.dispatch * 1e3, a.combine * 1e3, a.migration * 1e3,
        ov.hidden_fraction() * 100.0, cu * 100.0, crit
    );
}

fn header() {
    println!(
        "{:<26} {:>8} {:>8} {:>8} {:>8} {:>8} {:>8} {:>7} {:>7} {:>5}",
        "row", "total", "backbone", "expert", "dispatch", "combine", "migr",
        "hidden", "util", "crit"
    );
}

pub fn overlap_report(_args: &Args) -> Result<()> {
    let sc = Scenario::FourNodeA800IBx32;
    let topo = sc.topology();
    let dpn = topo.devices_per_node;
    let tc = xl_topo_proxy_costs(sc);
    println!("== makespan attribution x hidden comm ({}, GPT3-XL proxy; \
              all columns ms) ==", sc.label());
    header();

    print_row(
        "top2/seq",
        &ScheduleSpec::new(MoEKind::Standard { k: 2 }, Strategy::Sequential)
            .build(&tc)
            .sim,
        dpn,
    );
    print_row(
        "top2/pipe2",
        &ScheduleSpec::new(MoEKind::Standard { k: 2 },
                           Strategy::Pipelined { chunks: 2 })
            .build(&tc)
            .sim,
        dpn,
    );
    let kind = MoEKind::ScMoE { k: 1 };
    let ovl = ScheduleSpec::new(kind, Strategy::Overlap);
    let (slot, _) = ovl.choose_slot(&tc);
    print_row(
        &format!("scmoe/ovl (slot {})", slot + 1),
        &ovl.with_slot(slot).build(&tc).sim,
        dpn,
    );
    let opipe = ScheduleSpec::new(kind,
                                  Strategy::OverlapPipelined { chunks: 2 });
    let (oslot, _) = opipe.choose_slot(&tc);
    print_row(
        &format!("scmoe/ovl+pipe2 (slot {})", oslot + 1),
        &opipe.with_slot(oslot).build(&tc).sim,
        dpn,
    );

    // the drift study's migration step: block layout + measured-affinity
    // MigrationPlan's H2D transfers (same reconstruction as
    // `timeline_explorer --replace`), so `migr` finally shows up in the
    // attribution when the transfer engines outlast the step's compute
    let base = xl_compute_costs();
    let cfg = study_config(ReplacePolicy::BreakEven, 1.0);
    let tables = study_tables(STUDY_DRIFT_NOISE, STUDY_DRIFT_SEED, None);
    let block = Placement::new(32, 32);
    let mut est = AffinityEstimator::ewma(32, topo.n_nodes(), cfg.decay);
    est.observe(&tables[0], topo.n_devices, topo.devices_per_node);
    let measured = est.packed(topo.n_devices, topo.devices_per_node);
    let plan = MigrationPlan::between(&block, &measured, cfg.bytes_per_expert);
    let rtc = TopoCosts::from_routing(&base, &topo, &tables[0], &block,
                                      STUDY_TOKEN_BYTES);
    let mut sched = cfg.spec.build(&rtc);
    plan.add_h2d_tasks(&mut sched.sim, &cfg.h2d);
    print_row("replace/migrate-step", &sched.sim, dpn);

    // one whole-model pipeline row (GPipe at the study's microbatch
    // count, cross-layer placements) plus its per-stage bubble fractions
    println!("\n== whole-model pipeline (GPipe, m = {MODEL_MICROBATCHES}, \
              cross-layer placements) ==");
    header();
    let mtables = model_tables();
    let (_, cross) = model_grid_placements(&mtables[0]);
    let spec = model_spec(MODEL_MICROBATCHES, PipelineSchedule::GPipe);
    let costs = model_layer_costs(&base, &topo, STUDY_TOKEN_BYTES,
                                  &mtables[0], &cross, MODEL_MICROBATCHES);
    let (sim, _) = build_model_sim(&spec, &costs, topo.n_devices,
                                   topo.n_nodes());
    print_row("model/gpipe-m4", &sim, dpn);
    let bub = stage_bubbles(&sim.run(), MODEL_STAGES, topo.n_devices);
    let marks: Vec<String> = bub
        .iter()
        .enumerate()
        .map(|(s, b)| format!("s{s} {:.1}%", b * 100.0))
        .collect();
    println!("stage bubbles: {}", marks.join("  "));

    println!("\nhidden = comm time concurrent with compute on the same \
              device (comm stream) or node (uplink);");
    println!("util = mean compute-stream busy fraction; crit = tasks on \
              the realized critical path;");
    println!("attribution columns partition the makespan by \
              critical-path task category (exact)");
    Ok(())
}
