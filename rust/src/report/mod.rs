//! Experiment harness: regenerates every table and figure of the paper's
//! evaluation (see DESIGN.md §4 for the experiment index).
//!
//! Efficiency results (Fig. 1/6/8, the speedup columns of Tables 2-4) come
//! from the DES executing the coordinator's real schedules under the
//! calibrated hardware presets; quality results (accuracy/perplexity
//! columns, Tables 1/5/6/7, Fig. 9/11) come from real training runs through
//! the AOT artifacts. Offloading (Fig. 10) uses the decode simulator with
//! real parameter byte counts.

pub mod chaos;
pub mod efficiency;
pub mod model_report;
pub mod offload_report;
pub mod overlap_report;
pub mod quality;
pub mod replace;
pub mod serve_report;

use anyhow::{bail, Result};

use crate::util::cli::Args;

pub fn run(exp: &str, args: &Args) -> Result<()> {
    match exp {
        "fig1" => efficiency::fig1(args),
        "fig6" => efficiency::fig6(args),
        "fig8" => efficiency::fig8(args),
        "speedups" | "table2-speedup" | "table3-speedup" | "table4-speedup" => {
            efficiency::speedup_tables(args)
        }
        "topo" | "fleet" => efficiency::topo_report(args),
        "overlap" => overlap_report::overlap_report(args),
        "replace" => replace::replace_report(args),
        "serve" => serve_report::serve_report(args),
        "model" => model_report::model_report(args),
        "chaos" => chaos::chaos_report(args),
        "fig10" => offload_report::fig10(args),
        "table1" => quality::table1(args),
        "table2" => quality::table_archs(args, &["top2", "top1", "shared", "scmoe"], "table2"),
        "table3" => quality::table_archs(args, &["top2", "shared", "scmoe"], "table3"),
        "table4" => quality::table_archs(args, &["top2", "scmoe", "top3", "scmoe2"], "table4"),
        "table5" => quality::table5(args),
        "table6" | "table7" => quality::table_archs(
            args, &["top2", "top1", "shared", "dgmoe", "scmoe"], exp),
        "fig9" => quality::fig9(args),
        "fig11" => quality::fig11(args),
        "a5" => quality::table_archs(args, &["top1", "dgmoe", "dgmoe_share"], "a5"),
        "all-efficiency" => {
            efficiency::fig1(args)?;
            efficiency::fig6(args)?;
            efficiency::fig8(args)?;
            efficiency::speedup_tables(args)?;
            offload_report::fig10(args)
        }
        other => bail!("unknown experiment {other:?}; see DESIGN.md §4"),
    }
}
