//! `scmoe` — the L3 coordinator CLI.
//!
//! Subcommands:
//!   train        train a quality artifact set (loss curve + eval)
//!   report EXP   regenerate a paper table/figure (fig1, fig6, fig8, fig9,
//!                fig10, fig11, table1..table7, speedups, a5, all-efficiency)
//!   timeline     render one architecture×strategy schedule
//!   offload-sim  run the decode-offloading simulator
//!   bench-calib  measure operator wallclock on the CPU artifacts
//!   inspect DIR  dump a manifest's artifact interface

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use scmoe::cluster::Scenario;
use scmoe::coordinator::costs::{MoEKind, Strategy};
use scmoe::coordinator::spec::ScheduleSpec;
use scmoe::coordinator::timeline;
use scmoe::report;
use scmoe::runtime::Engine;
use scmoe::train::{TrainOptions, Trainer};
use scmoe::util::cli::Args;

const USAGE: &str = "\
usage: scmoe <command> [options]
  train        --arch scmoe --preset micro --steps 100 [--log out.csv]
  report       <fig1|fig6|fig8|fig9|fig10|fig11|table1..7|speedups|topo|replace|serve|model|chaos|a5|all-efficiency>
  timeline     --kind <top2|top1|shared|scmoe|scmoe2> --strategy <seq|pipe|overlap|overlap-pipe>
  offload-sim  [--tokens 64]
  bench-calib  [--dir artifacts/ops_tiny] [--reps 5]
  inspect      <manifest-dir>
";

fn main() -> Result<()> {
    let args = Args::from_env();
    let Some(cmd) = args.positional.first().map(|s| s.as_str()) else {
        print!("{USAGE}");
        return Ok(());
    };
    match cmd {
        "train" => cmd_train(&args),
        "report" => {
            let Some(exp) = args.positional.get(1) else {
                bail!("report needs an experiment id; see DESIGN.md §4");
            };
            report::run(exp, &args)
        }
        "timeline" => cmd_timeline(&args),
        "offload-sim" => report::offload_report::fig10(&args),
        "bench-calib" => cmd_calib(&args),
        "inspect" => cmd_inspect(&args),
        other => {
            print!("{USAGE}");
            bail!("unknown command {other:?}");
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let arch = args.str_or("arch", "scmoe");
    let preset = args.str_or("preset", "micro");
    let dir = report::quality::artifacts_root().join(format!("quality_{arch}_{preset}"));
    let engine = Arc::new(Engine::cpu()?);
    let set = engine.open(&dir)?;
    println!("training {arch}/{preset}: {} params, task={}",
             set.manifest.param_count, set.manifest.config.task);
    let mut tr = Trainer::new(&set, args.usize_or("seed", 0) as i32)?;
    let opts = TrainOptions {
        steps: args.usize_or("steps", 100),
        eval_every: args.usize_or("eval-every", 50),
        eval_batches: args.usize_or("eval-batches", 4),
        log_csv: args.str_opt("log").map(PathBuf::from),
        stats_csv: args.str_opt("stats-log").map(PathBuf::from),
        verbose: !args.flag("quiet"),
        seed: 0,
    };
    tr.run(&opts)?;
    let ev = tr.evaluate(opts.eval_batches)?;
    println!("final: eval loss {:.4}  ppl {:.2}  acc {:.3}", ev.loss, ev.ppl, ev.acc);
    if let Some(ckpt) = args.str_opt("checkpoint") {
        scmoe::train::checkpoint::save(
            &PathBuf::from(ckpt), &set.manifest, &tr.params_host()?)?;
        println!("checkpoint written to {ckpt}");
    }
    Ok(())
}

fn cmd_timeline(args: &Args) -> Result<()> {
    let sc = Scenario::parse(&args.str_or("scenario", "pcie"))
        .unwrap_or(Scenario::PcieA30x8);
    let kind = match args.str_or("kind", "scmoe").as_str() {
        "top1" => MoEKind::Standard { k: 1 },
        "top2" => MoEKind::Standard { k: 2 },
        "top3" => MoEKind::Standard { k: 3 },
        "shared" => MoEKind::SharedExpert,
        "scmoe" => MoEKind::ScMoE { k: 1 },
        "scmoe2" => MoEKind::ScMoE { k: 2 },
        other => bail!("unknown kind {other}"),
    };
    let strategy = match args.str_or("strategy", "overlap").as_str() {
        "seq" => Strategy::Sequential,
        "pipe" => Strategy::Pipelined { chunks: args.usize_or("chunks", 2) },
        "overlap" => Strategy::Overlap,
        "overlap-pipe" => Strategy::OverlapPipelined {
            chunks: args.usize_or("chunks", 2) },
        other => bail!("unknown strategy {other}"),
    };
    let costs = report::efficiency::proxy_costs(sc);
    let sched = ScheduleSpec::new(kind, strategy).adaptive().build(&costs);
    println!("{} / {} / {} (expert slot {})", sc.label(), kind.label(),
             sched.strategy.label(), sched.expert_slot);
    print!("{}", timeline::render(&sched.run(), args.usize_or("width", 110)));
    print!("{}", timeline::summary(&sched.run()));
    Ok(())
}

fn cmd_calib(args: &Args) -> Result<()> {
    let dir = PathBuf::from(args.str_or("dir", "artifacts/ops_tiny"));
    let reps = args.usize_or("reps", 5);
    let engine = Arc::new(Engine::cpu()?);
    let t = scmoe::bench_support::calibrate_ops(&engine, &dir, reps)?;
    println!("operator wallclock (median of {reps}) from {}:", dir.display());
    println!("  attn      {:>10.3} ms", t.attn * 1e3);
    println!("  mlp       {:>10.3} ms", t.mlp * 1e3);
    println!("  se        {:>10.3} ms", t.se * 1e3);
    println!("  gate      {:>10.3} ms", t.gate * 1e3);
    println!("  expert_k1 {:>10.3} ms (single expert shard)", t.expert_k1 * 1e3);
    println!("  experts   {:>10.3} ms (all local experts)", t.experts_all_k1 * 1e3);
    println!("ratios vs attn: mlp {:.2}, se {:.2}, gate {:.3}, experts {:.2}",
             t.mlp / t.attn, t.se / t.attn, t.gate / t.attn,
             t.experts_all_k1 / t.attn);
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let Some(dir) = args.positional.get(1) else {
        bail!("inspect needs a manifest directory");
    };
    let m = scmoe::runtime::Manifest::load(std::path::Path::new(dir))?;
    println!("kind: {} | arch: {} | task: {} | params: {}",
             m.kind, m.config.arch, m.config.task, m.param_count);
    for (name, a) in &m.artifacts {
        println!("  {name}: {} inputs -> {} outputs ({})",
                 a.inputs.len(), a.outputs.len(),
                 a.file.file_name().unwrap().to_string_lossy());
    }
    Ok(())
}
