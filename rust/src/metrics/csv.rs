//! Minimal CSV writer for metric logs (loss curves, Fig. 9/11 series).

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

pub struct CsvWriter {
    out: BufWriter<File>,
    n_cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        let f = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let mut out = BufWriter::new(f);
        writeln!(out, "{}", header.join(","))?;
        Ok(CsvWriter { out, n_cols: header.len() })
    }

    pub fn row(&mut self, values: &[f64]) -> Result<()> {
        assert_eq!(values.len(), self.n_cols, "column count mismatch");
        let cells: Vec<String> = values.iter().map(|v| format!("{v}")).collect();
        writeln!(self.out, "{}", cells.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, values: &[String]) -> Result<()> {
        assert_eq!(values.len(), self.n_cols, "column count mismatch");
        writeln!(self.out, "{}", values.join(","))?;
        Ok(())
    }

    pub fn flush(&mut self) -> Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let dir = std::env::temp_dir().join("scmoe_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        {
            let mut w = CsvWriter::create(&p, &["a", "b"]).unwrap();
            w.row(&[1.0, 2.5]).unwrap();
            w.flush().unwrap();
        }
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(text, "a,b\n1,2.5\n");
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let dir = std::env::temp_dir().join("scmoe_csv_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let mut w = CsvWriter::create(&dir.join("t.csv"), &["a"]).unwrap();
        let _ = w.row(&[1.0, 2.0]);
    }
}
