//! Wall-clock stopwatch with named laps (per-phase step timing).

use std::time::Instant;

#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    pub laps: Vec<(String, f64)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Stopwatch {
        let now = Instant::now();
        Stopwatch { start: now, last: now, laps: Vec::new() }
    }

    /// Record time since the previous lap under `label`.
    pub fn lap(&mut self, label: &str) -> f64 {
        let now = Instant::now();
        let dt = now.duration_since(self.last).as_secs_f64();
        self.last = now;
        self.laps.push((label.to_string(), dt));
        dt
    }

    pub fn total(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn lap_total(&self, label: &str) -> f64 {
        self.laps.iter().filter(|(l, _)| l == label).map(|(_, t)| t).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(std::time::Duration::from_millis(2));
        sw.lap("a");
        assert!(sw.lap_total("a") >= 0.004);
        assert!(sw.total() >= sw.lap_total("a"));
        assert_eq!(sw.lap_total("missing"), 0.0);
    }
}
