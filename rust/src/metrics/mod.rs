//! Training/benchmark metrics: step records, CSV sinks, wall-clock timers.

pub mod csv;
pub mod timer;

pub use csv::CsvWriter;
pub use timer::Stopwatch;
