//! Data substrate: byte-level tokenizer, the bundled tiny corpus for
//! language-model pretraining, and the synthetic classification task that
//! stands in for the paper's ImageNet/SwinV2 vision workload.

pub mod cls_task;
pub mod corpus;
pub mod tokenizer;

pub use cls_task::ClsTask;
pub use corpus::{Corpus, LmBatch};
pub use tokenizer::ByteTokenizer;
