//! Byte-level tokenizer with BOS/EOS/PAD specials.
//!
//! vocab: 0..=255 raw bytes, 256 BOS, 257 EOS, 258 PAD — matching
//! `vocab_size = 259` in python/compile/config.py.

pub const BOS: i32 = 256;
pub const EOS: i32 = 257;
pub const PAD: i32 = 258;
pub const VOCAB_SIZE: usize = 259;

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.bytes().map(|b| b as i32).collect()
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let bytes: Vec<u8> = ids
            .iter()
            .filter(|&&i| (0..256).contains(&i))
            .map(|&i| i as u8)
            .collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }

    pub fn vocab_size(&self) -> usize {
        VOCAB_SIZE
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let t = ByteTokenizer;
        let ids = t.encode("hello, MoE!");
        assert_eq!(t.decode(&ids), "hello, MoE!");
    }

    #[test]
    fn specials_out_of_byte_range() {
        assert!(BOS >= 256 && EOS >= 256 && PAD >= 256);
        assert_eq!(VOCAB_SIZE, 259);
    }

    #[test]
    fn decode_skips_specials() {
        let t = ByteTokenizer;
        assert_eq!(t.decode(&[104, BOS, 105, EOS]), "hi");
    }
}
