//! Tiny-corpus language-modeling dataset: contiguous byte chunks with
//! next-token targets, deterministic shuffled batching, train/valid split.
//!
//! The bundled corpus (rust/assets/corpus.txt, ~118 KB of public-license
//! English prose) substitutes for OpenWebText at this testbed's scale; the
//! loader also accepts any external text file (--corpus PATH).

use std::path::Path;

use anyhow::{Context, Result};

use crate::util::rng::Rng;

use super::tokenizer::ByteTokenizer;

pub const BUNDLED: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/rust/assets/corpus.txt");

/// One LM batch: row-major [batch, seq] inputs and next-token targets.
#[derive(Debug, Clone)]
pub struct LmBatch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

#[derive(Debug)]
pub struct Corpus {
    train: Vec<i32>,
    valid: Vec<i32>,
}

impl Corpus {
    pub fn load(path: &Path, valid_frac: f64) -> Result<Corpus> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading corpus {}", path.display()))?;
        Ok(Corpus::from_text(&text, valid_frac))
    }

    pub fn bundled() -> Result<Corpus> {
        Corpus::load(Path::new(BUNDLED), 0.1)
    }

    pub fn from_text(text: &str, valid_frac: f64) -> Corpus {
        let ids = ByteTokenizer.encode(text);
        let split = ((ids.len() as f64) * (1.0 - valid_frac)) as usize;
        Corpus { train: ids[..split].to_vec(), valid: ids[split..].to_vec() }
    }

    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    pub fn valid_len(&self) -> usize {
        self.valid.len()
    }

    fn sample_from(data: &[i32], rng: &mut Rng, batch: usize, seq: usize) -> LmBatch {
        assert!(data.len() > seq + 1, "corpus shorter than sequence length");
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let start = rng.below(data.len() - seq - 1);
            tokens.extend_from_slice(&data[start..start + seq]);
            targets.extend_from_slice(&data[start + 1..start + seq + 1]);
        }
        LmBatch { tokens, targets, batch, seq }
    }

    /// Deterministic random train batch for a step index.
    pub fn train_batch(&self, step: u64, batch: usize, seq: usize) -> LmBatch {
        let mut rng = Rng::new(0xC0FFEE ^ step);
        Self::sample_from(&self.train, &mut rng, batch, seq)
    }

    /// Fixed validation batches (same for every evaluation).
    pub fn valid_batches(&self, n: usize, batch: usize, seq: usize) -> Vec<LmBatch> {
        let mut rng = Rng::new(0xEA7_5EED);
        (0..n).map(|_| Self::sample_from(&self.valid, &mut rng, batch, seq)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Corpus {
        let text: String = std::iter::repeat("the quick brown fox jumps. ")
            .take(200)
            .collect();
        Corpus::from_text(&text, 0.1)
    }

    #[test]
    fn split_fractions() {
        let c = corpus();
        let total = c.train_len() + c.valid_len();
        assert!((c.valid_len() as f64 / total as f64 - 0.1).abs() < 0.01);
    }

    #[test]
    fn targets_shift_by_one() {
        let c = corpus();
        let b = c.train_batch(3, 2, 16);
        assert_eq!(b.tokens.len(), 32);
        // target[i] == token[i+1] within each row
        for row in 0..2 {
            for i in 0..15 {
                assert_eq!(b.targets[row * 16 + i], b.tokens[row * 16 + i + 1]);
            }
        }
    }

    #[test]
    fn deterministic_batches() {
        let c = corpus();
        let a = c.train_batch(7, 2, 8);
        let b = c.train_batch(7, 2, 8);
        assert_eq!(a.tokens, b.tokens);
        let d = c.train_batch(8, 2, 8);
        assert_ne!(a.tokens, d.tokens);
    }

    #[test]
    fn bundled_corpus_loads() {
        let c = Corpus::bundled().unwrap();
        assert!(c.train_len() > 50_000, "bundled corpus too small");
    }
}
