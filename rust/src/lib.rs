//! ScMoE: Shortcut-connected Expert Parallelism — Rust coordinator layer.
//!
//! Reproduction of "Shortcut-connected Expert Parallelism for Accelerating
//! Mixture of Experts" (Cai et al., ICML 2025) as a three-layer stack:
//! Pallas kernels (L1) and the JAX model (L2) are AOT-compiled to HLO text
//! by `python/compile/`; this crate (L3) owns everything at and above the
//! operator boundary: expert-parallel routing, All-to-All, the adaptive
//! overlap scheduler, expert offloading, and the training/inference drivers.

pub mod analyze;
pub mod bench_support;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod moe;
pub mod offload;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod simtime;
pub mod train;
pub mod util;
