//! GPU-resident expert pool: residency tracking + peak-memory accounting.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct ExpertId {
    pub layer: usize,
    pub expert: usize,
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Residency {
    Cpu,
    /// Migration issued; becomes GPU-resident at `ready` (seconds).
    Migrating { ready: f64 },
    Gpu,
}

/// Tracks which experts occupy GPU memory over time.
#[derive(Debug)]
pub struct ExpertPool {
    pub expert_bytes: usize,
    /// Bytes permanently resident (non-expert weights + shared experts).
    pub resident_bytes: usize,
    state: BTreeMap<ExpertId, Residency>,
    current_expert_bytes: usize,
    peak_bytes: usize,
}

impl ExpertPool {
    pub fn new(expert_bytes: usize, resident_bytes: usize) -> ExpertPool {
        ExpertPool {
            expert_bytes,
            resident_bytes,
            state: BTreeMap::new(),
            current_expert_bytes: 0,
            peak_bytes: resident_bytes,
        }
    }

    pub fn residency(&self, id: ExpertId) -> Residency {
        *self.state.get(&id).unwrap_or(&Residency::Cpu)
    }

    /// Issue a migration at time `now`; completes at `now + duration`.
    /// GPU memory is reserved from issue time (the transfer writes into it).
    pub fn start_migration(&mut self, id: ExpertId, now: f64, duration: f64) {
        self.start_migration_ready(id, now + duration);
    }

    /// Issue a migration that completes at absolute time `ready` (callers
    /// model the serialized H2D copy engine and pass the queued finish).
    pub fn start_migration_ready(&mut self, id: ExpertId, ready: f64) {
        match self.residency(id) {
            Residency::Cpu => {
                self.state.insert(id, Residency::Migrating { ready });
                self.current_expert_bytes += self.expert_bytes;
                self.peak_bytes = self.peak_bytes
                    .max(self.resident_bytes + self.current_expert_bytes);
            }
            _ => {} // already resident or in flight
        }
    }

    /// Time at which the expert is usable, given `now` (issues a blocking
    /// fetch if it was still on CPU).
    pub fn ready_time(&mut self, id: ExpertId, now: f64, duration: f64) -> f64 {
        match self.residency(id) {
            Residency::Gpu => now,
            Residency::Migrating { ready } => {
                if ready <= now {
                    self.state.insert(id, Residency::Gpu);
                    now
                } else {
                    ready
                }
            }
            Residency::Cpu => {
                self.start_migration(id, now, duration);
                now + duration
            }
        }
    }

    /// Evict an expert (after its layer's computation finished).
    pub fn evict(&mut self, id: ExpertId) {
        if !matches!(self.residency(id), Residency::Cpu) {
            self.state.remove(&id);
            self.current_expert_bytes = self.current_expert_bytes
                .saturating_sub(self.expert_bytes);
        }
    }

    pub fn peak_bytes(&self) -> usize {
        self.peak_bytes
    }

    pub fn current_bytes(&self) -> usize {
        self.resident_bytes + self.current_expert_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn migration_lifecycle() {
        let mut p = ExpertPool::new(100, 1000);
        let id = ExpertId { layer: 0, expert: 3 };
        assert_eq!(p.residency(id), Residency::Cpu);
        p.start_migration(id, 0.0, 2.0);
        assert!(matches!(p.residency(id), Residency::Migrating { .. }));
        // not ready at t=1 -> ready time is 2
        assert_eq!(p.ready_time(id, 1.0, 2.0), 2.0);
        // at t=3 it's resident
        assert_eq!(p.ready_time(id, 3.0, 2.0), 3.0);
        assert_eq!(p.residency(id), Residency::Gpu);
        p.evict(id);
        assert_eq!(p.residency(id), Residency::Cpu);
        assert_eq!(p.current_bytes(), 1000);
    }

    #[test]
    fn blocking_fetch_pays_full_duration() {
        let mut p = ExpertPool::new(100, 0);
        let id = ExpertId { layer: 1, expert: 0 };
        assert_eq!(p.ready_time(id, 5.0, 3.0), 8.0);
    }

    #[test]
    fn peak_tracks_max_concurrent() {
        let mut p = ExpertPool::new(100, 1000);
        for e in 0..3 {
            p.start_migration(ExpertId { layer: 0, expert: e }, 0.0, 1.0);
        }
        assert_eq!(p.peak_bytes(), 1300);
        for e in 0..3 {
            p.evict(ExpertId { layer: 0, expert: e });
        }
        assert_eq!(p.current_bytes(), 1000);
        assert_eq!(p.peak_bytes(), 1300); // peak is sticky
    }

    #[test]
    fn double_migration_is_idempotent() {
        let mut p = ExpertPool::new(100, 0);
        let id = ExpertId { layer: 0, expert: 0 };
        p.start_migration(id, 0.0, 1.0);
        p.start_migration(id, 0.5, 1.0);
        assert_eq!(p.current_bytes(), 100);
    }
}
