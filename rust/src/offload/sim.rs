//! Per-token decode simulation under each offloading policy (Fig. 10).

use crate::cluster::LinkModel;
use crate::util::rng::Rng;

use super::pool::{ExpertId, ExpertPool};

/// Per-token decode-step operator durations (seconds) for one
/// Block-MLP + Block-MoE pair.
#[derive(Debug, Clone, Copy)]
pub struct DecodeCosts {
    pub attn: f64,
    pub mlp: f64,
    pub se: f64,
    pub gate: f64,
    pub expert: f64,
}

impl DecodeCosts {
    /// The ScMoE overlap window available for migration:
    /// T_Atten + T_SE + T_MLP (§3.3).
    pub fn window(&self) -> f64 {
        self.attn + self.se + self.mlp
    }

    pub fn pair_compute(&self) -> f64 {
        self.attn + self.mlp + self.attn + self.se + self.gate + self.expert
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Policy {
    /// Entire model resident on GPU (no offloading).
    GpuOnly,
    /// Offload with on-demand blocking migration.
    Blocking,
    /// ScMoE determinate migration issued at the preceding layer (§3.3).
    AsyncDeterminate,
    /// Pre-gated-MoE-style speculative prefetch with hit-rate `accuracy`;
    /// misses fall back to blocking fetches.
    Speculative { accuracy: f64 },
}

impl Policy {
    pub fn label(&self) -> String {
        match self {
            Policy::GpuOnly => "GPU-only".into(),
            Policy::Blocking => "Offload".into(),
            Policy::AsyncDeterminate => "Offload-Async".into(),
            Policy::Speculative { accuracy } => format!("Speculative({accuracy:.2})"),
        }
    }
}

#[derive(Debug, Clone)]
pub struct OffloadConfig {
    /// Number of MoE layers (Block-MoE blocks).
    pub n_moe_layers: usize,
    /// Pre-allocated migration buffers: k expert slots per MoE layer stay
    /// reserved for the whole run (static allocation, no cudaMalloc on the
    /// decode path) — matches the paper's Fig. 10a accounting.
    pub static_buffers: bool,
    pub n_experts: usize,
    /// Experts activated per token per MoE layer.
    pub k: usize,
    /// Bytes of one expert's parameters.
    pub expert_bytes: usize,
    /// Bytes of everything kept resident (non-expert + shared experts).
    pub resident_bytes: usize,
    /// Host-to-device link.
    pub h2d: LinkModel,
    pub costs: DecodeCosts,
}

impl OffloadConfig {
    pub fn migration_time(&self) -> f64 {
        self.h2d.transfer_time(self.expert_bytes * self.k)
    }

    /// Peak GPU bytes with the full model resident.
    pub fn gpu_only_bytes(&self) -> usize {
        self.resident_bytes + self.n_moe_layers * self.n_experts * self.expert_bytes
    }
}

#[derive(Debug, Clone)]
pub struct OffloadReport {
    pub policy: Policy,
    pub peak_gpu_bytes: usize,
    /// Mean per-pair (Block-MLP + Block-MoE) latency over decoded tokens.
    pub block_latency: f64,
    /// Mean migration time NOT hidden by computation.
    pub exposed_migration: f64,
    pub tokens: usize,
}

/// Simulate `tokens` decode steps. `selections[t][l]` = experts chosen for
/// token t at MoE layer l (k entries each); generated from `seed` when None.
pub fn simulate_decode(
    cfg: &OffloadConfig,
    selections: Option<&[Vec<Vec<usize>>]>,
    tokens: usize,
    policy: Policy,
    seed: u64,
) -> OffloadReport {
    let mut rng = Rng::new(seed);
    let sel_owned: Vec<Vec<Vec<usize>>>;
    let sels: &[Vec<Vec<usize>>] = match selections {
        Some(s) => s,
        None => {
            sel_owned = (0..tokens)
                .map(|_| {
                    (0..cfg.n_moe_layers)
                        .map(|_| {
                            let mut picked = Vec::new();
                            while picked.len() < cfg.k {
                                let e = rng.below(cfg.n_experts);
                                if !picked.contains(&e) {
                                    picked.push(e);
                                }
                            }
                            picked
                        })
                        .collect()
                })
                .collect();
            &sel_owned
        }
    };

    if policy == Policy::GpuOnly {
        let lat = cfg.costs.pair_compute();
        return OffloadReport {
            policy,
            peak_gpu_bytes: cfg.gpu_only_bytes(),
            block_latency: lat,
            exposed_migration: 0.0,
            tokens,
        };
    }

    let reserved = if cfg.static_buffers {
        cfg.n_moe_layers * cfg.k * cfg.expert_bytes
    } else {
        0
    };
    let mut pool = ExpertPool::new(cfg.expert_bytes, cfg.resident_bytes + reserved);
    let mig = cfg.h2d.transfer_time(cfg.expert_bytes);
    let c = cfg.costs;
    // H2D copies serialize on the single transfer engine
    let mut h2d_free: f64;

    let mut total_latency = 0.0;
    let mut total_exposed = 0.0;

    for sel_t in sels.iter().take(tokens) {
        for (layer, experts) in sel_t.iter().enumerate() {
            // --- one Block-MLP + Block-MoE pair, time relative to pair start
            let mut now = 0.0;
            h2d_free = 0.0;
            now += c.attn; // Attn(l) — ScMoE gate runs here (preceding layer)
            let gate_t = now + c.gate;

            // migration issue point per policy (queued on the copy engine)
            let queue_mig = |pool: &mut ExpertPool, h2d_free: &mut f64,
                                 id: ExpertId, issue: f64| {
                if matches!(pool.residency(id), super::pool::Residency::Cpu) {
                    let start = h2d_free.max(issue);
                    let ready = start + mig;
                    *h2d_free = ready;
                    pool.start_migration_ready(id, ready);
                }
            };
            match policy {
                Policy::AsyncDeterminate => {
                    // exact selection known at preceding layer's gate
                    for &e in experts {
                        queue_mig(&mut pool, &mut h2d_free,
                                  ExpertId { layer, expert: e }, gate_t);
                    }
                }
                Policy::Speculative { accuracy } => {
                    for &e in experts {
                        let hit = rng.next_f64() < accuracy;
                        let guess = if hit {
                            e
                        } else {
                            (e + 1 + rng.below(cfg.n_experts - 1)) % cfg.n_experts
                        };
                        queue_mig(&mut pool, &mut h2d_free,
                                  ExpertId { layer, expert: guess }, gate_t);
                    }
                }
                _ => {}
            }

            now = gate_t + c.mlp;  // MLP(l)
            now += c.attn;         // Attn(l+1)
            now += c.se;           // SE(l+1)

            // expert computation needs the weights on GPU (blocking fetches
            // queue on the copy engine behind any in-flight prefetches)
            let mut ready = now;
            for &e in experts {
                let id = ExpertId { layer, expert: e };
                if matches!(pool.residency(id), super::pool::Residency::Cpu) {
                    queue_mig(&mut pool, &mut h2d_free, id, now);
                }
                ready = ready.max(pool.ready_time(id, now, mig));
            }
            let exposed = ready - now;
            now = ready + c.expert;

            total_latency += now;
            total_exposed += exposed;

            // evict after use (and any mispredicted prefetches)
            for e in 0..cfg.n_experts {
                pool.evict(ExpertId { layer, expert: e });
            }
        }
    }

    let n = (tokens * cfg.n_moe_layers) as f64;
    OffloadReport {
        policy,
        peak_gpu_bytes: pool.peak_bytes(),
        block_latency: total_latency / n,
        exposed_migration: total_exposed / n,
        tokens,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> OffloadConfig {
        OffloadConfig {
            n_moe_layers: 12,
            static_buffers: false,
            n_experts: 8,
            k: 1,
            expert_bytes: 8 << 20,
            resident_bytes: 200 << 20,
            h2d: LinkModel::new(10e-6, 8e9),
            costs: DecodeCosts {
                attn: 300e-6, mlp: 250e-6, se: 250e-6,
                gate: 20e-6, expert: 250e-6,
            },
        }
    }

    #[test]
    fn offload_cuts_peak_memory() {
        let c = cfg();
        let gpu = simulate_decode(&c, None, 16, Policy::GpuOnly, 1);
        let off = simulate_decode(&c, None, 16, Policy::Blocking, 1);
        assert!(off.peak_gpu_bytes < gpu.peak_gpu_bytes / 2,
                "offload {} vs gpu {}", off.peak_gpu_bytes, gpu.peak_gpu_bytes);
    }

    #[test]
    fn async_hides_migration() {
        let c = cfg();
        let blocking = simulate_decode(&c, None, 32, Policy::Blocking, 2);
        let asynch = simulate_decode(&c, None, 32, Policy::AsyncDeterminate, 2);
        assert!(blocking.exposed_migration > 0.0);
        assert!(asynch.exposed_migration < blocking.exposed_migration,
                "async {} vs blocking {}", asynch.exposed_migration,
                blocking.exposed_migration);
        assert!(asynch.block_latency < blocking.block_latency);
    }

    #[test]
    fn async_never_slower_than_gpu_only_plus_exposed() {
        let c = cfg();
        let gpu = simulate_decode(&c, None, 16, Policy::GpuOnly, 3);
        let asynch = simulate_decode(&c, None, 16, Policy::AsyncDeterminate, 3);
        assert!(asynch.block_latency + 1e-12 >= gpu.block_latency);
        assert!((asynch.block_latency - gpu.block_latency - asynch.exposed_migration).abs() < 1e-9);
    }

    #[test]
    fn speculative_accuracy_1_matches_async() {
        let c = cfg();
        let spec = simulate_decode(&c, None, 64, Policy::Speculative { accuracy: 1.0 }, 4);
        let asynch = simulate_decode(&c, None, 64, Policy::AsyncDeterminate, 4);
        assert!((spec.block_latency - asynch.block_latency).abs() < 1e-9);
    }

    #[test]
    fn speculative_misses_cost_more() {
        let c = cfg();
        let hi = simulate_decode(&c, None, 128, Policy::Speculative { accuracy: 0.95 }, 5);
        let lo = simulate_decode(&c, None, 128, Policy::Speculative { accuracy: 0.30 }, 5);
        assert!(lo.block_latency > hi.block_latency);
    }

    #[test]
    fn same_selections_same_experts_run() {
        // async determinate must never change *which* experts execute
        let c = cfg();
        let sels: Vec<Vec<Vec<usize>>> =
            vec![vec![vec![3]; c.n_moe_layers]; 8];
        let a = simulate_decode(&c, Some(&sels), 8, Policy::Blocking, 6);
        let b = simulate_decode(&c, Some(&sels), 8, Policy::AsyncDeterminate, 6);
        // identical peak memory (k resident at a time) and b strictly faster
        assert_eq!(a.peak_gpu_bytes, b.peak_gpu_bytes);
        assert!(b.block_latency <= a.block_latency);
    }
}
