//! Expert offloading for memory-limited inference (§3.3, Fig. 7, Fig. 10).
//!
//! Gate-selected experts live in CPU memory; non-expert weights and the
//! shared expert stay resident on the GPU. Three migration policies:
//!
//! - `Blocking`        — fetch the selected experts when the expert
//!                       computation needs them (baseline "Offload");
//! - `AsyncDeterminate`— the ScMoE property: expert selection happens at the
//!                       *preceding* layer's gate, so migration is issued one
//!                       overlap-window early and is *exact* ("Offload-Async");
//! - `Speculative`     — Pre-gated-MoE-style baseline: predict the selection
//!                       from preceding-layer hints with some accuracy;
//!                       mispredictions fall back to a blocking fetch.
//!
//! `pool` tracks residency + peak memory; `sim` builds per-token decode
//! schedules on the DES and reports MoE-block latency.

pub mod pool;
pub mod sim;

pub use pool::{ExpertId, ExpertPool, Residency};
pub use sim::{simulate_decode, DecodeCosts, OffloadConfig, OffloadReport, Policy};
