//! Calibration + micro-bench helpers shared by `cargo bench` targets and
//! the `scmoe bench-calib` subcommand.

pub mod calibrate;

pub use calibrate::{calibrate_ops, OpTimes};
