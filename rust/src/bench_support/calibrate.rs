//! Measure real CPU wallclock of each operator artifact — step 1 of the
//! DES grounding method (DESIGN.md §6). The measured *ratios* between
//! operators feed `ComputeCosts`; the absolute scale cancels in speedups.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::runtime::{ArtifactSet, Engine, HostTensor};
use crate::util::rng::Rng;
use crate::util::stats::median;

#[derive(Debug, Clone)]
pub struct OpTimes {
    pub attn: f64,
    pub mlp: f64,
    pub se: f64,
    pub gate: f64,
    pub expert_k1: f64,
    pub experts_all_k1: f64,
}

fn rand_tensor(shape: &[usize], rng: &mut Rng) -> HostTensor {
    let n: usize = shape.iter().product();
    HostTensor::f32(shape.to_vec(), (0..n).map(|_| rng.next_f32() - 0.5).collect())
}

fn time_exe(set: &ArtifactSet, name: &str, reps: usize, rng: &mut Rng) -> Result<f64> {
    let exe = set.get(name)?;
    let inputs: Vec<HostTensor> = exe.spec.inputs.iter()
        .map(|s| match s.dtype {
            crate::runtime::DType::F32 => rand_tensor(&s.shape, rng),
            _ => HostTensor::i32(s.shape.clone(),
                                 vec![0; s.shape.iter().product()]),
        })
        .collect();
    exe.run(&inputs)?; // warmup + compile
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        exe.run(&inputs).context(name.to_string())?;
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(median(&times))
}

/// Calibrate the ops manifest at `dir` with `reps` repetitions per op.
pub fn calibrate_ops(engine: &Arc<Engine>, dir: &Path, reps: usize) -> Result<OpTimes> {
    let set = engine.open(dir)?;
    let mut rng = Rng::new(0xCA11B);
    let cap1 = set.manifest.capacities.get(&1).copied().unwrap_or(1);
    Ok(OpTimes {
        attn: time_exe(&set, "attn_op", reps, &mut rng)?,
        mlp: time_exe(&set, "mlp_op", reps, &mut rng)?,
        se: time_exe(&set, "se_op", reps, &mut rng)?,
        gate: time_exe(&set, "gate_op_k1", reps, &mut rng)?,
        expert_k1: time_exe(&set, &format!("expert_op_c{cap1}"), reps, &mut rng)?,
        experts_all_k1: time_exe(&set, &format!("experts_op_c{cap1}"), reps, &mut rng)?,
    })
}
