//! Open-loop serving simulation: request streams → latency under load.
//!
//! Every study below this layer prices one fixed batch per step; serving
//! a model is different — requests *arrive*, wait in a queue, get batched
//! by a policy, run a prefill step, then ride along as decode tokens for
//! several more steps before completing. This module puts that loop on
//! top of the DES:
//!
//! - [`arrivals`] — seeded Poisson-like and trace-driven request streams
//!   ([`poisson_arrivals`], [`trace_arrivals`]);
//! - [`batch`] — continuous-batching admission policies
//!   ([`BatchPolicy`]: wait-k / deadline / token-budget);
//! - [`engine`] — the serving loop ([`run_serve`]): per step, the formed
//!   batch becomes a [`RoutingTable`](crate::moe::RoutingTable) via
//!   [`phase_affine_routing`](crate::moe::phase_affine_routing) (prefill
//!   and decode tokens carry distinct noise profiles), is priced by
//!   `TopoCosts::from_routing` under the placement currently in force,
//!   and executes as a `ScheduleSpec::build` schedule whose makespan
//!   advances the virtual clock. PR 5's
//!   [`ReplacePolicy`](crate::coordinator::replace::ReplacePolicy) runs
//!   *online* inside the loop — the same estimator/plan/break-even
//!   machinery as `run_replace_timeline`, with `remaining` counting
//!   outstanding requests instead of scripted steps.
//!
//! The closed-system configuration (all requests at `t = 0`, wait-1
//! batching, prefill-only requests) reduces bit-exactly to
//! `run_replace_timeline` over the same table stream — the property that
//! pins this loop to the validated PR 5 model (and to the DES mirror,
//! `tools/des_mirror/mirror2.py` `consistency_checks6`).

pub mod arrivals;
pub mod batch;
pub mod engine;

pub use arrivals::{poisson_arrivals, trace_arrivals, Request};
pub use batch::{BatchDecision, BatchPolicy};
pub use engine::{run_serve, ServeConfig, ServeOutcome, ServeStep, TrafficProfile};
