//! Continuous-batching admission policies.
//!
//! A serving step always carries every *active* decode request
//! (continuous batching: generation never waits on prompt admission);
//! the policy only decides how many *queued prefills* join, or — when
//! nothing would run — how far to advance the virtual clock before
//! re-evaluating.

/// What the policy decided for the instant `now`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchDecision {
    /// Launch a step admitting the first `n` queued prefills (FIFO).
    Admit(usize),
    /// Nothing runs yet: advance the virtual clock to this strictly
    /// later instant and re-evaluate (more arrivals or a deadline).
    WaitUntil(f64),
}

/// Prefill admission policy for batch formation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    /// Wait for `k` queued prefills before launching; if decode work is
    /// active, steps run anyway and take whatever is queued (up to `k`).
    WaitK { k: usize },
    /// Launch all queued prefills once the oldest has waited `window`
    /// seconds; until then prefills hold while decode steps run.
    Deadline { window: f64 },
    /// Admit queued prefills FIFO while the batch's total tokens
    /// (decode tokens of active requests + admitted prompt tokens) stay
    /// within `budget`; an oversized head-of-line request runs alone
    /// rather than starving.
    TokenBudget { budget: usize },
}

impl BatchPolicy {
    /// Display label for study tables.
    pub fn label(&self) -> String {
        match self {
            BatchPolicy::WaitK { k } => format!("wait-{k}"),
            BatchPolicy::Deadline { window } => {
                format!("deadline-{:.0}ms", window * 1e3)
            }
            BatchPolicy::TokenBudget { budget } => format!("budget-{budget}"),
        }
    }

    /// The admission decision at instant `now`.
    ///
    /// `queued` is the FIFO prefill queue as `(arrival, prefill_tokens)`
    /// rows; `active` counts in-flight decode requests (each contributing
    /// `decode_tokens` to the step); `next_arrival` is the next future
    /// arrival instant, if any (strictly after `now` — the engine drains
    /// all arrivals at or before `now` first). The engine only asks when
    /// some work exists (`!queued.is_empty() || active > 0`), and every
    /// `WaitUntil` target is strictly after `now`, so the loop always
    /// advances.
    pub fn decide(&self, now: f64, queued: &[(f64, usize)], active: usize,
                  decode_tokens: usize, next_arrival: Option<f64>)
                  -> BatchDecision {
        match *self {
            BatchPolicy::WaitK { k } => {
                assert!(k > 0, "WaitK needs k >= 1");
                if queued.len() >= k {
                    BatchDecision::Admit(k)
                } else if active > 0 {
                    BatchDecision::Admit(queued.len())
                } else if let Some(t) = next_arrival {
                    BatchDecision::WaitUntil(t)
                } else {
                    // tail drain: no arrivals left, fewer than k queued
                    BatchDecision::Admit(queued.len())
                }
            }
            BatchPolicy::Deadline { window } => {
                let Some(&(oldest, _)) = queued.first() else {
                    return BatchDecision::Admit(0); // pure-decode step
                };
                let deadline = oldest + window;
                if now >= deadline {
                    BatchDecision::Admit(queued.len())
                } else if active > 0 {
                    BatchDecision::Admit(0)
                } else {
                    let t = match next_arrival {
                        Some(na) if na < deadline => na,
                        _ => deadline,
                    };
                    BatchDecision::WaitUntil(t)
                }
            }
            BatchPolicy::TokenBudget { budget } => {
                let mut tokens = active * decode_tokens;
                let mut n = 0usize;
                for &(_, prefill) in queued {
                    if tokens + prefill > budget {
                        break;
                    }
                    tokens += prefill;
                    n += 1;
                }
                if n == 0 && active == 0 {
                    BatchDecision::Admit(1)
                } else {
                    BatchDecision::Admit(n)
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wait_k_holds_until_k_then_launches() {
        let p = BatchPolicy::WaitK { k: 2 };
        let q1 = [(0.0, 64)];
        assert_eq!(p.decide(0.0, &q1, 0, 8, Some(0.5)),
                   BatchDecision::WaitUntil(0.5));
        let q2 = [(0.0, 64), (0.5, 64)];
        assert_eq!(p.decide(0.5, &q2, 0, 8, None), BatchDecision::Admit(2));
        // decode work drives steps regardless of queue depth
        assert_eq!(p.decide(0.0, &q1, 3, 8, Some(0.5)),
                   BatchDecision::Admit(1));
        // tail drain with no future arrivals
        assert_eq!(p.decide(0.0, &q1, 0, 8, None), BatchDecision::Admit(1));
    }

    #[test]
    fn deadline_waits_for_window_or_arrival() {
        let p = BatchPolicy::Deadline { window: 0.25 };
        let q = [(1.0, 64), (1.1, 64)];
        // idle system: jump to the earlier of next arrival / deadline
        assert_eq!(p.decide(1.1, &q, 0, 8, Some(1.2)),
                   BatchDecision::WaitUntil(1.2));
        assert_eq!(p.decide(1.1, &q, 0, 8, Some(2.0)),
                   BatchDecision::WaitUntil(1.25));
        // deadline reached: admit everything queued
        assert_eq!(p.decide(1.25, &q, 0, 8, Some(2.0)),
                   BatchDecision::Admit(2));
        // decode work keeps stepping while prefills wait out the window
        assert_eq!(p.decide(1.1, &q, 2, 8, Some(2.0)),
                   BatchDecision::Admit(0));
    }

    #[test]
    fn token_budget_counts_decode_tokens_and_never_starves() {
        let p = BatchPolicy::TokenBudget { budget: 256 };
        let q = [(0.0, 100), (0.0, 100), (0.0, 100)];
        // 4 active decodes at 16 tokens each leave room for one prefill
        assert_eq!(p.decide(0.0, &q, 4, 16, None), BatchDecision::Admit(1));
        assert_eq!(p.decide(0.0, &q, 0, 16, None), BatchDecision::Admit(2));
        // oversized head-of-line request runs alone on an idle system
        let big = [(0.0, 1000)];
        assert_eq!(p.decide(0.0, &big, 0, 16, None), BatchDecision::Admit(1));
        // ...but holds while decode work exists
        assert_eq!(p.decide(0.0, &big, 4, 16, None), BatchDecision::Admit(0));
    }
}
