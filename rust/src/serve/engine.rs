//! The serving loop: arrivals → batches → priced DES steps → latencies.

use crate::cluster::{LinkModel, Topology};
use crate::coordinator::costs::{ComputeCosts, TopoCosts};
use crate::coordinator::replace::{MigrationPlan, ReplacePolicy};
use crate::coordinator::spec::ScheduleSpec;
use crate::moe::{phase_affine_routing, AffinityEstimator, Placement};
use crate::simtime::SimArena;
use crate::util::stats::percentile;

use super::arrivals::Request;
use super::batch::{BatchDecision, BatchPolicy};

/// Routing statistics of the served traffic: node-affine with
/// phase-dependent noise, optionally shifting regime mid-run. Step `s`
/// draws its table from `seed + s` (the `study_tables` convention).
#[derive(Debug, Clone)]
pub struct TrafficProfile {
    /// Base node→group rotation.
    pub regime: usize,
    /// Step index from which the regime rotates one further notch
    /// (models a routing-regime shift invalidating a learned placement).
    pub shift_at: Option<usize>,
    /// Per-token random-routing probability for prompt tokens.
    pub prefill_noise: f64,
    /// Per-token random-routing probability for generated tokens
    /// (typically noisier: generation drifts off the planted affinity).
    pub decode_noise: f64,
    /// Base seed; step `s` uses `seed + s`.
    pub seed: u64,
}

/// Everything the serving loop needs beyond the request stream.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Schedule built for every step.
    pub spec: ScheduleSpec,
    /// Prefill admission policy.
    pub batching: BatchPolicy,
    /// Online re-placement decision rule (PR 5's policy, driven by
    /// outstanding *requests* instead of scripted steps).
    pub policy: ReplacePolicy,
    /// Estimator decay (1.0 = counting).
    pub decay: f64,
    /// Parameter bytes per migrated expert.
    pub bytes_per_expert: usize,
    /// Host-to-device migration link.
    pub h2d: LinkModel,
    /// Payload bytes per routed token copy.
    pub token_bytes: usize,
    /// Tokens each active decode request contributes per step.
    pub decode_tokens: usize,
    /// Number of experts in the layer.
    pub n_experts: usize,
    /// Traffic statistics.
    pub traffic: TrafficProfile,
}

/// One executed serving step.
#[derive(Debug, Clone)]
pub struct ServeStep {
    /// 0-based executed-step index (idle gaps don't count).
    pub step: usize,
    /// Virtual-clock instant the step launched.
    pub start: f64,
    /// DES makespan, including migration H2D spans if one fired here.
    pub makespan: f64,
    /// DES makespan of the schedule alone.
    pub base_makespan: f64,
    /// Prefill requests admitted into this batch.
    pub prefills: usize,
    /// Prompt tokens those admissions contributed.
    pub prefill_tokens: usize,
    /// Active decode requests riding along.
    pub decodes: usize,
    /// Decode tokens they contributed.
    pub decode_tokens: usize,
    /// Prefills still queued after admission.
    pub queued: usize,
    /// Whether an online migration fired during this step.
    pub migrated: bool,
    /// Bytes the migration moved (0 when `!migrated`).
    pub migration_bytes: usize,
    /// Serialized H2D time of the migration (0 when `!migrated`).
    pub migration_time: f64,
    /// Requests completing at the end of this step.
    pub completed: usize,
}

/// Result of [`run_serve`].
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    /// Executed steps in order.
    pub steps: Vec<ServeStep>,
    /// Per-request latency (completion − arrival), in completion order.
    pub latencies: Vec<f64>,
    /// Sum of step makespans (fleet busy time).
    pub busy: f64,
    /// Virtual clock at the last completion (includes idle gaps).
    pub total_time: f64,
    /// Online migrations fired.
    pub migrations: usize,
    /// Placement in force after the last step.
    pub final_placement: Placement,
}

impl ServeOutcome {
    /// Median request latency.
    pub fn p50(&self) -> f64 {
        percentile(&self.latencies, 50.0)
    }

    /// Tail (99th-percentile) request latency.
    pub fn p99(&self) -> f64 {
        percentile(&self.latencies, 99.0)
    }

    /// Completed requests per second of virtual time.
    pub fn throughput(&self) -> f64 {
        self.latencies.len() as f64 / self.total_time
    }

    /// Requests per second completing within the latency target `slo`.
    pub fn goodput(&self, slo: f64) -> f64 {
        self.latencies.iter().filter(|&&l| l <= slo).count() as f64
            / self.total_time
    }
}

struct ActiveReq {
    arrival: f64,
    remaining_decode: usize,
}

/// Drive a request stream through the serving loop.
///
/// Per iteration: (1) drain arrivals at or before `now` into the prefill
/// queue; (2) if the system is empty, jump the clock to the next arrival;
/// (3) ask the [`BatchPolicy`] — either advance the clock and retry, or
/// launch a step admitting `n` queued prefills alongside every active
/// decode request; (4) generate the batch's [`phase_affine_routing`]
/// table (prompt tokens first, then decode tokens — matching the
/// even-split source convention of `a2a_bytes_placed`), price it under
/// the placement in force, build the spec's schedule, and advance `now`
/// by its makespan; (5) feed the table to the affinity estimator and run
/// the PR 5 migration decision with `remaining` = outstanding requests
/// after this step — on migration the plan's H2D tasks overlap into this
/// step's DES graph and the new placement takes effect next step;
/// (6) record completions (prefill-only admissions and decodes reaching
/// their last iteration) with latency `end − arrival`.
///
/// `requests` must be sorted by arrival time. With all requests at
/// `t = 0`, wait-1 batching and prefill-only requests, the loop is
/// bit-exactly `run_replace_timeline` over the same table stream.
pub fn run_serve(base: &ComputeCosts, topo: &Topology, requests: &[Request],
                 initial: &Placement, cfg: &ServeConfig) -> ServeOutcome {
    assert!(!requests.is_empty(), "a serving run needs at least one request");
    assert!(requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "requests must be sorted by arrival time");
    assert!(requests.iter().all(|r| r.decode_steps == 0) || cfg.decode_tokens > 0,
            "decode phases need decode_tokens > 0");
    assert_eq!(cfg.n_experts, initial.n_experts);
    let n_nodes = topo.n_devices / topo.devices_per_node;
    let mut est = AffinityEstimator::ewma(cfg.n_experts, n_nodes, cfg.decay);
    let mut placement = initial.clone();
    let mut queued: Vec<Request> = Vec::new();
    let mut active: Vec<ActiveReq> = Vec::new();
    let mut next_idx = 0usize;
    let mut now = 0.0f64;
    let mut step = 0usize;
    let mut steps = Vec::new();
    let mut latencies = Vec::new();
    let mut busy = 0.0f64;
    let mut migrations = 0usize;
    // step + break-even-probe arenas: every batch builds the same spec
    // shape, so repeat builds warm-start (see `simtime::arena`)
    let mut arena = SimArena::new();
    let mut probe = SimArena::new();

    while next_idx < requests.len() || !queued.is_empty() || !active.is_empty() {
        while next_idx < requests.len() && requests[next_idx].arrival <= now {
            queued.push(requests[next_idx].clone());
            next_idx += 1;
        }
        if queued.is_empty() && active.is_empty() {
            now = requests[next_idx].arrival; // idle: jump to next arrival
            continue;
        }
        let next_arrival = requests.get(next_idx).map(|r| r.arrival);
        let qmeta: Vec<(f64, usize)> =
            queued.iter().map(|r| (r.arrival, r.prefill_tokens)).collect();
        let admit = match cfg.batching.decide(now, &qmeta, active.len(),
                                              cfg.decode_tokens, next_arrival) {
            BatchDecision::Admit(n) => n,
            BatchDecision::WaitUntil(t) => {
                assert!(t > now, "batching must advance the clock");
                now = t;
                continue;
            }
        };
        let admitted: Vec<Request> = queued.drain(..admit).collect();
        let n_prefill_tokens: usize =
            admitted.iter().map(|r| r.prefill_tokens).sum();
        let n_decodes = active.len();
        let n_decode_tokens = n_decodes * cfg.decode_tokens;

        let regime = cfg.traffic.regime
            + match cfg.traffic.shift_at {
                Some(at) if step >= at => 1,
                _ => 0,
            };
        let rt = phase_affine_routing(
            topo.n_devices, topo.devices_per_node, cfg.n_experts,
            n_prefill_tokens, n_decode_tokens, regime,
            cfg.traffic.prefill_noise, cfg.traffic.decode_noise,
            cfg.traffic.seed + step as u64);
        let costs = TopoCosts::from_routing(base, topo, &rt, &placement,
                                            cfg.token_bytes);
        cfg.spec.build_into(&costs, &mut arena);
        let base_makespan = arena.makespan();
        est.observe(&rt, topo.n_devices, topo.devices_per_node);

        // outstanding requests once this step retires: still-future
        // arrivals, still-queued prefills, and batch members with decode
        // iterations left — the serving analogue of the timeline's
        // "remaining steps" (each needs at least one more step)
        let survivors = active.iter().filter(|a| a.remaining_decode > 1).count()
            + admitted.iter().filter(|r| r.decode_steps > 0).count();
        let remaining = (requests.len() - next_idx) + queued.len() + survivors;
        let mut migrated = false;
        let mut migration_bytes = 0usize;
        let mut migration_time = 0.0f64;
        if remaining > 0 && cfg.policy != ReplacePolicy::Never {
            let candidate = est.packed(topo.n_devices, topo.devices_per_node);
            let plan = MigrationPlan::between(&placement, &candidate,
                                             cfg.bytes_per_expert);
            if !plan.is_empty() {
                let mig = plan.time(&cfg.h2d);
                let overhead = (mig - base_makespan).max(0.0);
                let saving = match cfg.policy {
                    ReplacePolicy::BreakEven => {
                        let cand = TopoCosts::from_routing(
                            base, topo, &rt, &candidate, cfg.token_bytes);
                        cfg.spec.build_into(&cand, &mut probe);
                        base_makespan - probe.makespan()
                    }
                    _ => 0.0,
                };
                if cfg.policy.should_migrate(step, remaining, saving, overhead) {
                    plan.add_h2d_tasks(arena.sim_mut(), &cfg.h2d);
                    migrated = true;
                    migration_bytes = plan.total_bytes();
                    migration_time = mig;
                    placement = candidate;
                    migrations += 1;
                }
            }
        }
        let makespan = if migrated { arena.makespan() } else { base_makespan };
        let end = now + makespan;

        let mut completed = 0usize;
        let mut still = Vec::with_capacity(active.len());
        for a in active.drain(..) {
            if a.remaining_decode == 1 {
                latencies.push(end - a.arrival);
                completed += 1;
            } else {
                still.push(ActiveReq {
                    remaining_decode: a.remaining_decode - 1,
                    ..a
                });
            }
        }
        active = still;
        for r in admitted {
            if r.decode_steps == 0 {
                latencies.push(end - r.arrival);
                completed += 1;
            } else {
                active.push(ActiveReq {
                    arrival: r.arrival,
                    remaining_decode: r.decode_steps,
                });
            }
        }

        steps.push(ServeStep {
            step,
            start: now,
            makespan,
            base_makespan,
            prefills: admit,
            prefill_tokens: n_prefill_tokens,
            decodes: n_decodes,
            decode_tokens: n_decode_tokens,
            queued: queued.len(),
            migrated,
            migration_bytes,
            migration_time,
            completed,
        });
        busy += makespan;
        now = end;
        step += 1;
    }

    ServeOutcome {
        steps,
        latencies,
        busy,
        total_time: now,
        migrations,
        final_placement: placement,
    }
}
