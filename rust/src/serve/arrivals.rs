//! Request streams: seeded Poisson-like and trace-driven arrivals.

use crate::util::rng::Rng;

/// One inference request: a prompt to prefill, then `decode_steps`
/// generation iterations (each contributing the serving config's
/// per-request decode tokens to its step's batch).
#[derive(Debug, Clone)]
pub struct Request {
    /// Stable id (index in arrival order).
    pub id: usize,
    /// Arrival instant (seconds on the virtual clock).
    pub arrival: f64,
    /// Prompt tokens routed in the request's prefill step.
    pub prefill_tokens: usize,
    /// Generation iterations after prefill (0 = prefill-only).
    pub decode_steps: usize,
}

/// Seeded Poisson-like arrival stream via Bernoulli thinning on a fixed
/// tick grid: each tick of width `tick` seconds admits an arrival with
/// probability `rate * tick`, giving geometrically distributed
/// inter-arrival gaps with mean `1 / rate` — the discrete-grid limit of
/// a Poisson process, chosen over exponential sampling because it needs
/// no `ln()` and is therefore bit-reproducible across the Rust engine
/// and the Python DES mirror (only `*`, `<` on the splitmix64 stream).
///
/// All requests share one shape (`prefill_tokens`, `decode_steps`);
/// heterogeneous workloads go through [`trace_arrivals`].
pub fn poisson_arrivals(n_requests: usize, rate: f64, tick: f64,
                        prefill_tokens: usize, decode_steps: usize,
                        seed: u64) -> Vec<Request> {
    assert!(rate > 0.0 && tick > 0.0);
    let p = rate * tick;
    assert!(p < 1.0, "rate * tick must stay below 1 (got {p})");
    let mut rng = Rng::new(seed);
    let mut out = Vec::with_capacity(n_requests);
    let mut i = 0u64;
    while out.len() < n_requests {
        if rng.next_f64() < p {
            out.push(Request {
                id: out.len(),
                arrival: i as f64 * tick,
                prefill_tokens,
                decode_steps,
            });
        }
        i += 1;
    }
    out
}

/// Trace-driven arrivals from explicit `(arrival, prefill_tokens,
/// decode_steps)` rows; the trace must be sorted by arrival time.
pub fn trace_arrivals(trace: &[(f64, usize, usize)]) -> Vec<Request> {
    assert!(trace.windows(2).all(|w| w[0].0 <= w[1].0),
            "trace must be sorted by arrival time");
    trace
        .iter()
        .enumerate()
        .map(|(id, &(arrival, prefill_tokens, decode_steps))| Request {
            id,
            arrival,
            prefill_tokens,
            decode_steps,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seeded_and_sorted() {
        let a = poisson_arrivals(32, 100.0, 1.0 / 2048.0, 128, 4, 7);
        let b = poisson_arrivals(32, 100.0, 1.0 / 2048.0, 128, 4, 7);
        assert_eq!(a.len(), 32);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
        }
        assert!(a.windows(2).all(|w| w[0].arrival <= w[1].arrival));
        assert!(a.windows(2).all(|w| w[0].id + 1 == w[1].id));
    }

    #[test]
    fn poisson_mean_gap_tracks_the_rate() {
        let reqs = poisson_arrivals(512, 200.0, 1.0 / 4096.0, 1, 0, 3);
        let span = reqs.last().unwrap().arrival - reqs[0].arrival;
        let mean_gap = span / 511.0;
        assert!((mean_gap - 1.0 / 200.0).abs() < 1.0 / 400.0,
                "mean inter-arrival {mean_gap} should be near 5 ms");
    }

    #[test]
    #[should_panic(expected = "sorted")]
    fn trace_rejects_unsorted_input() {
        trace_arrivals(&[(1.0, 8, 0), (0.5, 8, 0)]);
    }
}
