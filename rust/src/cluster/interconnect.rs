//! α-β link model and All-to-All cost functions.

/// Point-to-point link: transfer time = alpha + bytes / beta.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// Per-message latency (seconds).
    pub alpha: f64,
    /// Bandwidth (bytes/second).
    pub beta: f64,
}

impl LinkModel {
    /// Build a link from per-message latency `alpha` (seconds, >= 0) and
    /// bandwidth `beta` (bytes/second, > 0).
    pub fn new(alpha: f64, beta: f64) -> LinkModel {
        assert!(alpha >= 0.0 && beta > 0.0);
        LinkModel { alpha, beta }
    }

    /// Time (seconds) to move `bytes` over this link; zero bytes cost
    /// nothing (no message is sent).
    pub fn transfer_time(&self, bytes: usize) -> f64 {
        if bytes == 0 {
            return 0.0;
        }
        self.alpha + bytes as f64 / self.beta
    }

    /// 8×A30 PCIe testbed: *effective* All-to-All goodput (host-mediated
    /// PCIe peer transfers with contention), calibrated so the top-2 comm
    /// share of MoE time lands at the paper's measured 60% (Fig. 1).
    pub fn pcie() -> LinkModel {
        LinkModel::new(10e-6, 2.9e9)
    }

    /// 8×A800 NVSwitch testbed: effective per-GPU A2A goodput, calibrated
    /// to the paper's 15% comm share (Fig. 1 middle).
    pub fn nvlink() -> LinkModel {
        LinkModel::new(1e-6, 50e9)
    }

    /// Inter-node fabric per node (DGX-A800-class nodes bond multiple
    /// 200 Gb NICs); calibrated so the 2-node comm share approaches 50%
    /// (Fig. 1 right).
    pub fn ethernet() -> LinkModel {
        LinkModel::new(30e-6, 30e9)
    }

    /// InfiniBand HDR-class inter-node fabric per node: much lower message
    /// latency than the bonded-Ethernet preset and ~2x its *effective*
    /// all-to-all goodput. Like the other presets this is calibrated
    /// collective goodput, not nameplate hardware bandwidth (rail-count x
    /// line-rate would be several times higher).
    pub fn infiniband() -> LinkModel {
        LinkModel::new(5e-6, 60e9)
    }
}

/// Time (seconds) for an All-to-All where `bytes[src * n + dst]` must move
/// between devices, given one intra-node link per fleet and an optional
/// inter-node bottleneck. Thin wrapper over [`a2a_time_per_node`] with the
/// same link replicated on every node.
pub fn a2a_time(
    bytes: &[usize],
    n_devices: usize,
    devices_per_node: usize,
    intra: LinkModel,
    inter: Option<LinkModel>,
) -> f64 {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let intra = vec![intra; n_devices / devices_per_node];
    a2a_time_per_node(bytes, n_devices, devices_per_node, &intra, inter)
}

/// Time (seconds) for an All-to-All where `bytes[src * n + dst]` must move
/// between devices, with one intra-node [`LinkModel`] *per node* (index =
/// node id; heterogeneous fleets mix PCIe and NVLink nodes) and an
/// optional shared inter-node bottleneck.
///
/// Cost model (congestion-free ring/pairwise-exchange):
///   per-device send time  = α·(messages) + (bytes out)/β_intra
///   node-crossing traffic additionally bounded by β_inter shared per node.
/// The A2A finishes when the slowest device/node finishes.
pub fn a2a_time_per_node(
    bytes: &[usize],
    n_devices: usize,
    devices_per_node: usize,
    intra: &[LinkModel],
    inter: Option<LinkModel>,
) -> f64 {
    a2a_time_split_per_node(bytes, n_devices, devices_per_node, intra, inter).0
}

/// [`a2a_time_per_node`] plus the launch-latency decomposition of the
/// bottleneck: returns `(time, alpha_part)` where `alpha_part` is the
/// α·messages component of whichever device (or node uplink) sets the
/// collective time. Chunked pipelines pay `alpha_part` once per chunk
/// while only the remaining byte term divides (see [`a2a_chunk_time`]).
/// Ties resolve to the first maximum in device order, then node order —
/// deterministic, and the time component is identical to the plain bound.
pub fn a2a_time_split_per_node(
    bytes: &[usize],
    n_devices: usize,
    devices_per_node: usize,
    intra: &[LinkModel],
    inter: Option<LinkModel>,
) -> (f64, f64) {
    assert_eq!(bytes.len(), n_devices * n_devices);
    assert!(n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    assert_eq!(intra.len(), n_nodes, "one intra link per node");
    let node_of = |d: usize| d / devices_per_node;

    let mut worst = (0.0f64, 0.0f64);
    for src in 0..n_devices {
        let mut out_bytes = 0usize;
        let mut msgs = 0usize;
        for dst in 0..n_devices {
            if dst == src {
                continue; // local experts need no transfer
            }
            let b = bytes[src * n_devices + dst];
            if b > 0 {
                out_bytes += b;
                msgs += 1;
            }
        }
        let l = intra[node_of(src)];
        let a = l.alpha * msgs as f64;
        let t = a + out_bytes as f64 / l.beta;
        if t > worst.0 {
            worst = (t, a);
        }
    }

    if let (Some(inter), true) = (inter, n_nodes > 1) {
        for node in 0..n_nodes {
            let mut cross = 0usize;
            for src in 0..n_devices {
                if node_of(src) != node {
                    continue;
                }
                for dst in 0..n_devices {
                    if node_of(dst) != node {
                        cross += bytes[src * n_devices + dst];
                    }
                }
            }
            if cross > 0 {
                let t = inter.alpha + cross as f64 / inter.beta;
                if t > worst.0 {
                    worst = (t, inter.alpha);
                }
            }
        }
    }
    worst
}

/// One chunk's share of a `chunks`-way-pipelined phase whose full
/// (unchunked) time is `full` and whose launch-latency component is
/// `alpha`: every chunk message pays the full α; only the byte term
/// divides. `chunks == 1` returns `full` bit-exactly, so unchunked
/// schedules are untouched by the decomposition.
///
/// This helper is the single source of truth for per-chunk phase times —
/// the legacy `BlockCosts` path and the topology-aware analytic path both
/// call it, so the two models can never disagree on chunking arithmetic.
/// Summed over chunks it charges `full + (chunks - 1) · alpha`: chunking
/// is no longer latency-free, which is exactly the point.
pub fn a2a_chunk_time(full: f64, alpha: f64, chunks: usize) -> f64 {
    assert!(chunks >= 1);
    if chunks == 1 {
        full
    } else {
        alpha + (full - alpha) / chunks as f64
    }
}

/// MoNTA-style per-link decomposition of one All-to-All: the per-device
/// intra-node phase (same-node traffic over the device's NVLink/PCIe
/// egress) and the per-node inter-node phase (node-crossing traffic over
/// the node's shared IB/Ethernet uplink, DMA'd directly to the NIC).
///
/// The two phases run on *different* simulation resources
/// (`simtime::Resource::Comm(device)` vs. `simtime::Resource::Link(node)`),
/// so a topology-aware schedule genuinely overlaps them; the collective
/// completes when every phase task has finished.
#[derive(Debug, Clone)]
pub struct A2aPhases {
    /// Per source device: intra-node phase duration (seconds).
    pub intra: Vec<f64>,
    /// Per source node: inter-node phase duration (seconds); empty when
    /// the topology is single-node or has no inter link.
    pub inter: Vec<f64>,
    /// Per source device: the α·messages launch-latency component of
    /// `intra` (the part every pipeline chunk pays in full).
    pub intra_alpha: Vec<f64>,
    /// Per source node: the α launch-latency component of `inter`; zero
    /// for nodes with no cross traffic, empty when `inter` is empty.
    pub inter_alpha: Vec<f64>,
}

impl A2aPhases {
    /// Completion time when all phases start together (the barrier view:
    /// every phase runs on its own resource).
    pub fn barrier_time(&self) -> f64 {
        let d = self.intra.iter().fold(0.0f64, |m, &t| m.max(t));
        let n = self.inter.iter().fold(0.0f64, |m, &t| m.max(t));
        d.max(n)
    }
}

/// Decompose an All-to-All over `bytes[src * n + dst]` into per-link
/// phases (see [`A2aPhases`]) with one intra-node link per fleet. Thin
/// wrapper over [`a2a_decompose_per_node`] with the same link replicated
/// on every node.
pub fn a2a_decompose(
    bytes: &[usize],
    n_devices: usize,
    devices_per_node: usize,
    intra: LinkModel,
    inter: Option<LinkModel>,
) -> A2aPhases {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let intra = vec![intra; n_devices / devices_per_node];
    a2a_decompose_per_node(bytes, n_devices, devices_per_node, &intra, inter)
}

/// Decompose an All-to-All over `bytes[src * n + dst]` into per-link
/// phases (see [`A2aPhases`]) with one intra-node [`LinkModel`] *per node*
/// (index = node id). Same-node traffic costs
/// `α_intra · messages + bytes / β_intra` on the source device; node-
/// crossing traffic costs `α_inter + bytes / β_inter` on the source node's
/// shared uplink. With a single node (or `inter == None`) every transfer
/// is intra-node and the result reduces to the flat per-device model of
/// [`a2a_time_per_node`].
pub fn a2a_decompose_per_node(
    bytes: &[usize],
    n_devices: usize,
    devices_per_node: usize,
    intra: &[LinkModel],
    inter: Option<LinkModel>,
) -> A2aPhases {
    assert_eq!(bytes.len(), n_devices * n_devices);
    assert!(n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    assert_eq!(intra.len(), n_nodes, "one intra link per node");
    let node_of = |d: usize| d / devices_per_node;
    let split_nodes = inter.is_some() && n_nodes > 1;

    let mut intra_phase = vec![0.0f64; n_devices];
    let mut intra_alpha = vec![0.0f64; n_devices];
    for src in 0..n_devices {
        let mut out_bytes = 0usize;
        let mut msgs = 0usize;
        for dst in 0..n_devices {
            if dst == src || (split_nodes && node_of(dst) != node_of(src)) {
                continue;
            }
            let b = bytes[src * n_devices + dst];
            if b > 0 {
                out_bytes += b;
                msgs += 1;
            }
        }
        let l = intra[node_of(src)];
        intra_alpha[src] = l.alpha * msgs as f64;
        intra_phase[src] = intra_alpha[src] + out_bytes as f64 / l.beta;
    }

    let mut inter_phase = Vec::new();
    let mut inter_alpha = Vec::new();
    if split_nodes {
        let inter = inter.unwrap();
        inter_phase = vec![0.0f64; n_nodes];
        inter_alpha = vec![0.0f64; n_nodes];
        for node in 0..n_nodes {
            let mut cross = 0usize;
            for src in 0..n_devices {
                if node_of(src) != node {
                    continue;
                }
                for dst in 0..n_devices {
                    if node_of(dst) != node {
                        cross += bytes[src * n_devices + dst];
                    }
                }
            }
            if cross > 0 {
                inter_alpha[node] = inter.alpha;
                inter_phase[node] = inter.alpha + cross as f64 / inter.beta;
            }
        }
    }
    A2aPhases { intra: intra_phase, inter: inter_phase, intra_alpha, inter_alpha }
}

/// Byte matrix for a perfectly balanced A2A: every device sends
/// `bytes_per_pair` to every other device (and keeps its local share).
pub fn uniform_a2a_bytes(n_devices: usize, bytes_per_pair: usize) -> Vec<usize> {
    let mut m = vec![0usize; n_devices * n_devices];
    for s in 0..n_devices {
        for d in 0..n_devices {
            if s != d {
                m[s * n_devices + d] = bytes_per_pair;
            }
        }
    }
    m
}

/// Transpose a row-major `[n, n]` byte matrix. The combine All-to-All
/// carries the dispatch traffic in reverse (expert-owner back to token
/// source), so its byte matrix is the transpose of the dispatch matrix.
pub fn a2a_transpose(bytes: &[usize], n_devices: usize) -> Vec<usize> {
    assert_eq!(bytes.len(), n_devices * n_devices);
    let mut out = vec![0usize; n_devices * n_devices];
    for s in 0..n_devices {
        for d in 0..n_devices {
            out[d * n_devices + s] = bytes[s * n_devices + d];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_time_scales() {
        let l = LinkModel::new(1e-6, 1e9);
        assert_eq!(l.transfer_time(0), 0.0);
        assert!((l.transfer_time(1_000_000) - (1e-6 + 1e-3)).abs() < 1e-12);
    }

    #[test]
    fn uniform_a2a_single_node() {
        let l = LinkModel::new(0.0, 1e9);
        let m = uniform_a2a_bytes(4, 1000);
        let t = a2a_time(&m, 4, 4, l, None);
        // each device sends 3 * 1000 bytes
        assert!((t - 3000.0 / 1e9).abs() < 1e-15);
    }

    #[test]
    fn inter_node_bottleneck_dominates() {
        let intra = LinkModel::new(0.0, 100e9);
        let inter = LinkModel::new(0.0, 1e9);
        let m = uniform_a2a_bytes(4, 1_000_000);
        // 2 nodes of 2: each node sends 2 devices x 2 remote dsts x 1MB = 4MB cross
        let t = a2a_time(&m, 4, 2, intra, Some(inter));
        assert!((t - 4e6 / 1e9).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn skewed_matrix_uses_worst_device() {
        let l = LinkModel::new(0.0, 1e9);
        let mut m = vec![0usize; 16];
        m[0 * 4 + 1] = 8000; // device0 sends everything
        let t = a2a_time(&m, 4, 4, l, None);
        assert!((t - 8e3 / 1e9).abs() < 1e-15);
    }

    #[test]
    fn nvlink_faster_than_pcie() {
        let m = uniform_a2a_bytes(8, 1 << 20);
        let tp = a2a_time(&m, 8, 8, LinkModel::pcie(), None);
        let tn = a2a_time(&m, 8, 8, LinkModel::nvlink(), None);
        assert!(tn < tp / 4.0);
    }

    #[test]
    fn decompose_single_node_matches_flat_model() {
        let l = LinkModel::new(2e-6, 1e9);
        let m = uniform_a2a_bytes(4, 1000);
        let p = a2a_decompose(&m, 4, 4, l, None);
        assert!(p.inter.is_empty());
        assert_eq!(p.intra.len(), 4);
        let flat = a2a_time(&m, 4, 4, l, None);
        assert!((p.barrier_time() - flat).abs() < 1e-15);
    }

    #[test]
    fn decompose_splits_cross_node_traffic() {
        let intra = LinkModel::new(0.0, 1e9);
        let inter = LinkModel::new(0.0, 2e9);
        let m = uniform_a2a_bytes(4, 1_000_000);
        // 2 nodes of 2: each device sends 1 MB intra + 2 MB cross;
        // each node sends 4 MB cross over its uplink.
        let p = a2a_decompose(&m, 4, 2, intra, Some(inter));
        for t in &p.intra {
            assert!((t - 1e6 / 1e9).abs() < 1e-12, "intra {t}");
        }
        assert_eq!(p.inter.len(), 2);
        for t in &p.inter {
            assert!((t - 4e6 / 2e9).abs() < 1e-12, "inter {t}");
        }
    }

    #[test]
    fn decompose_skewed_matrix_zero_cross() {
        // all traffic stays inside node 0: uplink phase must be zero
        let intra = LinkModel::new(0.0, 1e9);
        let inter = LinkModel::new(1e-3, 1e9);
        let mut m = vec![0usize; 16];
        m[1] = 5000; // device0 -> device1, same node
        let p = a2a_decompose(&m, 4, 2, intra, Some(inter));
        assert!((p.intra[0] - 5e3 / 1e9).abs() < 1e-15);
        assert_eq!(p.inter, vec![0.0, 0.0]);
    }

    #[test]
    fn infiniband_beats_ethernet_per_node() {
        let ib = LinkModel::infiniband();
        let eth = LinkModel::ethernet();
        assert!(ib.transfer_time(8 << 20) < eth.transfer_time(8 << 20));
    }

    #[test]
    fn per_node_wrappers_are_bit_exact_with_flat_model() {
        // same link on every node: the per-node functions must reproduce
        // the single-link functions exactly (identical arithmetic)
        let intra = LinkModel::new(2e-6, 3e9);
        let inter = Some(LinkModel::new(10e-6, 1e9));
        let m = uniform_a2a_bytes(4, 12_345);
        let links = vec![intra; 2];
        assert_eq!(a2a_time(&m, 4, 2, intra, inter),
                   a2a_time_per_node(&m, 4, 2, &links, inter));
        let a = a2a_decompose(&m, 4, 2, intra, inter);
        let b = a2a_decompose_per_node(&m, 4, 2, &links, inter);
        assert_eq!(a.intra, b.intra);
        assert_eq!(a.inter, b.inter);
    }

    #[test]
    fn per_node_links_differ_per_source_node() {
        // node 0 on a fast link, node 1 on a slow one: the slow node's
        // devices pay more for the same intra-node traffic
        let links = vec![LinkModel::new(0.0, 10e9), LinkModel::new(0.0, 1e9)];
        let mut m = vec![0usize; 16];
        m[1] = 1_000_000; // device0 -> device1 (node 0)
        m[2 * 4 + 3] = 1_000_000; // device2 -> device3 (node 1)
        let p = a2a_decompose_per_node(&m, 4, 2, &links, None);
        assert!((p.intra[0] - 1e6 / 10e9).abs() < 1e-15);
        assert!((p.intra[2] - 1e6 / 1e9).abs() < 1e-15);
    }

    #[test]
    fn chunk_time_preserves_alpha_per_chunk() {
        // unchunked: bit-exact identity
        let full = 0.3 + 0.1;
        assert_eq!(a2a_chunk_time(full, 0.1, 1), full);
        // chunked: α stays whole, bytes divide
        let per = a2a_chunk_time(full, 0.1, 4);
        assert!((per - (0.1 + 0.3 / 4.0)).abs() < 1e-15);
        // total over chunks = full + (chunks-1)·α
        assert!((4.0 * per - (full + 3.0 * 0.1)).abs() < 1e-12);
        // zero α reduces to plain division
        assert_eq!(a2a_chunk_time(0.8, 0.0, 2), 0.4);
    }

    #[test]
    fn time_split_reports_bottleneck_alpha() {
        let intra = LinkModel::new(1e-6, 1e9);
        let m = uniform_a2a_bytes(4, 1000);
        let (t, a) = a2a_time_split_per_node(&m, 4, 4, &[intra; 1], None);
        assert_eq!(t, a2a_time(&m, 4, 4, intra, None));
        assert!((a - 3.0 * 1e-6).abs() < 1e-18, "3 messages worth of α");
        // when the uplink dominates, the α part is the inter link's α
        let slow_inter = Some(LinkModel::new(5e-6, 1e8));
        let (t2, a2) = a2a_time_split_per_node(&m, 4, 2,
                                               &[intra; 2], slow_inter);
        assert!(t2 > t);
        assert!((a2 - 5e-6).abs() < 1e-18);
    }

    #[test]
    fn decompose_reports_phase_alphas() {
        let intra = LinkModel::new(2e-6, 1e9);
        let inter = Some(LinkModel::new(7e-6, 1e9));
        let m = uniform_a2a_bytes(4, 1000);
        let p = a2a_decompose(&m, 4, 2, intra, inter);
        // one same-node peer -> one intra message per device
        assert_eq!(p.intra_alpha, vec![2e-6; 4]);
        assert_eq!(p.inter_alpha, vec![7e-6; 2]);
        // α components are contained in the phases
        for (t, a) in p.intra.iter().zip(&p.intra_alpha) {
            assert!(t >= a);
        }
        // no cross traffic -> zero uplink α
        let mut local = vec![0usize; 16];
        local[1] = 500; // device0 -> device1, same node
        let q = a2a_decompose(&local, 4, 2, intra, inter);
        assert_eq!(q.inter_alpha, vec![0.0, 0.0]);
        assert_eq!(q.intra_alpha[2], 0.0, "idle device sends no messages");
    }

    #[test]
    fn transpose_reverses_src_dst() {
        let m = vec![0, 1, 2, 3, 4, 5, 6, 7, 8];
        let t = a2a_transpose(&m, 3);
        assert_eq!(t, vec![0, 3, 6, 1, 4, 7, 2, 5, 8]);
        // transposing a symmetric matrix is the identity
        let u = uniform_a2a_bytes(4, 9);
        assert_eq!(a2a_transpose(&u, 4), u);
    }
}
