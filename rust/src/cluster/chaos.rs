//! Chaos perturbation layer: per-step fault models over a clean
//! [`Topology`].
//!
//! Real fleets are not the clean presets of
//! [`Scenario`](super::topology::Scenario): devices jitter, some straggle
//! persistently, links degrade or flap, and whole devices drop out. A
//! [`ChaosSpec`] describes such a fault set declaratively;
//! [`ChaosSpec::perturb`] applies it to a topology for one step, producing
//! the perturbed [`Topology`] that `TopoCosts::from_routing` prices like
//! any other fleet:
//!
//! - **compute jitter** — every device's compute scale is divided by
//!   `1 + jitter * u` with `u ~ U[0, 1)` drawn from the spec's seeded
//!   splitmix64 stream, forked per step
//!   ([`Rng::fork`](crate::util::rng::Rng::fork)), so any step of a study
//!   is reproducible in isolation and independent of every other step;
//! - **stragglers** — persistent per-device slowdown factors composing
//!   multiplicatively with the jitter (and, downstream, with
//!   `ExpertLoad`'s load stretching);
//! - **link faults** — α/β degradation of one node's intra link or of the
//!   shared uplink, persistent or *flapping* on a periodic schedule
//!   ([`LinkFault`]);
//! - **dropout** — one device fails at a step ([`Dropout`]); the recovery
//!   (expert failover + migration storm) is priced by
//!   `coordinator::replace::run_chaos_timeline`, not here — the spec only
//!   carries the fault.
//!
//! A zero-magnitude spec ([`ChaosSpec::is_zero`]) perturbs *nothing*:
//! untouched fields are cloned verbatim rather than recomputed, so clean
//! schedules stay bit-identical to never having had a chaos layer at all
//! (the zero-perturbation identity pinned in `rust/tests/chaos_suite.rs`).
//! Every pinned expectation is minted through the DES mirror
//! (`tools/des_mirror/mirror2.py`, PR7 model).

use crate::util::rng::Rng;

use super::interconnect::LinkModel;
use super::topology::Topology;

/// One degraded link: the shared inter-node uplink (`node: None`) or one
/// node's intra-node link, with α multiplied and β divided while the
/// fault is active — persistently, or on a flapping schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkFault {
    /// `None` = the shared inter-node uplink; `Some(n)` = node `n`'s
    /// intra-node link.
    pub node: Option<usize>,
    /// Launch-latency multiplier while active (1.0 = untouched).
    pub alpha_mult: f64,
    /// Bandwidth divisor while active (1.0 = untouched).
    pub beta_div: f64,
    /// `None` = persistent; `Some((period, up))` = the link is healthy
    /// for `up` steps then degraded for the rest of each `period`-step
    /// cycle (degraded exactly when `step % period >= up`).
    pub flap: Option<(usize, usize)>,
}

impl LinkFault {
    /// Whether the fault degrades its link at this step.
    pub fn active(&self, step: usize) -> bool {
        match self.flap {
            None => true,
            Some((period, up)) => step % period >= up,
        }
    }
}

/// Whole-device failure at a step. `run_chaos_timeline` prices the
/// recovery: the failed device's experts fail over to survivors and the
/// resulting migration storm overlaps the recovery step as H2D tasks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Dropout {
    /// The failing device.
    pub device: usize,
    /// 0-based step at which it fails.
    pub at_step: usize,
}

/// A declarative fault set over a fleet: jitter + stragglers + link
/// faults + at most one device dropout. See the module docs for the
/// semantics of each field.
#[derive(Debug, Clone)]
pub struct ChaosSpec {
    /// Jitter stream seed (forked per step, so steps draw independently).
    pub seed: u64,
    /// Max fractional per-device compute slowdown per step (0 = none).
    pub jitter: f64,
    /// Persistent `(device, slowdown factor)` stragglers.
    pub stragglers: Vec<(usize, f64)>,
    /// Degraded / flapping links.
    pub link_faults: Vec<LinkFault>,
    /// Whole-device failure, if any.
    pub dropout: Option<Dropout>,
}

impl ChaosSpec {
    /// The fault-free spec (named seed, zero magnitudes).
    pub fn clean(seed: u64) -> ChaosSpec {
        ChaosSpec {
            seed,
            jitter: 0.0,
            stragglers: Vec::new(),
            link_faults: Vec::new(),
            dropout: None,
        }
    }

    /// True when every magnitude is the identity: no jitter, only 1.0x
    /// stragglers, only identity link faults, no dropout. Such a spec's
    /// [`Self::perturb`] is a field-exact clone.
    pub fn is_zero(&self) -> bool {
        self.jitter == 0.0
            && self.stragglers.iter().all(|&(_, f)| f == 1.0)
            && self
                .link_faults
                .iter()
                .all(|f| f.alpha_mult == 1.0 && f.beta_div == 1.0)
            && self.dropout.is_none()
    }

    /// Apply the spec to a topology for one step. Only the faulted
    /// fields change: jitter/stragglers materialize `device_scales`,
    /// intra-link faults materialize `node_intra`, uplink faults rewrite
    /// `inter` — everything a zero-magnitude spec never touches is the
    /// clone's verbatim copy (the bit-exactness guarantee).
    pub fn perturb(&self, topo: &Topology, step: usize) -> Topology {
        let mut out = topo.clone();
        let straggling = self.stragglers.iter().any(|&(_, f)| f != 1.0);
        if self.jitter > 0.0 || straggling {
            let mut scales: Vec<f64> = (0..topo.n_devices)
                .map(|d| topo.device_compute_scale(d))
                .collect();
            if self.jitter > 0.0 {
                let mut rng = Rng::new(self.seed).fork(step as u64);
                for s in scales.iter_mut() {
                    *s /= 1.0 + self.jitter * rng.next_f64();
                }
            }
            for &(d, f) in &self.stragglers {
                scales[d] /= f;
            }
            out.device_scales = Some(scales);
        }
        let mut links: Option<Vec<LinkModel>> = None;
        for f in &self.link_faults {
            if (f.alpha_mult == 1.0 && f.beta_div == 1.0) || !f.active(step) {
                continue;
            }
            match f.node {
                None => {
                    let l = out
                        .inter
                        .expect("uplink fault on a single-node topology");
                    out.inter = Some(LinkModel::new(l.alpha * f.alpha_mult,
                                                    l.beta / f.beta_div));
                }
                Some(n) => {
                    let v = links.get_or_insert_with(|| topo.intra_links());
                    let l = v[n];
                    v[n] = LinkModel::new(l.alpha * f.alpha_mult,
                                          l.beta / f.beta_div);
                }
            }
        }
        if let Some(v) = links {
            out.node_intra = Some(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dyadic_topo() -> Topology {
        Topology {
            n_devices: 4,
            devices_per_node: 2,
            intra: LinkModel::new(0.0625, 1024.0),
            inter: Some(LinkModel::new(0.125, 512.0)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        }
    }

    #[test]
    fn zero_magnitude_spec_touches_no_field() {
        // 1.0x stragglers, identity uplink faults and never-active flap
        // schedules all count as zero — and perturb leaves every field
        // verbatim (mirror consistency_checks7 case 1)
        let topo = dyadic_topo();
        let zero = ChaosSpec {
            seed: 9,
            jitter: 0.0,
            stragglers: vec![(2, 1.0)],
            link_faults: vec![
                LinkFault { node: None, alpha_mult: 1.0, beta_div: 1.0,
                            flap: None },
                LinkFault { node: Some(0), alpha_mult: 2.0, beta_div: 2.0,
                            flap: Some((4, 4)) },
            ],
            dropout: None,
        };
        assert!(zero.is_zero());
        assert!(ChaosSpec::clean(9).is_zero());
        assert!(!ChaosSpec {
            dropout: Some(Dropout { device: 0, at_step: 0 }),
            ..ChaosSpec::clean(9)
        }
        .is_zero());
        for step in 0..4 {
            let pt = zero.perturb(&topo, step);
            assert_eq!(pt.device_scales, None);
            assert_eq!(pt.node_intra, None);
            assert_eq!(pt.inter, topo.inter);
            assert_eq!(pt.intra, topo.intra);
        }
    }

    #[test]
    fn jitter_is_seed_deterministic_and_fork_true() {
        // identical seed+step => identical scales; distinct seed or step
        // => distinct draws; and the draws follow the fork(step) stream
        // contract shared with util::rng (mirror case 3)
        let topo = dyadic_topo();
        let spec = ChaosSpec { jitter: 0.25, ..ChaosSpec::clean(41) };
        let a1 = spec.perturb(&topo, 2);
        let a2 = spec.perturb(&topo, 2);
        assert_eq!(a1.device_scales, a2.device_scales);
        let b = ChaosSpec { jitter: 0.25, ..ChaosSpec::clean(42) }
            .perturb(&topo, 2);
        assert_ne!(a1.device_scales, b.device_scales);
        let c = spec.perturb(&topo, 3);
        assert_ne!(a1.device_scales, c.device_scales);
        let mut manual = Rng::new(41).fork(2);
        let expect: Vec<f64> = (0..4)
            .map(|_| 1.0 / (1.0 + 0.25 * manual.next_f64()))
            .collect();
        assert_eq!(a1.device_scales, Some(expect));
    }

    #[test]
    fn stragglers_compose_multiplicatively_with_jitter() {
        let topo = dyadic_topo();
        let jittered = ChaosSpec { jitter: 0.25, ..ChaosSpec::clean(41) }
            .perturb(&topo, 2);
        let both = ChaosSpec {
            jitter: 0.25,
            stragglers: vec![(3, 2.0)],
            ..ChaosSpec::clean(41)
        }
        .perturb(&topo, 2);
        let j = jittered.device_scales.unwrap();
        let s = both.device_scales.unwrap();
        assert_eq!(s[..3], j[..3]);
        assert_eq!(s[3], j[3] / 2.0);
    }

    #[test]
    fn flap_schedule_gates_uplink_faults_per_step() {
        let topo = dyadic_topo();
        let flap = ChaosSpec {
            link_faults: vec![LinkFault { node: None, alpha_mult: 2.0,
                                          beta_div: 4.0, flap: Some((4, 2)) }],
            ..ChaosSpec::clean(0)
        };
        for step in 0..8 {
            let pt = flap.perturb(&topo, step);
            if step % 4 >= 2 {
                assert_eq!(pt.inter, Some(LinkModel::new(0.25, 128.0)));
            } else {
                assert_eq!(pt.inter, topo.inter);
            }
        }
    }

    #[test]
    fn intra_fault_materializes_node_intra_and_leaves_inter() {
        let topo = dyadic_topo();
        let pt = ChaosSpec {
            link_faults: vec![LinkFault { node: Some(1), alpha_mult: 2.0,
                                          beta_div: 2.0, flap: None }],
            ..ChaosSpec::clean(0)
        }
        .perturb(&topo, 0);
        assert_eq!(pt.node_intra,
                   Some(vec![LinkModel::new(0.0625, 1024.0),
                             LinkModel::new(0.125, 512.0)]));
        assert_eq!(pt.inter, topo.inter);
        pt.assert_valid();
    }
}
