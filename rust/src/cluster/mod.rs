//! Simulated device fleet + interconnect models.
//!
//! The paper's testbeds (8×A30-PCIe, 8×A800-NVLink, 16×A800 across two
//! nodes) are modeled as `Topology` (devices, nodes, per-device compute
//! scales) + `LinkModel` (α latency + bytes/β bandwidth per message).
//! Presets are calibrated so the All-to-All share of total MoE time
//! reproduces the paper's measured fractions (Fig. 1: 60% on PCIe, 15% on
//! NVLink, ≈50% across 2 nodes) — see DESIGN.md §6 for the calibration
//! method. `Scenario::extended()` adds multi-node InfiniBand and
//! heterogeneous presets beyond the paper's testbeds.
//!
//! All-to-All costs come in two granularities: the flat [`a2a_time`]
//! bound (one number per collective, used by the single-representative-
//! device schedules) and the MoNTA-style [`a2a_decompose`] per-link phase
//! split (per-device intra-node + per-node inter-node), which the
//! topology-aware DES schedules on distinct contended resources. Both have
//! `*_per_node` variants taking one intra [`LinkModel`] per node for
//! fleets that mix PCIe and NVLink nodes, and both consume an arbitrary
//! `[n, n]` byte matrix — uniform ([`uniform_a2a_bytes`]) or derived from
//! real routing decisions (`moe::RoutingTable::a2a_bytes_placed`).

//!
//! The `chaos` perturbation layer describes what real fleets add on top
//! of the clean presets — per-device compute jitter, persistent
//! stragglers, degraded or flapping links, and whole-device dropout — as
//! a declarative [`ChaosSpec`] whose per-step `perturb` yields an
//! ordinary `Topology` the cost constructors price unchanged.

pub mod chaos;
pub mod interconnect;
pub mod topology;

pub use chaos::{ChaosSpec, Dropout, LinkFault};
pub use interconnect::{
    a2a_chunk_time, a2a_decompose, a2a_decompose_per_node, a2a_time,
    a2a_time_per_node, a2a_time_split_per_node, a2a_transpose,
    uniform_a2a_bytes, A2aPhases, LinkModel,
};
pub use topology::{Scenario, Topology};
