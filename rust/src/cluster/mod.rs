//! Simulated device fleet + interconnect models.
//!
//! The paper's testbeds (8×A30-PCIe, 8×A800-NVLink, 16×A800 across two
//! nodes) are modeled as `Topology` (devices, nodes) + `LinkModel`
//! (α latency + bytes/β bandwidth per message). Presets are calibrated so
//! the All-to-All share of total MoE time reproduces the paper's measured
//! fractions (Fig. 1: 60% on PCIe, 15% on NVLink, ≈50% across 2 nodes) —
//! see DESIGN.md §6 for the calibration method.

pub mod interconnect;
pub mod topology;

pub use interconnect::{a2a_time, uniform_a2a_bytes, LinkModel};
pub use topology::{Scenario, Topology};
