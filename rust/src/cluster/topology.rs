//! Named hardware scenarios matching the paper's testbeds.

use super::interconnect::LinkModel;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// 8×A30, PCIe only (Fig. 1 left: comm ≈ 60% of MoE time).
    PcieA30x8,
    /// 8×A800 with NVLink (comm ≈ 15%).
    NvlinkA800x8,
    /// 16×A800 across 2 nodes over Ethernet (comm ≈ 50%).
    TwoNodeA800x16,
}

impl Scenario {
    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "pcie" | "8xA30-PCIe" => Some(Scenario::PcieA30x8),
            "nvlink" | "8xA800-NVLink" => Some(Scenario::NvlinkA800x8),
            "2node" | "16xA800-2node" => Some(Scenario::TwoNodeA800x16),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Scenario::PcieA30x8 => "8xA30-PCIe",
            Scenario::NvlinkA800x8 => "8xA800-NVLink",
            Scenario::TwoNodeA800x16 => "16xA800-2node",
        }
    }

    pub fn all() -> [Scenario; 3] {
        [Scenario::PcieA30x8, Scenario::NvlinkA800x8, Scenario::TwoNodeA800x16]
    }

    pub fn topology(&self) -> Topology {
        match self {
            Scenario::PcieA30x8 => Topology {
                n_devices: 8,
                devices_per_node: 8,
                intra: LinkModel::pcie(),
                inter: None,
                // A30: 165 TFLOPS bf16 tensor — relative compute scale 1.0
                compute_scale: 1.0,
            },
            Scenario::NvlinkA800x8 => Topology {
                n_devices: 8,
                devices_per_node: 8,
                intra: LinkModel::nvlink(),
                inter: None,
                // A800 ~1.9x A30 on the dense kernels in this proxy
                compute_scale: 1.9,
            },
            Scenario::TwoNodeA800x16 => Topology {
                n_devices: 16,
                devices_per_node: 8,
                intra: LinkModel::nvlink(),
                inter: Some(LinkModel::ethernet()),
                compute_scale: 1.9,
            },
        }
    }
}

#[derive(Debug, Clone)]
pub struct Topology {
    pub n_devices: usize,
    pub devices_per_node: usize,
    pub intra: LinkModel,
    pub inter: Option<LinkModel>,
    /// Device compute speed relative to the A30 baseline (divides op times).
    pub compute_scale: f64,
}

impl Topology {
    pub fn n_nodes(&self) -> usize {
        self.n_devices / self.devices_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in Scenario::all() {
            assert_eq!(Scenario::parse(s.label()), Some(s));
        }
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn two_node_has_inter_link() {
        let t = Scenario::TwoNodeA800x16.topology();
        assert_eq!(t.n_nodes(), 2);
        assert!(t.inter.is_some());
    }
}
