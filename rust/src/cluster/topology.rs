//! Named hardware scenarios matching the paper's testbeds, plus extended
//! hierarchical presets for the multi-device topology-aware DES.
//!
//! A [`Topology`] describes the device fleet the scheduler models: device
//! and node counts, the intra-node link, the optional shared inter-node
//! uplink, and per-device compute speed (heterogeneous fleets supply a
//! per-device scale vector). The three paper testbeds ([`Scenario::all`])
//! stay calibrated to Fig. 1's communication shares; [`Scenario::extended`]
//! adds a multi-node InfiniBand preset and a mixed A800+A30 preset for
//! scenario-diversity studies.

use super::interconnect::LinkModel;

/// Named hardware preset (paper testbeds + extended fleets); see the
/// module docs for calibration notes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// 8×A30, PCIe only (Fig. 1 left: comm ≈ 60% of MoE time).
    PcieA30x8,
    /// 8×A800 with NVLink (comm ≈ 15%).
    NvlinkA800x8,
    /// 16×A800 across 2 nodes over Ethernet (comm ≈ 50%).
    TwoNodeA800x16,
    /// 32×A800 across 4 nodes over an InfiniBand-class fabric
    /// (multi-node IB preset for the topology-aware DES).
    FourNodeA800IBx32,
    /// Heterogeneous 2-node fleet: one NVLink node of A800s plus one node
    /// of A30s, bridged by Ethernet (mixed preset: stragglers shift the
    /// overlap window per device).
    HeteroA800A30x8,
}

impl Scenario {
    /// Parse a preset from its short alias (`"pcie"`) or full label
    /// (`"8xA30-PCIe"`); `None` for unknown names.
    pub fn parse(s: &str) -> Option<Scenario> {
        match s {
            "pcie" | "8xA30-PCIe" => Some(Scenario::PcieA30x8),
            "nvlink" | "8xA800-NVLink" => Some(Scenario::NvlinkA800x8),
            "2node" | "16xA800-2node" => Some(Scenario::TwoNodeA800x16),
            "4node-ib" | "32xA800-4node-IB" => Some(Scenario::FourNodeA800IBx32),
            "hetero" | "8xA800+A30-hetero" => Some(Scenario::HeteroA800A30x8),
            _ => None,
        }
    }

    /// Canonical display label (also accepted by [`Self::parse`]).
    pub fn label(&self) -> &'static str {
        match self {
            Scenario::PcieA30x8 => "8xA30-PCIe",
            Scenario::NvlinkA800x8 => "8xA800-NVLink",
            Scenario::TwoNodeA800x16 => "16xA800-2node",
            Scenario::FourNodeA800IBx32 => "32xA800-4node-IB",
            Scenario::HeteroA800A30x8 => "8xA800+A30-hetero",
        }
    }

    /// The paper's three calibrated testbeds (Fig. 1 bands).
    pub fn all() -> [Scenario; 3] {
        [Scenario::PcieA30x8, Scenario::NvlinkA800x8, Scenario::TwoNodeA800x16]
    }

    /// Every preset, including the extended multi-node and heterogeneous
    /// topologies that go beyond the paper's testbeds.
    pub fn extended() -> [Scenario; 5] {
        [
            Scenario::PcieA30x8,
            Scenario::NvlinkA800x8,
            Scenario::TwoNodeA800x16,
            Scenario::FourNodeA800IBx32,
            Scenario::HeteroA800A30x8,
        ]
    }

    /// Materialize the preset's [`Topology`] (device/node counts, link
    /// models, per-device compute scales).
    pub fn topology(&self) -> Topology {
        match self {
            Scenario::PcieA30x8 => Topology {
                n_devices: 8,
                devices_per_node: 8,
                intra: LinkModel::pcie(),
                inter: None,
                // A30: 165 TFLOPS bf16 tensor — relative compute scale 1.0
                compute_scale: 1.0,
                device_scales: None,
                node_intra: None,
            },
            Scenario::NvlinkA800x8 => Topology {
                n_devices: 8,
                devices_per_node: 8,
                intra: LinkModel::nvlink(),
                inter: None,
                // A800 ~1.9x A30 on the dense kernels in this proxy
                compute_scale: 1.9,
                device_scales: None,
                node_intra: None,
            },
            Scenario::TwoNodeA800x16 => Topology {
                n_devices: 16,
                devices_per_node: 8,
                intra: LinkModel::nvlink(),
                inter: Some(LinkModel::ethernet()),
                compute_scale: 1.9,
                device_scales: None,
                node_intra: None,
            },
            Scenario::FourNodeA800IBx32 => Topology {
                n_devices: 32,
                devices_per_node: 8,
                intra: LinkModel::nvlink(),
                inter: Some(LinkModel::infiniband()),
                compute_scale: 1.9,
                device_scales: None,
                node_intra: None,
            },
            Scenario::HeteroA800A30x8 => Topology {
                n_devices: 8,
                devices_per_node: 4,
                intra: LinkModel::nvlink(),
                inter: Some(LinkModel::ethernet()),
                compute_scale: 1.9,
                // node 0: A800s; node 1: A30s (the stragglers)
                device_scales: Some(vec![1.9, 1.9, 1.9, 1.9, 1.0, 1.0, 1.0, 1.0]),
                // the A800 node has NVSwitch; the A30 node is PCIe-only,
                // so its intra-node A2A phases run on the slower link
                node_intra: Some(vec![LinkModel::nvlink(), LinkModel::pcie()]),
            },
        }
    }
}

/// A modeled device fleet: device/node counts, intra- and inter-node
/// link models, and per-device compute speed (relative to the A30
/// baseline; divides operator durations).
#[derive(Debug, Clone)]
pub struct Topology {
    /// Total modeled devices.
    pub n_devices: usize,
    /// Devices per node (contiguous block node layout).
    pub devices_per_node: usize,
    /// Default intra-node link (NVLink/PCIe class), shared by every node
    /// unless `node_intra` overrides it per node.
    pub intra: LinkModel,
    /// Shared inter-node uplink (IB/Ethernet class); `None` on single-node
    /// topologies.
    pub inter: Option<LinkModel>,
    /// Device compute speed relative to the A30 baseline (divides op times).
    pub compute_scale: f64,
    /// Per-device compute scales for heterogeneous fleets; `None` means
    /// every device runs at `compute_scale`.
    pub device_scales: Option<Vec<f64>>,
    /// Per-node intra links for heterogeneous fleets (index = node id);
    /// `None` means every node uses `intra`. Lets a PCIe-only node coexist
    /// with NVSwitch nodes in one fleet.
    pub node_intra: Option<Vec<LinkModel>>,
}

impl Topology {
    /// Validate internal consistency; cost constructors call this so a
    /// malformed hand-built topology fails at the source instead of as an
    /// index panic deep inside cost derivation.
    pub fn assert_valid(&self) {
        assert!(self.n_devices > 0 && self.devices_per_node > 0);
        assert!(self.n_devices % self.devices_per_node == 0,
                "devices ({}) must divide into nodes of {}",
                self.n_devices, self.devices_per_node);
        if let Some(v) = &self.device_scales {
            assert_eq!(v.len(), self.n_devices,
                       "device_scales length must equal n_devices");
            assert!(v.iter().all(|&s| s > 0.0), "compute scales must be positive");
        }
        if let Some(v) = &self.node_intra {
            assert_eq!(v.len(), self.n_nodes(),
                       "node_intra length must equal the node count");
        }
    }

    /// Number of nodes in the fleet.
    pub fn n_nodes(&self) -> usize {
        self.n_devices / self.devices_per_node
    }

    /// Node owning a device (contiguous block layout).
    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    /// Compute scale of one device (heterogeneity-aware).
    pub fn device_compute_scale(&self, device: usize) -> f64 {
        match &self.device_scales {
            Some(v) => v[device],
            None => self.compute_scale,
        }
    }

    /// Intra-node link of every node (index = node id): the per-node
    /// override when present, otherwise the fleet-wide `intra` replicated.
    /// This is the vector the per-node A2A cost functions consume.
    pub fn intra_links(&self) -> Vec<LinkModel> {
        match &self.node_intra {
            Some(v) => v.clone(),
            None => vec![self.intra; self.n_nodes()],
        }
    }

    /// Compute scale of the slowest device. The single-representative-
    /// device cost model uses this: on a heterogeneous fleet the barrier
    /// collectives are gated by the stragglers, so the representative
    /// device must be the slow one.
    pub fn min_compute_scale(&self) -> f64 {
        match &self.device_scales {
            Some(v) => v.iter().copied().fold(f64::INFINITY, f64::min),
            None => self.compute_scale,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in Scenario::extended() {
            assert_eq!(Scenario::parse(s.label()), Some(s));
        }
        assert_eq!(Scenario::parse("nope"), None);
    }

    #[test]
    fn two_node_has_inter_link() {
        let t = Scenario::TwoNodeA800x16.topology();
        assert_eq!(t.n_nodes(), 2);
        assert!(t.inter.is_some());
    }

    #[test]
    fn four_node_ib_shape() {
        let t = Scenario::FourNodeA800IBx32.topology();
        assert_eq!(t.n_devices, 32);
        assert_eq!(t.n_nodes(), 4);
        assert!(t.inter.is_some());
        assert_eq!(t.node_of(7), 0);
        assert_eq!(t.node_of(8), 1);
    }

    #[test]
    fn hetero_scales_per_device() {
        let t = Scenario::HeteroA800A30x8.topology();
        assert_eq!(t.n_nodes(), 2);
        assert_eq!(t.device_compute_scale(0), 1.9);
        assert_eq!(t.device_compute_scale(7), 1.0);
        // homogeneous presets fall back to the fleet scale
        let n = Scenario::NvlinkA800x8.topology();
        assert_eq!(n.device_compute_scale(3), 1.9);
    }

    #[test]
    fn hetero_has_per_node_intra_links() {
        // A800 node on NVLink, A30 node on PCIe
        let t = Scenario::HeteroA800A30x8.topology();
        let links = t.intra_links();
        assert_eq!(links.len(), 2);
        assert_eq!(links[0], LinkModel::nvlink());
        assert_eq!(links[1], LinkModel::pcie());
        // homogeneous presets replicate the fleet-wide intra link
        let n = Scenario::FourNodeA800IBx32.topology();
        assert_eq!(n.intra_links(), vec![LinkModel::nvlink(); 4]);
    }

    #[test]
    #[should_panic(expected = "node_intra length")]
    fn short_node_intra_vector_fails_validation() {
        let mut t = Scenario::TwoNodeA800x16.topology();
        t.node_intra = Some(vec![LinkModel::nvlink()]);
        t.assert_valid();
    }
}
