//! Reusable simulation arena: cached graph skeletons + shared run buffers.
//!
//! Policy search re-evaluates near-identical graphs thousands of times —
//! `choose_slot` prices four slots per step, `ReplacePolicy::BreakEven`
//! prices a candidate placement per step, and the serving loop prices
//! what-ifs per batch. The graph *structure* (task count, resources,
//! labels, dependency lists) is fully determined by the schedule's shape;
//! only durations change between evaluations. A [`SimArena`] caches built
//! skeletons keyed by an injective [`GraphShape`] so a repeat build becomes
//! a warm start: the builder replays over the cached skeleton re-pricing
//! durations in place (no label formatting, no dependency copies, no
//! allocation), and the run reuses the cached dependents index plus one
//! shared set of engine buffers.
//!
//! Contract:
//!
//! * `begin(shape)` → `true` (warm) enters re-pricing mode over the cached
//!   skeleton for `shape`; `false` (cold) provides an empty [`Sim`] to
//!   append into. Either way the caller then replays the *same* builder
//!   and calls [`SimArena::finish`].
//! * Warm and cold paths are bit-identical by construction: a warm build
//!   only ever overwrites durations of a skeleton produced by the same
//!   builder under the same shape, and [`GraphShape`] keys are injective
//!   mappings of every structure-determining input (no hashing), so a
//!   stale-cache hit is impossible rather than merely unlikely.
//! * Fallback to a full rebuild is automatic on structural change: a new
//!   shape misses the cache (cold build into a fresh or LRU-evicted slot),
//!   and tasks appended after `finish` (e.g. migration what-ifs via
//!   [`SimArena::sim_mut`]) are truncated away by the next `begin`.
//! * Capacity is bounded: at most [`SimArena::MAX_SLOTS`] skeletons are
//!   retained, evicting the least recently used.

use super::engine::{DependentsIndex, RunBuffers, Sim, Span, TracedRun};

/// Injective structural key for a cached skeleton. Producers (e.g.
/// `ScheduleSpec::shape`) must encode *every* input that influences the
/// builder's control flow — task order, resources, labels and dependency
/// lists — and no input that only influences durations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GraphShape(pub [u64; 8]);

struct Slot {
    shape: GraphShape,
    sim: Sim,
    /// Task count at the last `finish` — what `begin` truncates back to.
    built_len: usize,
    /// Cached adjacency, revalidated lazily against `sim`'s structural
    /// version (stays valid across pure re-pricing).
    index: DependentsIndex,
    last_used: u64,
}

/// See the module docs. One arena per evaluation loop; two independent
/// loops over the same shapes (e.g. a timeline step and its break-even
/// probe) need two arenas, otherwise the second build re-prices the
/// durations out from under the first.
#[derive(Default)]
pub struct SimArena {
    slots: Vec<Slot>,
    bufs: RunBuffers,
    active: usize,
    tick: u64,
}

impl SimArena {
    /// Maximum cached skeletons (LRU beyond this). Covers the four
    /// `choose_slot` candidates plus a full strategy × chunk-count sweep
    /// through one arena without thrashing.
    pub const MAX_SLOTS: usize = 16;

    pub fn new() -> SimArena {
        SimArena::default()
    }

    /// Start a build for `shape`. Returns `true` if a cached skeleton was
    /// found (the builder's `add` calls will re-price it in place), else
    /// `false` (the builder appends into an empty sim). Call
    /// [`SimArena::finish`] after the builder completes.
    pub fn begin(&mut self, shape: GraphShape) -> bool {
        self.tick += 1;
        if let Some(i) = self.slots.iter().position(|s| s.shape == shape) {
            self.active = i;
            let slot = &mut self.slots[i];
            slot.last_used = self.tick;
            slot.sim.truncate(slot.built_len);
            slot.sim.begin_reprice();
            return true;
        }
        let i = if self.slots.len() < Self::MAX_SLOTS {
            self.slots.push(Slot {
                shape,
                sim: Sim::new(),
                built_len: 0,
                index: DependentsIndex::default(),
                last_used: self.tick,
            });
            self.slots.len() - 1
        } else {
            let (i, _) = self
                .slots
                .iter()
                .enumerate()
                .min_by_key(|(_, s)| s.last_used)
                .expect("MAX_SLOTS > 0");
            let slot = &mut self.slots[i];
            slot.shape = shape;
            slot.sim.clear();
            slot.built_len = 0;
            slot.last_used = self.tick;
            i
        };
        self.active = i;
        false
    }

    /// End the build started by the last [`SimArena::begin`]: asserts a
    /// warm re-price covered the whole skeleton (a structural drift under
    /// an unchanged shape is a bug, not a fallback) and records the built
    /// length for the next warm start.
    pub fn finish(&mut self) {
        let slot = &mut self.slots[self.active];
        slot.sim.finish_reprice();
        slot.built_len = slot.sim.len();
    }

    /// The active slot's sim (the one most recently built via
    /// `begin`/`finish`). Panics if nothing was built yet.
    pub fn sim(&self) -> &Sim {
        &self.slots[self.active].sim
    }

    /// Mutable access to the active sim, for appending what-if tasks
    /// (e.g. `MigrationPlan::add_transfer_tasks`) after `finish`. Appends
    /// are priced by the next run and shed by the next `begin`.
    pub fn sim_mut(&mut self) -> &mut Sim {
        &mut self.slots[self.active].sim
    }

    /// Makespan of the active sim on the fast engine, reusing the slot's
    /// cached dependents index and the arena's shared run buffers; no
    /// spans are materialized. Bit-identical to `self.sim().makespan()`.
    pub fn makespan(&mut self) -> f64 {
        let slot = &mut self.slots[self.active];
        slot.index.ensure(&slot.sim);
        slot.sim.run_fast(&slot.index, &mut self.bufs, false)
    }

    /// Spans of the active sim (bit-identical to `self.sim().run()`).
    pub fn run(&mut self) -> Vec<Span> {
        let slot = &mut self.slots[self.active];
        slot.index.ensure(&slot.sim);
        slot.sim.run_fast(&slot.index, &mut self.bufs, false);
        slot.sim.materialize_spans(&self.bufs)
    }

    /// Traced run of the active sim (bit-identical to
    /// `self.sim().run_traced()`).
    pub fn run_traced(&mut self) -> TracedRun {
        let slot = &mut self.slots[self.active];
        slot.index.ensure(&slot.sim);
        slot.sim.run_fast(&slot.index, &mut self.bufs, true);
        TracedRun {
            spans: slot.sim.materialize_spans(&self.bufs),
            blockers: self.bufs.blockers.clone(),
        }
    }

    /// Number of currently cached skeletons (test/bench introspection).
    pub fn cached_slots(&self) -> usize {
        self.slots.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::Resource;

    fn shape(tag: u64) -> GraphShape {
        GraphShape([tag, 0, 0, 0, 0, 0, 0, 0])
    }

    // a two-task builder whose durations come from `scale`
    fn build_pair(sim: &mut Sim, scale: f64) {
        let a = sim.add("a", Resource::Compute(0), 1.0 * scale, &[]);
        sim.add("b", Resource::Comm(0), 2.0 * scale, &[a]);
    }

    #[test]
    fn warm_start_reprices_and_matches_cold() {
        let mut arena = SimArena::new();
        assert!(!arena.begin(shape(1)));
        build_pair(arena.sim_mut(), 1.0);
        arena.finish();
        assert_eq!(arena.makespan(), 3.0);

        // same shape again: warm, durations re-priced
        assert!(arena.begin(shape(1)));
        build_pair(arena.sim_mut(), 2.0);
        arena.finish();
        assert_eq!(arena.makespan(), 6.0);

        let mut cold = Sim::new();
        build_pair(&mut cold, 2.0);
        assert_eq!(arena.makespan().to_bits(), cold.makespan().to_bits());
    }

    #[test]
    fn different_shape_is_cold() {
        let mut arena = SimArena::new();
        assert!(!arena.begin(shape(1)));
        build_pair(arena.sim_mut(), 1.0);
        arena.finish();
        assert!(!arena.begin(shape(2)));
        build_pair(arena.sim_mut(), 1.0);
        arena.finish();
        assert_eq!(arena.cached_slots(), 2);
        // revisiting either shape is warm again
        assert!(arena.begin(shape(1)));
        build_pair(arena.sim_mut(), 3.0);
        arena.finish();
        assert_eq!(arena.makespan(), 9.0);
    }

    #[test]
    fn appended_tasks_are_shed_by_next_begin() {
        let mut arena = SimArena::new();
        arena.begin(shape(1));
        build_pair(arena.sim_mut(), 1.0);
        arena.finish();
        // what-if append: an H2D task serialized after nothing
        arena.sim_mut().add("mig", Resource::H2D(0), 10.0, &[]);
        assert_eq!(arena.makespan(), 10.0);
        // next warm build drops the append and re-prices the skeleton
        assert!(arena.begin(shape(1)));
        build_pair(arena.sim_mut(), 1.0);
        arena.finish();
        assert_eq!(arena.sim().len(), 2);
        assert_eq!(arena.makespan(), 3.0);
    }

    #[test]
    fn lru_eviction_bounds_memory_and_stays_correct() {
        let mut arena = SimArena::new();
        let n_shapes = SimArena::MAX_SLOTS as u64 + 4;
        for round in 0..3u64 {
            for tag in 0..n_shapes {
                let warm = arena.begin(shape(tag));
                // with more shapes than slots cycling in order, every
                // visit misses (the LRU evicts ahead of reuse)
                assert!(!warm, "round {round} tag {tag}");
                build_pair(arena.sim_mut(), (tag + 1) as f64);
                arena.finish();
                assert_eq!(arena.makespan(), 3.0 * (tag + 1) as f64);
                assert!(arena.cached_slots() <= SimArena::MAX_SLOTS);
            }
        }
    }
}
