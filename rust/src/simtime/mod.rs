//! Deterministic discrete-event simulator for operator schedules.
//!
//! Models the paper's execution environment (Fig. 6): each device has one
//! *compute stream* (exclusive — "computation operators are unable to
//! execute concurrently due to the constraints on computing resources") and
//! one *comm stream* that runs All-to-All transfers concurrently with
//! compute. Tasks form a DAG; the engine performs resource-constrained list
//! scheduling with deterministic tie-breaking, returning per-task spans that
//! the timeline renderer and the experiment harness consume.

pub mod engine;

pub use engine::{Resource, Sim, Span, TaskId, TaskSpec};
