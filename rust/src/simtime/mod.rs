//! Deterministic discrete-event simulator for operator schedules.
//!
//! Models the paper's execution environment (Fig. 6): each device has one
//! *compute stream* (exclusive — "computation operators are unable to
//! execute concurrently due to the constraints on computing resources") and
//! one *comm stream* that runs All-to-All transfers concurrently with
//! compute. Tasks form a DAG; the engine performs resource-constrained list
//! scheduling with deterministic tie-breaking, returning per-task spans that
//! the timeline renderer and the experiment harness consume.
//!
//! Multi-device, topology-aware schedules instantiate one compute/comm
//! stream pair per modeled device plus one shared [`Resource::Link`] per
//! node, so the MoNTA-style intra-node vs. inter-node All-to-All phase
//! decomposition (see `cluster::interconnect::a2a_decompose`) maps onto
//! genuinely contended simulation resources.

pub mod arena;
pub mod engine;

pub use arena::{GraphShape, SimArena};
pub use engine::{lazy_label, makespan, Blocker, EdgeKind, EngineScratch,
                 LazyLabel, Resource, Sim, Span, TaskId, TaskSpec, TracedRun};
