//! Resource-constrained list scheduler over a task DAG.

use std::collections::BinaryHeap;

pub type TaskId = usize;

/// Execution resource. Each resource executes at most one task at a time;
/// tasks queued on the same resource run in global readiness order.
///
/// Multi-device schedules use one `Compute`/`Comm` pair per modeled device
/// plus one `Link` per node for the shared inter-node fabric, so All-to-All
/// phases crossing the node boundary contend on the node's uplink while
/// intra-node phases run on the per-device comm streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Resource {
    /// The device's compute stream (kernels are serialized here).
    Compute(usize),
    /// The device's communication stream (overlaps compute).
    Comm(usize),
    /// A node's shared inter-node uplink (IB/Ethernet fabric): all
    /// node-crossing All-to-All phases of that node serialize here.
    Link(usize),
    /// A device's host-to-device transfer engine: expert-offloading
    /// fetches and live re-placement migrations
    /// (`coordinator::replace::MigrationPlan::add_h2d_tasks`) serialize
    /// here while overlapping the device's compute and comm streams.
    H2D(usize),
    /// A device's device-to-host transfer engine: the *source* side of a
    /// live re-placement move (`MigrationPlan::add_transfer_tasks` with a
    /// D2H link) serializes its outgoing expert snapshots here; each
    /// destination H2D task then depends on its own D2H read-out.
    D2H(usize),
    /// Unlimited: bookkeeping tasks that consume time but no stream.
    Free,
}

impl Resource {
    /// Human-readable row label shared by the ASCII timeline renderer
    /// (`coordinator::timeline`) and the Chrome-trace exporter
    /// (`analyze::export`): `compute[d]` / `comm[d]` / `link[n]` /
    /// `h2d[d]` / `d2h[d]` / `free`.
    pub fn row_label(self) -> String {
        match self {
            Resource::Compute(d) => format!("compute[{d}]"),
            Resource::Comm(d) => format!("comm[{d}]"),
            Resource::Link(n) => format!("link[{n}]"),
            Resource::H2D(d) => format!("h2d[{d}]"),
            Resource::D2H(d) => format!("d2h[{d}]"),
            Resource::Free => "free".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub label: String,
    pub resource: Resource,
    pub duration: f64,
    pub deps: Vec<TaskId>,
}

#[derive(Debug, Clone)]
pub struct Span {
    pub id: TaskId,
    pub label: String,
    pub resource: Resource,
    pub start: f64,
    pub end: f64,
}

/// Which realized constraint gated a task's start time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// A DAG dependency: the task started the instant its latest-finishing
    /// dependency completed.
    Dep,
    /// Resource serialization: the task was ready earlier but its exclusive
    /// resource was still running another task.
    Resource,
}

/// The realized blocking predecessor of a task: the single predecessor
/// whose *finish* equals this task's start in the executed schedule.
/// `None` only for tasks that start at t = 0 with nothing gating them.
#[derive(Debug, Clone, Copy)]
pub struct Blocker {
    pub pred: TaskId,
    pub kind: EdgeKind,
}

/// Output of [`Sim::run_traced`]: the spans plus, per task, the realized
/// blocking predecessor. Walking `blockers` back from the latest-finishing
/// span yields a time-contiguous chain from t = 0 — the critical path
/// (`analyze::critpath` consumes exactly this).
#[derive(Debug, Clone)]
pub struct TracedRun {
    /// Per-task spans, indexed by task id (identical to [`Sim::run`]).
    pub spans: Vec<Span>,
    /// Per-task realized blocking predecessor, indexed by task id.
    pub blockers: Vec<Option<Blocker>>,
}

#[derive(Default)]
pub struct Sim {
    tasks: Vec<TaskSpec>,
}

impl Sim {
    pub fn new() -> Sim {
        Sim::default()
    }

    pub fn add(&mut self, label: impl Into<String>, resource: Resource,
               duration: f64, deps: &[TaskId]) -> TaskId {
        let id = self.tasks.len();
        for &d in deps {
            assert!(d < id, "dependency {d} of task {id} not yet defined");
        }
        assert!(duration >= 0.0, "negative duration");
        self.tasks.push(TaskSpec {
            label: label.into(),
            resource,
            duration,
            deps: deps.to_vec(),
        });
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// The task specs added so far, in insertion (id) order. The model
    /// composition layer (`coordinator::model`) reads a built
    /// [`PairSchedule`](crate::coordinator::PairSchedule)'s graph through
    /// this to embed it — offset, remapped — into a larger Sim.
    pub fn tasks(&self) -> &[TaskSpec] {
        &self.tasks
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Run the schedule; returns spans indexed by task id.
    ///
    /// Thin wrapper over [`Sim::run_traced`] — the spans are bit-identical
    /// (pinned by the mirror and the `analyze_timeline` property suite);
    /// only the blocking-edge record is dropped.
    pub fn run(&self) -> Vec<Span> {
        self.run_traced().spans
    }

    /// Run the schedule, additionally recording each task's realized
    /// blocking predecessor: a [`EdgeKind::Resource`] edge to the previous
    /// task on the same exclusive resource when the resource freed *after*
    /// the task's dependencies finished, otherwise a [`EdgeKind::Dep`] edge
    /// to the latest-finishing dependency (first such dep on ties). Tasks
    /// that start at t = 0 unconstrained get `None`.
    pub fn run_traced(&self) -> TracedRun {
        let n = self.tasks.len();
        let mut remaining: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (id, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }

        let mut heap: BinaryHeap<(std::cmp::Reverse<(u64, usize)>, TaskId)> = BinaryHeap::new();
        // encode ready_at as ordered u64 bits for a total order in the heap
        let key = |t: f64, seq: usize| std::cmp::Reverse((t.to_bits(), seq));

        let mut ready_at = vec![0.0f64; n];
        for (id, t) in self.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                heap.push((key(0.0, id), id));
            }
            let _ = t;
        }

        let mut resource_free: std::collections::BTreeMap<Resource, f64> =
            std::collections::BTreeMap::new();
        let mut last_on: std::collections::BTreeMap<Resource, TaskId> =
            std::collections::BTreeMap::new();
        let mut spans: Vec<Option<Span>> = (0..n).map(|_| None).collect();
        let mut blockers: Vec<Option<Blocker>> = vec![None; n];
        let mut done = 0usize;

        // latest-finishing dependency of `id` (first one on ties)
        let latest_dep = |id: TaskId, spans: &[Option<Span>]| {
            let mut best: Option<(TaskId, f64)> = None;
            for &d in &self.tasks[id].deps {
                let end = spans[d].as_ref().unwrap().end;
                if best.is_none_or(|(_, e)| end > e) {
                    best = Some((d, end));
                }
            }
            best.map(|(pred, _)| Blocker { pred, kind: EdgeKind::Dep })
        };

        while let Some((_, id)) = heap.pop() {
            let t = &self.tasks[id];
            let (start, blocker) = match t.resource {
                Resource::Free => (ready_at[id], latest_dep(id, &spans)),
                r => {
                    let free = resource_free.get(&r).copied().unwrap_or(0.0);
                    if free > ready_at[id] {
                        let pred = *last_on.get(&r).expect("busy resource");
                        (free, Some(Blocker { pred,
                                              kind: EdgeKind::Resource }))
                    } else {
                        (ready_at[id], latest_dep(id, &spans))
                    }
                }
            };
            let end = start + t.duration;
            if !matches!(t.resource, Resource::Free) {
                resource_free.insert(t.resource, end);
                last_on.insert(t.resource, id);
            }
            spans[id] = Some(Span {
                id,
                label: t.label.clone(),
                resource: t.resource,
                start,
                end,
            });
            blockers[id] = blocker;
            done += 1;
            for &dep in &dependents[id] {
                ready_at[dep] = ready_at[dep].max(end);
                remaining[dep] -= 1;
                if remaining[dep] == 0 {
                    heap.push((key(ready_at[dep], dep), dep));
                }
            }
        }
        assert_eq!(done, n, "cycle in task graph");
        TracedRun {
            spans: spans.into_iter().map(|s| s.unwrap()).collect(),
            blockers,
        }
    }

    /// Makespan of the schedule.
    pub fn makespan(&self) -> f64 {
        self.run().iter().fold(0.0, |m, s| m.max(s.end))
    }
}

/// Makespan from precomputed spans.
pub fn makespan(spans: &[Span]) -> f64 {
    spans.iter().fold(0.0, |m, s| m.max(s.end))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Compute(0), 2.0, &[a]);
        let _c = sim.add("c", Resource::Compute(0), 3.0, &[b]);
        assert_eq!(sim.makespan(), 6.0);
    }

    #[test]
    fn comm_overlaps_compute() {
        let mut sim = Sim::new();
        let a = sim.add("comp1", Resource::Compute(0), 2.0, &[]);
        let _b = sim.add("comm", Resource::Comm(0), 3.0, &[a]);
        let _c = sim.add("comp2", Resource::Compute(0), 3.0, &[a]);
        // comm and comp2 run concurrently after a: makespan = 2 + 3
        assert_eq!(sim.makespan(), 5.0);
    }

    #[test]
    fn resource_serializes() {
        let mut sim = Sim::new();
        let _a = sim.add("x", Resource::Compute(0), 2.0, &[]);
        let _b = sim.add("y", Resource::Compute(0), 2.0, &[]);
        // same resource, no deps: still serial
        assert_eq!(sim.makespan(), 4.0);
    }

    #[test]
    fn h2d_engine_serializes_and_overlaps_compute() {
        let mut sim = Sim::new();
        sim.add("comp", Resource::Compute(0), 2.0, &[]);
        sim.add("m1", Resource::H2D(0), 1.5, &[]);
        sim.add("m2", Resource::H2D(0), 1.5, &[]);
        // the two transfers overlap compute on a separate engine but
        // serialize against each other: makespan = 1.5 + 1.5
        assert_eq!(sim.makespan(), 3.0);
    }

    #[test]
    fn d2h_feeds_h2d_across_engines() {
        let mut sim = Sim::new();
        sim.add("comp", Resource::Compute(0), 2.0, &[]);
        let r = sim.add("read", Resource::D2H(0), 1.0, &[]);
        sim.add("write", Resource::H2D(1), 1.5, &[r]);
        // the D2H read-out overlaps compute; the dependent H2D write
        // starts only once the source engine has drained: 1.0 + 1.5
        assert_eq!(sim.makespan(), 2.5);
    }

    #[test]
    fn free_resource_is_concurrent() {
        let mut sim = Sim::new();
        for _ in 0..10 {
            sim.add("t", Resource::Free, 5.0, &[]);
        }
        assert_eq!(sim.makespan(), 5.0);
    }

    #[test]
    fn diamond_dependency() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        let d = sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let spans = sim.run();
        assert_eq!(spans[d].start, 5.0); // waits for comm (1+4)
        assert_eq!(spans[d].end, 6.0);
        assert_eq!(spans[b].start, 1.0);
        assert_eq!(spans[c].start, 1.0);
    }

    #[test]
    fn deterministic_tie_break() {
        let build = || {
            let mut sim = Sim::new();
            let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
            let b = sim.add("b", Resource::Compute(0), 1.0, &[]);
            sim.add("c", Resource::Compute(0), 1.0, &[a, b]);
            sim.run().iter().map(|s| (s.start, s.end)).collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    #[should_panic]
    fn forward_dependency_panics() {
        let mut sim = Sim::new();
        sim.add("a", Resource::Compute(0), 1.0, &[5]);
    }

    #[test]
    fn traced_spans_match_run() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let plain = sim.run();
        let traced = sim.run_traced();
        assert_eq!(plain.len(), traced.spans.len());
        for (p, t) in plain.iter().zip(&traced.spans) {
            assert_eq!(p.id, t.id);
            assert_eq!(p.start.to_bits(), t.start.to_bits());
            assert_eq!(p.end.to_bits(), t.end.to_bits());
        }
    }

    #[test]
    fn blocker_kinds_record_dep_vs_resource() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 2.0, &[]);
        // same resource, no dep: gated by the resource freeing
        let b = sim.add("b", Resource::Compute(0), 1.0, &[]);
        // other resource, dep on a: gated by the dependency
        let c = sim.add("c", Resource::Comm(0), 1.0, &[a]);
        let tr = sim.run_traced();
        assert!(tr.blockers[a].is_none());
        let bb = tr.blockers[b].unwrap();
        assert_eq!((bb.pred, bb.kind), (a, EdgeKind::Resource));
        let bc = tr.blockers[c].unwrap();
        assert_eq!((bc.pred, bc.kind), (a, EdgeKind::Dep));
    }

    #[test]
    fn blocker_chain_is_time_contiguous() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        let d = sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let tr = sim.run_traced();
        let _ = (c, d);
        for (id, blk) in tr.blockers.iter().enumerate() {
            match blk {
                Some(bl) => assert_eq!(
                    tr.spans[bl.pred].end.to_bits(),
                    tr.spans[id].start.to_bits(),
                    "blocker finish must equal task start"
                ),
                None => assert_eq!(tr.spans[id].start, 0.0),
            }
        }
        // d's latest-finishing dep is b (ends at 5.0), not c
        assert_eq!(tr.blockers[d].unwrap().pred, b);
    }

    #[test]
    fn row_labels() {
        assert_eq!(Resource::Compute(3).row_label(), "compute[3]");
        assert_eq!(Resource::Link(1).row_label(), "link[1]");
        assert_eq!(Resource::Free.row_label(), "free");
    }
}
