//! Resource-constrained list scheduler over a task DAG.
//!
//! Two engines implement the identical semantics:
//!
//! * [`Sim::run_traced_reference`] — the original single global
//!   `BinaryHeap` event loop. It is kept verbatim as the pinned reference:
//!   the differential harness (`rust/tests/engine_equivalence.rs`) asserts
//!   the fast engine span- and blocker-bit-identical to it over the whole
//!   golden corpus plus randomized DAGs.
//! * The fast engine behind [`Sim::run`] / [`Sim::run_traced`] /
//!   [`Sim::makespan`] — per-resource ready queues advanced independently,
//!   with only cross-resource wakeups touching a small frontier heap, over
//!   index-based buffers that [`EngineScratch`] / `SimArena` reuse across
//!   runs.
//!
//! Why they agree bit-for-bit: the reference pops a global heap keyed
//! `(ready_at.to_bits(), task-id)`, and every push carries a key ≥ the key
//! currently popped (a dependent becomes ready no earlier than its
//! dependency's end), so the global service order is exactly the ascending
//! sort of all final `(ready, id)` keys. Realized starts/ends depend only
//! on (a) the *per-resource* restriction of that order and (b) dep-derived
//! ready times — cross-resource interleaving is irrelevant, and `Free`
//! tasks can be scheduled eagerly the instant they become ready. The fast
//! engine services each exclusive resource in ascending `(ready, id)`
//! order directly, which is the same restriction, including ties (for
//! equal ready bits the smaller id is always serviced first by both).

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

pub type TaskId = usize;

/// Execution resource. Each resource executes at most one task at a time;
/// tasks queued on the same resource run in global readiness order.
///
/// Multi-device schedules use one `Compute`/`Comm` pair per modeled device
/// plus one `Link` per node for the shared inter-node fabric, so All-to-All
/// phases crossing the node boundary contend on the node's uplink while
/// intra-node phases run on the per-device comm streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Resource {
    /// The device's compute stream (kernels are serialized here).
    Compute(usize),
    /// The device's communication stream (overlaps compute).
    Comm(usize),
    /// A node's shared inter-node uplink (IB/Ethernet fabric): all
    /// node-crossing All-to-All phases of that node serialize here.
    Link(usize),
    /// A device's host-to-device transfer engine: expert-offloading
    /// fetches and live re-placement migrations
    /// (`coordinator::replace::MigrationPlan::add_h2d_tasks`) serialize
    /// here while overlapping the device's compute and comm streams.
    H2D(usize),
    /// A device's device-to-host transfer engine: the *source* side of a
    /// live re-placement move (`MigrationPlan::add_transfer_tasks` with a
    /// D2H link) serializes its outgoing expert snapshots here; each
    /// destination H2D task then depends on its own D2H read-out.
    D2H(usize),
    /// Unlimited: bookkeeping tasks that consume time but no stream.
    Free,
}

impl Resource {
    /// Human-readable row label shared by the ASCII timeline renderer
    /// (`coordinator::timeline`) and the Chrome-trace exporter
    /// (`analyze::export`): `compute[d]` / `comm[d]` / `link[n]` /
    /// `h2d[d]` / `d2h[d]` / `free`.
    pub fn row_label(self) -> String {
        match self {
            Resource::Compute(d) => format!("compute[{d}]"),
            Resource::Comm(d) => format!("comm[{d}]"),
            Resource::Link(n) => format!("link[{n}]"),
            Resource::H2D(d) => format!("h2d[{d}]"),
            Resource::D2H(d) => format!("d2h[{d}]"),
            Resource::Free => "free".into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub label: String,
    pub resource: Resource,
    pub duration: f64,
    pub deps: Vec<TaskId>,
}

#[derive(Debug, Clone)]
pub struct Span {
    pub id: TaskId,
    pub label: String,
    pub resource: Resource,
    pub start: f64,
    pub end: f64,
}

/// Which realized constraint gated a task's start time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EdgeKind {
    /// A DAG dependency: the task started the instant its latest-finishing
    /// dependency completed.
    Dep,
    /// Resource serialization: the task was ready earlier but its exclusive
    /// resource was still running another task.
    Resource,
}

/// The realized blocking predecessor of a task: the single predecessor
/// whose *finish* equals this task's start in the executed schedule.
/// `None` only for tasks that start at t = 0 with nothing gating them.
#[derive(Debug, Clone, Copy)]
pub struct Blocker {
    pub pred: TaskId,
    pub kind: EdgeKind,
}

/// Output of [`Sim::run_traced`]: the spans plus, per task, the realized
/// blocking predecessor. Walking `blockers` back from the latest-finishing
/// span yields a time-contiguous chain from t = 0 — the critical path
/// (`analyze::critpath` consumes exactly this).
#[derive(Debug, Clone)]
pub struct TracedRun {
    /// Per-task spans, indexed by task id (identical to [`Sim::run`]).
    pub spans: Vec<Span>,
    /// Per-task realized blocking predecessor, indexed by task id.
    pub blockers: Vec<Option<Blocker>>,
}

/// A label that is only rendered if the task is actually appended.
/// Warm-start re-pricing (`SimArena`) replays a builder over a cached
/// skeleton where labels already exist; wrapping `format!` call sites in
/// [`lazy_label`] skips the formatting entirely on that path.
pub struct LazyLabel<F>(F);

impl<F: FnOnce() -> String> From<LazyLabel<F>> for String {
    fn from(l: LazyLabel<F>) -> String {
        (l.0)()
    }
}

/// Wrap a `FnOnce() -> String` so it satisfies `impl Into<String>` label
/// parameters without being evaluated on the re-pricing path.
pub fn lazy_label<F: FnOnce() -> String>(f: F) -> LazyLabel<F> {
    LazyLabel(f)
}

/// Dense index reserved for [`Resource::Free`] in `Sim::res_idx`.
pub(crate) const FREE_RES: u32 = u32::MAX;

/// Monotone source of per-`Sim` identities so cached [`DependentsIndex`]es
/// can never be applied to the wrong graph.
static SIM_NONCE: AtomicU64 = AtomicU64::new(1);

pub struct Sim {
    tasks: Vec<TaskSpec>,
    /// Interned exclusive-resource index per task (`FREE_RES` for `Free`),
    /// parallel to `tasks`.
    res_idx: Vec<u32>,
    res_map: HashMap<Resource, u32>,
    n_res: u32,
    /// `Some(cursor)` while a `SimArena` warm build re-prices durations in
    /// place over the cached skeleton instead of appending.
    reprice: Option<usize>,
    /// Unique per-instance identity (see [`SIM_NONCE`]).
    nonce: u64,
    /// Bumped on every *structural* change (append / truncate / clear) —
    /// re-pricing durations deliberately does not bump it, which is what
    /// lets a warm run reuse its cached dependents index.
    version: u64,
}

impl Default for Sim {
    fn default() -> Sim {
        Sim {
            tasks: Vec::new(),
            res_idx: Vec::new(),
            res_map: HashMap::new(),
            n_res: 0,
            reprice: None,
            nonce: SIM_NONCE.fetch_add(1, Ordering::Relaxed),
            version: 0,
        }
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim::default()
    }

    pub fn add(&mut self, label: impl Into<String>, resource: Resource,
               duration: f64, deps: &[TaskId]) -> TaskId {
        self.add_cat(label, resource, duration, deps, &[])
    }

    /// [`Sim::add`] with the dependency list given as a concatenation of
    /// two slices (`deps` then `extra`). Builders use this to pass barrier
    /// dependency lists (e.g. "all dispatch chunks") by reference plus a
    /// small tail without materializing a combined `Vec` per call — on the
    /// warm-start re-pricing path no dependency copy happens at all.
    pub fn add_cat(&mut self, label: impl Into<String>, resource: Resource,
                   duration: f64, deps: &[TaskId], extra: &[TaskId]) -> TaskId {
        assert!(duration >= 0.0, "negative duration");
        if let Some(cursor) = self.reprice {
            assert!(
                cursor < self.tasks.len(),
                "warm re-price appended past the cached skeleton \
                 (structural change without a shape change)"
            );
            let t = &mut self.tasks[cursor];
            debug_assert_eq!(t.resource, resource, "skeleton resource drifted");
            debug_assert_eq!(t.deps.len(), deps.len() + extra.len(),
                             "skeleton dep count drifted");
            debug_assert!(
                t.deps.iter().eq(deps.iter().chain(extra)),
                "skeleton deps drifted"
            );
            t.duration = duration;
            self.reprice = Some(cursor + 1);
            return cursor;
        }
        let id = self.tasks.len();
        for &d in deps.iter().chain(extra) {
            assert!(d < id, "dependency {d} of task {id} not yet defined");
        }
        let r = match resource {
            Resource::Free => FREE_RES,
            r => {
                let n_res = &mut self.n_res;
                *self.res_map.entry(r).or_insert_with(|| {
                    let i = *n_res;
                    *n_res += 1;
                    i
                })
            }
        };
        let mut dep_vec = Vec::with_capacity(deps.len() + extra.len());
        dep_vec.extend_from_slice(deps);
        dep_vec.extend_from_slice(extra);
        self.res_idx.push(r);
        self.tasks.push(TaskSpec {
            label: label.into(),
            resource,
            duration,
            deps: dep_vec,
        });
        self.version += 1;
        id
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// The task specs added so far, in insertion (id) order. The model
    /// composition layer (`coordinator::model`) reads a built
    /// [`PairSchedule`](crate::coordinator::PairSchedule)'s graph through
    /// this to embed it — offset, remapped — into a larger Sim.
    pub fn tasks(&self) -> &[TaskSpec] {
        &self.tasks
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Enter warm-start re-pricing: subsequent `add`/`add_cat` calls
    /// overwrite durations of the cached skeleton in id order instead of
    /// appending. [`Sim::finish_reprice`] asserts full coverage.
    pub(crate) fn begin_reprice(&mut self) {
        self.reprice = Some(0);
    }

    pub(crate) fn finish_reprice(&mut self) {
        if let Some(cursor) = self.reprice.take() {
            assert_eq!(
                cursor,
                self.tasks.len(),
                "warm re-price covered {cursor} of {} skeleton tasks \
                 (structural change without a shape change)",
                self.tasks.len()
            );
        }
    }

    /// Drop tasks appended after the first `len` (used by `SimArena` to
    /// shed what-if tasks — e.g. migration H2D/D2H appends — before the
    /// next warm build). A no-op truncation keeps the structural version,
    /// so the cached dependents index stays valid across warm rebuilds.
    pub(crate) fn truncate(&mut self, len: usize) {
        if len < self.tasks.len() {
            self.tasks.truncate(len);
            self.res_idx.truncate(len);
            self.version += 1;
        }
    }

    pub(crate) fn clear(&mut self) {
        self.tasks.clear();
        self.res_idx.clear();
        self.res_map.clear();
        self.n_res = 0;
        self.reprice = None;
        self.version += 1;
    }

    /// Run the schedule; returns spans indexed by task id.
    ///
    /// Thin wrapper over [`Sim::run_traced`] — the spans are bit-identical
    /// (pinned by the mirror and the `analyze_timeline` property suite);
    /// only the blocking-edge record is dropped.
    pub fn run(&self) -> Vec<Span> {
        self.run_traced().spans
    }

    /// Run the schedule, additionally recording each task's realized
    /// blocking predecessor: a [`EdgeKind::Resource`] edge to the previous
    /// task on the same exclusive resource when the resource freed *after*
    /// the task's dependencies finished, otherwise a [`EdgeKind::Dep`] edge
    /// to the latest-finishing dependency (first such dep on ties). Tasks
    /// that start at t = 0 unconstrained get `None`.
    pub fn run_traced(&self) -> TracedRun {
        let mut scratch = EngineScratch::default();
        self.run_traced_with(&mut scratch)
    }

    /// [`Sim::run_traced`] reusing caller-owned buffers — zero steady-state
    /// allocation apart from the returned spans.
    pub fn run_traced_with(&self, scratch: &mut EngineScratch) -> TracedRun {
        let EngineScratch { index, bufs } = scratch;
        index.ensure(self);
        self.run_fast(index, bufs, true);
        TracedRun {
            spans: self.materialize_spans(bufs),
            blockers: bufs.blockers.clone(),
        }
    }

    /// Makespan of the schedule. Runs the fast engine in makespan-only
    /// mode: no spans are materialized and no labels are cloned.
    pub fn makespan(&self) -> f64 {
        let mut scratch = EngineScratch::default();
        self.makespan_with(&mut scratch)
    }

    /// [`Sim::makespan`] reusing caller-owned buffers.
    pub fn makespan_with(&self, scratch: &mut EngineScratch) -> f64 {
        let EngineScratch { index, bufs } = scratch;
        index.ensure(self);
        self.run_fast(index, bufs, false)
    }

    pub(crate) fn materialize_spans(&self, bufs: &RunBuffers) -> Vec<Span> {
        self.tasks
            .iter()
            .enumerate()
            .map(|(id, t)| Span {
                id,
                label: t.label.clone(),
                resource: t.resource,
                start: bufs.starts[id],
                end: bufs.ends[id],
            })
            .collect()
    }

    /// The fast engine. Fills `bufs.starts` / `bufs.ends` (and, when
    /// `trace`, `bufs.blockers`) and returns the makespan.
    ///
    /// Per exclusive resource, tasks are serviced in ascending
    /// `(ready.to_bits(), id)` order from that resource's own priority
    /// queue; a frontier heap holds (at least) the current head of every
    /// non-empty queue and decides which resource acts next. `Free` tasks
    /// never touch a queue: they are scheduled eagerly the moment their
    /// last dependency completes (their start is `ready_at` regardless of
    /// global order). Frontier entries are invalidated lazily: an entry is
    /// acted on only if it still equals its queue's head.
    ///
    /// Correctness of the frontier order (incl. zero-duration ties): if
    /// some not-yet-queued task U on resource r has a smaller key than r's
    /// queued head, U has a chain of unscheduled ancestors down to a task Q
    /// that *is* queued, with time(Q) ≤ time(U); if all times are equal
    /// (zero durations), Q is an ancestor of U so id(Q) < id(U) (deps must
    /// have smaller ids, enforced by `add`). Either way key(Q) < key(U) ≤
    /// key(head), so the frontier serves Q first and U is enqueued before r
    /// could run its head out of order.
    pub(crate) fn run_fast(&self, di: &DependentsIndex, bufs: &mut RunBuffers,
                           trace: bool) -> f64 {
        assert!(self.reprice.is_none(), "run during an unfinished re-price");
        debug_assert!(di.matches(self), "stale dependents index");
        let n = self.tasks.len();
        let nr = self.n_res as usize;
        bufs.remaining.clear();
        bufs.remaining.extend_from_slice(&di.dep_count);
        bufs.ready.clear();
        bufs.ready.resize(n, 0.0);
        bufs.starts.clear();
        bufs.starts.resize(n, 0.0);
        bufs.ends.clear();
        bufs.ends.resize(n, 0.0);
        if trace {
            bufs.blockers.clear();
            bufs.blockers.resize(n, None);
        }
        bufs.res_free.clear();
        bufs.res_free.resize(nr, 0.0);
        bufs.res_last.clear();
        bufs.res_last.resize(nr, usize::MAX);
        if bufs.queues.len() < nr {
            bufs.queues.resize_with(nr, BinaryHeap::new);
        }
        for q in &mut bufs.queues[..nr] {
            q.clear();
        }
        bufs.frontier.clear();
        bufs.cascade.clear();

        let mut done = 0usize;
        let mut makespan = 0.0f64;

        // latest-finishing dependency of `id` (first one on ties)
        fn latest_dep(tasks: &[TaskSpec], ends: &[f64], id: TaskId)
                      -> Option<Blocker> {
            let mut best: Option<(TaskId, f64)> = None;
            for &d in &tasks[id].deps {
                let end = ends[d];
                if best.is_none_or(|(_, e)| end > e) {
                    best = Some((d, end));
                }
            }
            best.map(|(pred, _)| Blocker { pred, kind: EdgeKind::Dep })
        }

        // Schedule one completed task's effects: propagate its end to
        // dependents and collect the newly ready ones onto the cascade.
        macro_rules! complete {
            ($id:expr, $end:expr) => {{
                let end = $end;
                makespan = makespan.max(end);
                done += 1;
                let (lo, hi) =
                    (di.off[$id] as usize, di.off[$id + 1] as usize);
                for &dep in &di.dat[lo..hi] {
                    let dep = dep as usize;
                    bufs.ready[dep] = bufs.ready[dep].max(end);
                    bufs.remaining[dep] -= 1;
                    if bufs.remaining[dep] == 0 {
                        bufs.cascade.push(dep);
                    }
                }
            }};
        }

        // Drain the ready cascade: Free tasks run eagerly (possibly making
        // more tasks ready), exclusive tasks are enqueued on their
        // resource's queue, publishing a frontier entry when they become
        // that queue's new head.
        macro_rules! drain_cascade {
            () => {
                while let Some(id) = bufs.cascade.pop() {
                    let r = self.res_idx[id];
                    if r == FREE_RES {
                        let start = bufs.ready[id];
                        let end = start + self.tasks[id].duration;
                        bufs.starts[id] = start;
                        bufs.ends[id] = end;
                        if trace {
                            bufs.blockers[id] =
                                latest_dep(&self.tasks, &bufs.ends, id);
                        }
                        complete!(id, end);
                    } else {
                        let key = (bufs.ready[id].to_bits(), id);
                        let q = &mut bufs.queues[r as usize];
                        q.push(Reverse(key));
                        if q.peek() == Some(&Reverse(key)) {
                            bufs.frontier.push(Reverse((key.0, key.1, r)));
                        }
                    }
                }
            };
        }

        for (id, &dc) in di.dep_count.iter().enumerate() {
            if dc == 0 {
                bufs.cascade.push(id);
            }
        }
        drain_cascade!();

        while let Some(Reverse((bits, id, r))) = bufs.frontier.pop() {
            let ri = r as usize;
            // lazily dropped stale entry: the queue moved past it
            if bufs.queues[ri].peek() != Some(&Reverse((bits, id))) {
                continue;
            }
            bufs.queues[ri].pop();
            let ready = bufs.ready[id];
            debug_assert_eq!(ready.to_bits(), bits);
            let free = bufs.res_free[ri];
            let start = if free > ready {
                if trace {
                    bufs.blockers[id] = Some(Blocker {
                        pred: bufs.res_last[ri],
                        kind: EdgeKind::Resource,
                    });
                }
                free
            } else {
                if trace {
                    bufs.blockers[id] =
                        latest_dep(&self.tasks, &bufs.ends, id);
                }
                ready
            };
            let end = start + self.tasks[id].duration;
            bufs.res_free[ri] = end;
            bufs.res_last[ri] = id;
            bufs.starts[id] = start;
            bufs.ends[id] = end;
            complete!(id, end);
            drain_cascade!();
            if let Some(&Reverse((b2, t2))) = bufs.queues[ri].peek() {
                bufs.frontier.push(Reverse((b2, t2, r)));
            }
        }
        assert_eq!(done, n, "cycle in task graph");
        makespan
    }

    /// The original global-`BinaryHeap` engine, kept verbatim as the pinned
    /// reference for the differential harness
    /// (`rust/tests/engine_equivalence.rs`) and the bench's
    /// reference-vs-optimized comparison (`benches/des_engine.rs`). Do not
    /// optimize this — its entire value is being the unchanged baseline.
    pub fn run_traced_reference(&self) -> TracedRun {
        let n = self.tasks.len();
        let mut remaining: Vec<usize> = self.tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for (id, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                dependents[d].push(id);
            }
        }

        let mut heap: BinaryHeap<(std::cmp::Reverse<(u64, usize)>, TaskId)> = BinaryHeap::new();
        // encode ready_at as ordered u64 bits for a total order in the heap
        let key = |t: f64, seq: usize| std::cmp::Reverse((t.to_bits(), seq));

        let mut ready_at = vec![0.0f64; n];
        for (id, t) in self.tasks.iter().enumerate() {
            if t.deps.is_empty() {
                heap.push((key(0.0, id), id));
            }
            let _ = t;
        }

        let mut resource_free: std::collections::BTreeMap<Resource, f64> =
            std::collections::BTreeMap::new();
        let mut last_on: std::collections::BTreeMap<Resource, TaskId> =
            std::collections::BTreeMap::new();
        let mut spans: Vec<Option<Span>> = (0..n).map(|_| None).collect();
        let mut blockers: Vec<Option<Blocker>> = vec![None; n];
        let mut done = 0usize;

        // latest-finishing dependency of `id` (first one on ties)
        let latest_dep = |id: TaskId, spans: &[Option<Span>]| {
            let mut best: Option<(TaskId, f64)> = None;
            for &d in &self.tasks[id].deps {
                let end = spans[d].as_ref().unwrap().end;
                if best.is_none_or(|(_, e)| end > e) {
                    best = Some((d, end));
                }
            }
            best.map(|(pred, _)| Blocker { pred, kind: EdgeKind::Dep })
        };

        while let Some((_, id)) = heap.pop() {
            let t = &self.tasks[id];
            let (start, blocker) = match t.resource {
                Resource::Free => (ready_at[id], latest_dep(id, &spans)),
                r => {
                    let free = resource_free.get(&r).copied().unwrap_or(0.0);
                    if free > ready_at[id] {
                        let pred = *last_on.get(&r).expect("busy resource");
                        (free, Some(Blocker { pred,
                                              kind: EdgeKind::Resource }))
                    } else {
                        (ready_at[id], latest_dep(id, &spans))
                    }
                }
            };
            let end = start + t.duration;
            if !matches!(t.resource, Resource::Free) {
                resource_free.insert(t.resource, end);
                last_on.insert(t.resource, id);
            }
            spans[id] = Some(Span {
                id,
                label: t.label.clone(),
                resource: t.resource,
                start,
                end,
            });
            blockers[id] = blocker;
            done += 1;
            for &dep in &dependents[id] {
                ready_at[dep] = ready_at[dep].max(end);
                remaining[dep] -= 1;
                if remaining[dep] == 0 {
                    heap.push((key(ready_at[dep], dep), dep));
                }
            }
        }
        assert_eq!(done, n, "cycle in task graph");
        TracedRun {
            spans: spans.into_iter().map(|s| s.unwrap()).collect(),
            blockers,
        }
    }
}

/// CSR adjacency (dependents of each task) plus per-task dependency
/// counts, cached against a specific `Sim` structural version so warm
/// re-priced runs skip rebuilding it.
#[derive(Default)]
pub(crate) struct DependentsIndex {
    nonce: u64,
    version: u64,
    dep_count: Vec<u32>,
    /// `off[i]..off[i+1]` indexes `dat` with the dependents of task `i`.
    off: Vec<u32>,
    dat: Vec<u32>,
    cursor: Vec<u32>,
}

impl DependentsIndex {
    fn matches(&self, sim: &Sim) -> bool {
        self.nonce == sim.nonce && self.version == sim.version
    }

    /// Rebuild iff the index does not match `sim`'s structural identity.
    /// Sound because `Sim` bumps `version` on every structural change and
    /// `nonce` is unique per instance.
    pub(crate) fn ensure(&mut self, sim: &Sim) {
        if self.matches(sim) {
            return;
        }
        let n = sim.tasks.len();
        self.dep_count.clear();
        self.off.clear();
        self.off.resize(n + 1, 0);
        for t in &sim.tasks {
            self.dep_count.push(t.deps.len() as u32);
        }
        for t in &sim.tasks {
            for &d in &t.deps {
                self.off[d + 1] += 1;
            }
        }
        for i in 0..n {
            self.off[i + 1] += self.off[i];
        }
        self.dat.clear();
        self.dat.resize(self.off[n] as usize, 0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.off[..n]);
        for (id, t) in sim.tasks.iter().enumerate() {
            for &d in &t.deps {
                self.dat[self.cursor[d] as usize] = id as u32;
                self.cursor[d] += 1;
            }
        }
        self.nonce = sim.nonce;
        self.version = sim.version;
    }
}

/// Reusable per-run buffers for the fast engine. Separated from
/// [`DependentsIndex`] so a `SimArena` can keep one adjacency cache per
/// cached skeleton while sharing a single set of run buffers.
#[derive(Default)]
pub(crate) struct RunBuffers {
    remaining: Vec<u32>,
    ready: Vec<f64>,
    starts: Vec<f64>,
    ends: Vec<f64>,
    pub(crate) blockers: Vec<Option<Blocker>>,
    res_free: Vec<f64>,
    res_last: Vec<usize>,
    queues: Vec<BinaryHeap<Reverse<(u64, usize)>>>,
    frontier: BinaryHeap<Reverse<(u64, usize, u32)>>,
    cascade: Vec<usize>,
}

/// Caller-owned scratch for [`Sim::run_traced_with`] /
/// [`Sim::makespan_with`]: reusing one across many runs eliminates the
/// steady-state allocation of the engine (the dependents index is
/// re-validated per call against the sim's structural identity).
#[derive(Default)]
pub struct EngineScratch {
    pub(crate) index: DependentsIndex,
    pub(crate) bufs: RunBuffers,
}

/// Makespan from precomputed spans.
pub fn makespan(spans: &[Span]) -> f64 {
    spans.iter().fold(0.0, |m, s| m.max(s.end))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serial_chain() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Compute(0), 2.0, &[a]);
        let _c = sim.add("c", Resource::Compute(0), 3.0, &[b]);
        assert_eq!(sim.makespan(), 6.0);
    }

    #[test]
    fn comm_overlaps_compute() {
        let mut sim = Sim::new();
        let a = sim.add("comp1", Resource::Compute(0), 2.0, &[]);
        let _b = sim.add("comm", Resource::Comm(0), 3.0, &[a]);
        let _c = sim.add("comp2", Resource::Compute(0), 3.0, &[a]);
        // comm and comp2 run concurrently after a: makespan = 2 + 3
        assert_eq!(sim.makespan(), 5.0);
    }

    #[test]
    fn resource_serializes() {
        let mut sim = Sim::new();
        let _a = sim.add("x", Resource::Compute(0), 2.0, &[]);
        let _b = sim.add("y", Resource::Compute(0), 2.0, &[]);
        // same resource, no deps: still serial
        assert_eq!(sim.makespan(), 4.0);
    }

    #[test]
    fn h2d_engine_serializes_and_overlaps_compute() {
        let mut sim = Sim::new();
        sim.add("comp", Resource::Compute(0), 2.0, &[]);
        sim.add("m1", Resource::H2D(0), 1.5, &[]);
        sim.add("m2", Resource::H2D(0), 1.5, &[]);
        // the two transfers overlap compute on a separate engine but
        // serialize against each other: makespan = 1.5 + 1.5
        assert_eq!(sim.makespan(), 3.0);
    }

    #[test]
    fn d2h_feeds_h2d_across_engines() {
        let mut sim = Sim::new();
        sim.add("comp", Resource::Compute(0), 2.0, &[]);
        let r = sim.add("read", Resource::D2H(0), 1.0, &[]);
        sim.add("write", Resource::H2D(1), 1.5, &[r]);
        // the D2H read-out overlaps compute; the dependent H2D write
        // starts only once the source engine has drained: 1.0 + 1.5
        assert_eq!(sim.makespan(), 2.5);
    }

    #[test]
    fn free_resource_is_concurrent() {
        let mut sim = Sim::new();
        for _ in 0..10 {
            sim.add("t", Resource::Free, 5.0, &[]);
        }
        assert_eq!(sim.makespan(), 5.0);
    }

    #[test]
    fn diamond_dependency() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        let d = sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let spans = sim.run();
        assert_eq!(spans[d].start, 5.0); // waits for comm (1+4)
        assert_eq!(spans[d].end, 6.0);
        assert_eq!(spans[b].start, 1.0);
        assert_eq!(spans[c].start, 1.0);
    }

    #[test]
    fn deterministic_tie_break() {
        let build = || {
            let mut sim = Sim::new();
            let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
            let b = sim.add("b", Resource::Compute(0), 1.0, &[]);
            sim.add("c", Resource::Compute(0), 1.0, &[a, b]);
            sim.run().iter().map(|s| (s.start, s.end)).collect::<Vec<_>>()
        };
        assert_eq!(build(), build());
    }

    #[test]
    #[should_panic]
    fn forward_dependency_panics() {
        let mut sim = Sim::new();
        sim.add("a", Resource::Compute(0), 1.0, &[5]);
    }

    #[test]
    fn traced_spans_match_run() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let plain = sim.run();
        let traced = sim.run_traced();
        assert_eq!(plain.len(), traced.spans.len());
        for (p, t) in plain.iter().zip(&traced.spans) {
            assert_eq!(p.id, t.id);
            assert_eq!(p.start.to_bits(), t.start.to_bits());
            assert_eq!(p.end.to_bits(), t.end.to_bits());
        }
    }

    #[test]
    fn blocker_kinds_record_dep_vs_resource() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 2.0, &[]);
        // same resource, no dep: gated by the resource freeing
        let b = sim.add("b", Resource::Compute(0), 1.0, &[]);
        // other resource, dep on a: gated by the dependency
        let c = sim.add("c", Resource::Comm(0), 1.0, &[a]);
        let tr = sim.run_traced();
        assert!(tr.blockers[a].is_none());
        let bb = tr.blockers[b].unwrap();
        assert_eq!((bb.pred, bb.kind), (a, EdgeKind::Resource));
        let bc = tr.blockers[c].unwrap();
        assert_eq!((bc.pred, bc.kind), (a, EdgeKind::Dep));
    }

    #[test]
    fn blocker_chain_is_time_contiguous() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("b", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("c", Resource::Compute(0), 2.0, &[a]);
        let d = sim.add("d", Resource::Compute(0), 1.0, &[b, c]);
        let tr = sim.run_traced();
        let _ = (c, d);
        for (id, blk) in tr.blockers.iter().enumerate() {
            match blk {
                Some(bl) => assert_eq!(
                    tr.spans[bl.pred].end.to_bits(),
                    tr.spans[id].start.to_bits(),
                    "blocker finish must equal task start"
                ),
                None => assert_eq!(tr.spans[id].start, 0.0),
            }
        }
        // d's latest-finishing dep is b (ends at 5.0), not c
        assert_eq!(tr.blockers[d].unwrap().pred, b);
    }

    #[test]
    fn fast_engine_matches_reference_on_zero_duration_ties() {
        // zero-duration tasks + duplicate ready times: the (time, id)
        // tie-break is fully exercised and must match the reference
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 0.0, &[]);
        let b = sim.add("b", Resource::Compute(0), 0.0, &[a]);
        sim.add("c", Resource::Compute(0), 0.0, &[]);
        sim.add("d", Resource::Comm(0), 0.0, &[b]);
        sim.add("e", Resource::Free, 0.0, &[a]);
        let fast = sim.run_traced();
        let reference = sim.run_traced_reference();
        for (f, r) in fast.spans.iter().zip(&reference.spans) {
            assert_eq!(f.start.to_bits(), r.start.to_bits());
            assert_eq!(f.end.to_bits(), r.end.to_bits());
        }
        for (f, r) in fast.blockers.iter().zip(&reference.blockers) {
            match (f, r) {
                (None, None) => {}
                (Some(fb), Some(rb)) => {
                    assert_eq!((fb.pred, fb.kind), (rb.pred, rb.kind));
                }
                _ => panic!("blocker presence diverged"),
            }
        }
    }

    #[test]
    fn scratch_reuse_is_bit_identical() {
        let mut scratch = EngineScratch::default();
        let mut big = Sim::new();
        for i in 0..20 {
            let deps: Vec<TaskId> = if i == 0 { vec![] } else { vec![i - 1] };
            big.add(format!("t{i}"), Resource::Compute(i % 3), 0.5, &deps);
        }
        let mut small = Sim::new();
        small.add("only", Resource::Comm(0), 1.0, &[]);
        // big run, then small run with the same scratch: stale buffers
        // from the larger graph must not leak into the smaller one
        let m_big = big.makespan_with(&mut scratch);
        let m_small = small.makespan_with(&mut scratch);
        assert_eq!(m_big.to_bits(), big.makespan().to_bits());
        assert_eq!(m_small.to_bits(), small.makespan().to_bits());
        let t_big = big.run_traced_with(&mut scratch);
        for (a, b) in t_big.spans.iter().zip(&big.run_traced().spans) {
            assert_eq!(a.start.to_bits(), b.start.to_bits());
            assert_eq!(a.end.to_bits(), b.end.to_bits());
        }
    }

    #[test]
    fn reprice_overwrites_durations_in_place() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 1.0, &[]);
        sim.add("b", Resource::Comm(0), 2.0, &[a]);
        assert_eq!(sim.makespan(), 3.0);
        sim.begin_reprice();
        let a2 = sim.add("a", Resource::Compute(0), 4.0, &[]);
        sim.add("b", Resource::Comm(0), 0.5, &[a2]);
        sim.finish_reprice();
        assert_eq!(sim.len(), 2);
        assert_eq!(sim.makespan(), 4.5);
    }

    #[test]
    #[should_panic]
    fn reprice_must_cover_whole_skeleton() {
        let mut sim = Sim::new();
        sim.add("a", Resource::Compute(0), 1.0, &[]);
        sim.add("b", Resource::Comm(0), 2.0, &[0]);
        sim.begin_reprice();
        sim.add("a", Resource::Compute(0), 4.0, &[]);
        sim.finish_reprice(); // covered 1 of 2
    }

    #[test]
    fn lazy_label_renders_on_append() {
        let mut sim = Sim::new();
        sim.add(lazy_label(|| format!("t{}", 7)), Resource::Free, 1.0, &[]);
        assert_eq!(sim.tasks()[0].label, "t7");
    }

    #[test]
    fn row_labels() {
        assert_eq!(Resource::Compute(3).row_label(), "compute[3]");
        assert_eq!(Resource::Link(1).row_label(), "link[1]");
        assert_eq!(Resource::Free.row_label(), "free");
    }
}
