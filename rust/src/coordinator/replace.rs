//! Live expert re-placement: migration plans priced as H2D DES tasks,
//! composed with per-step schedules into migration-aware multi-step
//! timelines.
//!
//! The single-step simulator answers "how fast is this placement?"; this
//! module answers the temporal follow-up ExFlow (arXiv:2401.08383) and
//! MoNTA (arXiv:2411.00662) pose together: *when does re-placing pay for
//! itself?* A [`MigrationPlan`] is the expert→device delta between two
//! [`Placement`]s with per-expert byte costs; its transfers become real
//! DES tasks on the per-device [`Resource::H2D`] engines, overlapped
//! behind the backbone compute of the step in which they fire. With a
//! configured D2H link ([`ReplaceConfig::d2h_link`]) every move also
//! pays its source-side read-out on the per-device [`Resource::D2H`]
//! engine first — the H2D write chains behind it, so a device shedding
//! many experts throttles all of their arrivals.
//! [`run_replace_timeline`] drives N steps of a routing stream through a
//! [`ScheduleSpec`], feeding every step's table to a
//! [`AffinityEstimator`](crate::moe::AffinityEstimator) and letting a
//! [`ReplacePolicy`] decide when the measured-affinity packing is worth
//! migrating to; the N-step makespan is the sum of the per-step DES
//! makespans (migration steps include their H2D spans).
//!
//! The break-even arithmetic is deliberately DES-true: because the H2D
//! engines run concurrently with the step's compute/comm streams, the
//! cost of a migration is only the part of the transfer that *outlasts*
//! the step (`max(0, transfer − step makespan)`), and the per-step
//! saving is the difference of two simulated makespans under the cost
//! model's own phase totals. `scmoe report replace` and
//! `timeline_explorer --replace` drive the studies; every pinned number
//! is minted through `tools/des_mirror/mirror2.py` (PR5 model).

use crate::cluster::{ChaosSpec, LinkModel, Topology};
use crate::moe::{AffinityEstimator, Placement, RoutingTable};
use crate::simtime::{Resource, Sim, SimArena, TaskId};

use super::costs::{ComputeCosts, TopoCosts};
use super::spec::ScheduleSpec;

/// One expert's parameter move between devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExpertMove {
    /// Expert whose parameters move.
    pub expert: usize,
    /// Device owning the expert before the migration.
    pub from: usize,
    /// Device owning the expert after the migration.
    pub to: usize,
    /// Parameter bytes transferred (the expert's full weight footprint).
    pub bytes: usize,
}

/// The expert→device delta between two placements, with byte costs —
/// everything needed to price a live re-placement.
#[derive(Debug, Clone)]
pub struct MigrationPlan {
    /// One move per expert whose device changed, in ascending expert id.
    pub moves: Vec<ExpertMove>,
    /// Fleet size (sizes the per-device H2D accounting).
    pub n_devices: usize,
}

impl MigrationPlan {
    /// Diff two placements over the same experts and fleet: one
    /// [`ExpertMove`] of `bytes_per_expert` for every expert whose
    /// owning device differs.
    pub fn between(old: &Placement, new: &Placement,
                   bytes_per_expert: usize) -> MigrationPlan {
        assert_eq!(old.n_experts, new.n_experts,
                   "placements must cover the same experts");
        assert_eq!(old.n_devices, new.n_devices,
                   "placements must cover the same fleet");
        let moves = (0..old.n_experts)
            .filter_map(|e| {
                let (from, to) = (old.device_of(e), new.device_of(e));
                (from != to).then_some(ExpertMove {
                    expert: e,
                    from,
                    to,
                    bytes: bytes_per_expert,
                })
            })
            .collect();
        MigrationPlan { moves, n_devices: old.n_devices }
    }

    /// True when the placements were identical (nothing to transfer).
    pub fn is_empty(&self) -> bool {
        self.moves.is_empty()
    }

    /// Total bytes the migration moves — exactly
    /// `moved experts × bytes_per_expert`.
    pub fn total_bytes(&self) -> usize {
        self.moves.iter().map(|m| m.bytes).sum()
    }

    /// Bytes arriving at one device's H2D engine.
    pub fn bytes_into(&self, device: usize) -> usize {
        self.moves.iter().filter(|m| m.to == device).map(|m| m.bytes).sum()
    }

    /// Serialized per-destination-engine transfer time: each receiving
    /// device's H2D engine runs its incoming moves back to back, and the
    /// plan completes when the slowest engine drains — the same value
    /// the DES produces for the dependency-free tasks of
    /// [`Self::add_h2d_tasks`].
    pub fn time(&self, h2d: &LinkModel) -> f64 {
        let mut per = vec![0.0f64; self.n_devices];
        for m in &self.moves {
            per[m.to] += h2d.transfer_time(m.bytes);
        }
        per.iter().fold(0.0f64, |w, &x| w.max(x))
    }

    /// Add one DES task per move on the destination device's
    /// [`Resource::H2D`] engine, dependency-free: transfers start at
    /// step begin and genuinely overlap the step's backbone compute and
    /// All-to-All phases (separate resources). Returns the task ids.
    pub fn add_h2d_tasks(&self, sim: &mut Sim, h2d: &LinkModel) -> Vec<TaskId> {
        self.moves
            .iter()
            .map(|m| {
                sim.add(format!("H2D-E{}", m.expert), Resource::H2D(m.to),
                        h2d.transfer_time(m.bytes), &[])
            })
            .collect()
    }

    /// [`Self::add_h2d_tasks`] generalized to price the *source* side of
    /// every move: with `d2h = Some(link)` each move first reads the
    /// expert's parameters out on the source device's [`Resource::D2H`]
    /// engine (serialized per device, overlapping compute/comm like H2D)
    /// and the destination H2D task depends on that read-out; with
    /// `d2h = None` the legacy destination-only tasks are emitted
    /// bit-exactly. `device_offset` shifts every engine index — the
    /// model layer uses it to land a layer's migration on its pipeline
    /// stage's engines. Returns the H2D task ids.
    pub fn add_transfer_tasks(&self, sim: &mut Sim, h2d: &LinkModel,
                              d2h: Option<&LinkModel>,
                              device_offset: usize) -> Vec<TaskId> {
        self.moves
            .iter()
            .map(|m| {
                let deps: Vec<TaskId> = match d2h {
                    Some(link) => vec![sim.add(
                        format!("D2H-E{}", m.expert),
                        Resource::D2H(m.from + device_offset),
                        link.transfer_time(m.bytes), &[])],
                    None => Vec::new(),
                };
                sim.add(format!("H2D-E{}", m.expert),
                        Resource::H2D(m.to + device_offset),
                        h2d.transfer_time(m.bytes), &deps)
            })
            .collect()
    }

    /// Completion time of the plan's transfer tasks alone. With no D2H
    /// link this is the analytic per-destination serialization of
    /// [`Self::time`], bit-exactly; with one, each H2D task waits on its
    /// own source read-out, so destination engines can stall on busy
    /// source engines — an interaction only the DES prices correctly,
    /// so the value comes from a scratch simulation of exactly the
    /// tasks [`Self::add_transfer_tasks`] would add.
    pub fn transfer_time(&self, h2d: &LinkModel,
                         d2h: Option<&LinkModel>) -> f64 {
        match d2h {
            None => self.time(h2d),
            Some(_) => {
                let mut sim = Sim::new();
                self.add_transfer_tasks(&mut sim, h2d, d2h, 0);
                sim.makespan()
            }
        }
    }
}

/// When a multi-step timeline migrates to the measured-affinity packing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ReplacePolicy {
    /// Never migrate: the initial placement is held for every step (the
    /// static baseline).
    Never,
    /// Migrate on every k-th step whenever the measured packing differs
    /// from the current placement, ignoring costs. `k = 1` is the eager
    /// every-step baseline — under drift noise it churns, repaying
    /// migration cost for placements barely better than the last.
    EveryK {
        /// Step period (fires on steps `k-1, 2k-1, …`).
        k: usize,
    },
    /// Migrate only when the projected downstream saving repays the
    /// migration's makespan cost: simulated per-step saving × remaining
    /// steps must exceed the part of the transfer that outlasts the
    /// current step (MoNTA-style cost awareness, DES-true overlap).
    BreakEven,
}

impl ReplacePolicy {
    /// Display label for study tables.
    pub fn label(&self) -> String {
        match self {
            ReplacePolicy::Never => "never".into(),
            ReplacePolicy::EveryK { k } => format!("every-{k}"),
            ReplacePolicy::BreakEven => "break-even".into(),
        }
    }

    /// The decision rule. `step` is 0-based, `remaining` the steps left
    /// after this one, `saving` the simulated per-step makespan gain of
    /// the candidate placement, `overhead` the migration's makespan cost
    /// (`max(0, transfer − step makespan)` — the overlapped remainder).
    pub fn should_migrate(&self, step: usize, remaining: usize, saving: f64,
                          overhead: f64) -> bool {
        match self {
            ReplacePolicy::Never => false,
            ReplacePolicy::EveryK { k } => {
                assert!(*k > 0, "EveryK period must be at least 1");
                (step + 1) % k == 0
            }
            ReplacePolicy::BreakEven => {
                saving > 0.0 && saving * remaining as f64 > overhead
            }
        }
    }
}

/// Everything a multi-step re-placement timeline needs beyond the
/// routing stream: which schedule to build per step, when to migrate,
/// and what a migration costs.
#[derive(Debug, Clone)]
pub struct ReplaceConfig {
    /// Schedule built for every step (fixed or adaptive slot; resolved
    /// per step against that step's routed costs).
    pub spec: ScheduleSpec,
    /// Migration decision rule.
    pub policy: ReplacePolicy,
    /// Parameter bytes per migrated expert.
    pub bytes_per_expert: usize,
    /// Host-to-device transfer link the H2D engines model.
    pub h2d: LinkModel,
    /// Device-to-host link pricing the *source* side of each move.
    /// `None` (the legacy configuration) emits destination-only H2D
    /// tasks; `Some` chains every H2D task behind its source read-out
    /// on the per-device [`Resource::D2H`] engine. An infinite-bandwidth
    /// zero-latency D2H link reduces bit-exactly to `None` (pinned in
    /// `rust/tests/model_timeline.rs` and mirror `consistency_checks8`).
    pub d2h_link: Option<LinkModel>,
    /// Estimator decay (1.0 = counting; < 1.0 forgets old regimes).
    pub decay: f64,
}

/// One step of a [`ReplaceOutcome`].
#[derive(Debug, Clone)]
pub struct StepReport {
    /// 0-based step index.
    pub step: usize,
    /// DES makespan of the step, including migration H2D spans if a
    /// migration fired here.
    pub makespan: f64,
    /// DES makespan of the step's schedule alone (no migration tasks).
    pub base_makespan: f64,
    /// Whether a migration fired during this step (the new placement
    /// takes effect from the next step).
    pub migrated: bool,
    /// Bytes the migration moved (0 when `!migrated`).
    pub migration_bytes: usize,
    /// Serialized H2D transfer time of the migration (0 when
    /// `!migrated`); the step pays only `max(0, this − base_makespan)`.
    pub migration_time: f64,
}

/// Result of [`run_replace_timeline`]: per-step reports plus the N-step
/// totals and the placement left in force after the last step.
#[derive(Debug, Clone)]
pub struct ReplaceOutcome {
    /// One report per input routing table, in step order.
    pub steps: Vec<StepReport>,
    /// Sum of the per-step makespans — the N-step timeline's makespan
    /// under strict step barriers (optimizer steps synchronize the
    /// fleet between iterations).
    pub total: f64,
    /// Number of migrations fired.
    pub migrations: usize,
    /// Placement in force after the final step.
    pub final_placement: Placement,
}

/// Drive an N-step routing stream through per-step schedules with live
/// measured-affinity re-placement.
///
/// Per step: (1) price the step's table under the placement currently
/// in force (`TopoCosts::from_routing` — routed phases + expert loads)
/// and build the spec's schedule; (2) feed the table to the affinity
/// estimator; (3) unless the policy is [`ReplacePolicy::Never`] or this
/// is the last step, diff the current placement against the measured
/// packing and ask the policy; (4) on migration, overlap the plan's H2D
/// tasks into *this* step's DES graph — the new placement takes effect
/// from the *next* step (weights move while the current step computes
/// with the old layout). Balanced/static streams reduce bit-exactly to
/// N independent single-step schedules (mirror `consistency_checks5`).
pub fn run_replace_timeline(base: &ComputeCosts, topo: &Topology,
                            token_bytes: usize, tables: &[RoutingTable],
                            initial: &Placement,
                            cfg: &ReplaceConfig) -> ReplaceOutcome {
    assert!(!tables.is_empty(), "a timeline needs at least one step");
    let n_nodes = topo.n_devices / topo.devices_per_node;
    let mut est = AffinityEstimator::ewma(initial.n_experts, n_nodes, cfg.decay);
    let mut placement = initial.clone();
    let mut steps = Vec::with_capacity(tables.len());
    let mut total = 0.0f64;
    let mut migrations = 0usize;
    let n_steps = tables.len();
    // every step builds the same spec shape, so the step schedule and the
    // break-even probe warm-start from cached skeletons — two arenas,
    // because the probe would otherwise re-price the step's durations out
    // from under the pending migration append
    let mut arena = SimArena::new();
    let mut probe = SimArena::new();
    for (s, rt) in tables.iter().enumerate() {
        let costs = TopoCosts::from_routing(base, topo, rt, &placement,
                                            token_bytes);
        cfg.spec.build_into(&costs, &mut arena);
        let base_makespan = arena.makespan();
        est.observe(rt, topo.n_devices, topo.devices_per_node);
        let remaining = n_steps - s - 1;
        let mut migrated = false;
        let mut migration_bytes = 0usize;
        let mut migration_time = 0.0f64;
        if remaining > 0 && cfg.policy != ReplacePolicy::Never {
            let candidate = est.packed(topo.n_devices, topo.devices_per_node);
            let plan = MigrationPlan::between(&placement, &candidate,
                                             cfg.bytes_per_expert);
            if !plan.is_empty() {
                // the transfer engines run concurrently with the step's
                // schedule, so the makespan cost of migrating is only
                // the part of the transfer that outlasts the step
                let mig = plan.transfer_time(&cfg.h2d, cfg.d2h_link.as_ref());
                let overhead = (mig - base_makespan).max(0.0);
                let saving = match cfg.policy {
                    ReplacePolicy::BreakEven => {
                        let cand = TopoCosts::from_routing(
                            base, topo, rt, &candidate, token_bytes);
                        cfg.spec.build_into(&cand, &mut probe);
                        base_makespan - probe.makespan()
                    }
                    _ => 0.0,
                };
                if cfg.policy.should_migrate(s, remaining, saving, overhead) {
                    plan.add_transfer_tasks(arena.sim_mut(), &cfg.h2d,
                                            cfg.d2h_link.as_ref(), 0);
                    migrated = true;
                    migration_bytes = plan.total_bytes();
                    migration_time = mig;
                    placement = candidate;
                    migrations += 1;
                }
            }
        }
        // the DES is deterministic, so a step without migration tasks
        // keeps the makespan already simulated above
        let makespan = if migrated { arena.makespan() } else { base_makespan };
        total += makespan;
        steps.push(StepReport {
            step: s,
            makespan,
            base_makespan,
            migrated,
            migration_bytes,
            migration_time,
        });
    }
    ReplaceOutcome { steps, total, migrations, final_placement: placement }
}

/// Deterministic expert failover off a failed device: each of its
/// experts (ascending id) moves to the least-loaded surviving device,
/// ties toward the lower device id, with the running load updated after
/// every reassignment — so a failed device's experts spread instead of
/// piling onto one survivor. Pure placement arithmetic; the migration
/// storm it implies is priced by [`run_chaos_timeline`].
pub fn failover_placement(p: &Placement, failed: usize) -> Placement {
    assert!(p.n_devices > 1, "failover needs a surviving device");
    let mut load = vec![0usize; p.n_devices];
    let mut mapping: Vec<usize> =
        (0..p.n_experts).map(|e| p.device_of(e)).collect();
    for &d in &mapping {
        load[d] += 1;
    }
    for e in 0..p.n_experts {
        if mapping[e] != failed {
            continue;
        }
        load[failed] -= 1;
        let mut best = failed;
        for d in 0..p.n_devices {
            if d == failed {
                continue;
            }
            if best == failed || load[d] < load[best] {
                best = d;
            }
        }
        mapping[e] = best;
        load[best] += 1;
    }
    Placement::custom(p.n_experts, p.n_devices, mapping)
}

/// [`run_replace_timeline`] under a [`ChaosSpec`]: every step prices its
/// table on the spec's *perturbed* topology (jittered/straggling compute
/// scales, degraded or flapping links), and a device dropout triggers
/// recovery — on the dropout step the [`failover_placement`] plan fires
/// unconditionally (its H2D storm overlaps that step; the recovered
/// placement takes effect from the next step, exactly like a policy
/// migration), and later policy candidates are remapped off the dead
/// device so re-learning never places an expert back on it. A
/// zero-magnitude spec ([`ChaosSpec::is_zero`]) reduces bit-exactly to
/// [`run_replace_timeline`] (pinned in `rust/tests/chaos_suite.rs`).
pub fn run_chaos_timeline(base: &ComputeCosts, topo: &Topology,
                          token_bytes: usize, tables: &[RoutingTable],
                          initial: &Placement, cfg: &ReplaceConfig,
                          chaos: &ChaosSpec) -> ReplaceOutcome {
    assert!(!tables.is_empty(), "a timeline needs at least one step");
    let n_nodes = topo.n_devices / topo.devices_per_node;
    let mut est = AffinityEstimator::ewma(initial.n_experts, n_nodes, cfg.decay);
    let mut placement = initial.clone();
    let mut steps = Vec::with_capacity(tables.len());
    let mut total = 0.0f64;
    let mut migrations = 0usize;
    let n_steps = tables.len();
    // step + probe arenas, exactly as in `run_replace_timeline`
    let mut arena = SimArena::new();
    let mut probe = SimArena::new();
    for (s, rt) in tables.iter().enumerate() {
        let ptopo = chaos.perturb(topo, s);
        let costs = TopoCosts::from_routing(base, &ptopo, rt, &placement,
                                            token_bytes);
        cfg.spec.build_into(&costs, &mut arena);
        let base_makespan = arena.makespan();
        est.observe(rt, topo.n_devices, topo.devices_per_node);
        let remaining = n_steps - s - 1;
        let mut migrated = false;
        let mut migration_bytes = 0usize;
        let mut migration_time = 0.0f64;
        let failing = matches!(chaos.dropout, Some(d) if d.at_step == s);
        if failing {
            // the failover is not optional: the device is gone, so the
            // plan fires regardless of policy and pays whatever the
            // migration storm costs on this step's H2D engines
            let failed = chaos.dropout.unwrap().device;
            let candidate = failover_placement(&placement, failed);
            let plan = MigrationPlan::between(&placement, &candidate,
                                              cfg.bytes_per_expert);
            if !plan.is_empty() {
                migration_time = plan.transfer_time(&cfg.h2d,
                                                    cfg.d2h_link.as_ref());
                plan.add_transfer_tasks(arena.sim_mut(), &cfg.h2d,
                                        cfg.d2h_link.as_ref(), 0);
                migrated = true;
                migration_bytes = plan.total_bytes();
                migrations += 1;
            }
            placement = candidate;
        } else if remaining > 0 && cfg.policy != ReplacePolicy::Never {
            let mut candidate = est.packed(topo.n_devices,
                                           topo.devices_per_node);
            if let Some(d) = chaos.dropout {
                if s > d.at_step {
                    candidate = failover_placement(&candidate, d.device);
                }
            }
            let plan = MigrationPlan::between(&placement, &candidate,
                                              cfg.bytes_per_expert);
            if !plan.is_empty() {
                let mig = plan.transfer_time(&cfg.h2d, cfg.d2h_link.as_ref());
                let overhead = (mig - base_makespan).max(0.0);
                let saving = match cfg.policy {
                    ReplacePolicy::BreakEven => {
                        let cand = TopoCosts::from_routing(
                            base, &ptopo, rt, &candidate, token_bytes);
                        cfg.spec.build_into(&cand, &mut probe);
                        base_makespan - probe.makespan()
                    }
                    _ => 0.0,
                };
                if cfg.policy.should_migrate(s, remaining, saving, overhead) {
                    plan.add_transfer_tasks(arena.sim_mut(), &cfg.h2d,
                                            cfg.d2h_link.as_ref(), 0);
                    migrated = true;
                    migration_bytes = plan.total_bytes();
                    migration_time = mig;
                    placement = candidate;
                    migrations += 1;
                }
            }
        }
        let makespan = if migrated { arena.makespan() } else { base_makespan };
        total += makespan;
        steps.push(StepReport {
            step: s,
            makespan,
            base_makespan,
            migrated,
            migration_bytes,
            migration_time,
        });
    }
    ReplaceOutcome { steps, total, migrations, final_placement: placement }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn placements() -> (Placement, Placement) {
        // block [0,1,2,3] vs the corpus affinity packing [0,3,1,2]
        (Placement::new(4, 4), Placement::custom(4, 4, vec![0, 3, 1, 2]))
    }

    #[test]
    fn plan_diffs_only_moved_experts() {
        let (block, affinity) = placements();
        let plan = MigrationPlan::between(&block, &affinity, 4096);
        assert_eq!(plan.moves.len(), 3); // expert 0 stays on device 0
        assert_eq!(plan.moves[0],
                   ExpertMove { expert: 1, from: 1, to: 3, bytes: 4096 });
        assert_eq!(plan.total_bytes(), 3 * 4096);
        assert_eq!(plan.bytes_into(0), 0);
        assert_eq!(plan.bytes_into(3), 4096);
        assert!(MigrationPlan::between(&block, &block, 4096).is_empty());
    }

    #[test]
    fn plan_time_serializes_per_destination_engine() {
        // two experts land on device 0, one on device 1: device 0's H2D
        // engine runs its transfers back to back
        let old = Placement::custom(3, 3, vec![1, 2, 2]);
        let new = Placement::custom(3, 3, vec![0, 0, 1]);
        let plan = MigrationPlan::between(&old, &new, 1000);
        let h2d = LinkModel::new(0.5, 1000.0);
        assert!((plan.time(&h2d) - 2.0 * 1.5).abs() < 1e-15);
        // and the DES agrees with the analytic serialization
        let mut sim = Sim::new();
        plan.add_h2d_tasks(&mut sim, &h2d);
        assert!((sim.makespan() - plan.time(&h2d)).abs() < 1e-15);
    }

    #[test]
    fn h2d_tasks_never_overlap_on_one_engine() {
        let old = Placement::custom(4, 2, vec![0, 0, 1, 1]);
        let new = Placement::custom(4, 2, vec![1, 1, 0, 0]);
        let mut sim = Sim::new();
        MigrationPlan::between(&old, &new, 2048)
            .add_h2d_tasks(&mut sim, &LinkModel::new(0.25, 1024.0));
        let mut spans = sim.run();
        spans.sort_by(|a, b| {
            a.resource.cmp(&b.resource).then(a.start.total_cmp(&b.start))
        });
        for w in spans.windows(2) {
            if w[0].resource == w[1].resource {
                assert!(w[1].start >= w[0].end - 1e-12,
                        "H2D overlap on {:?}", w[0].resource);
            }
        }
    }

    #[test]
    fn transfer_tasks_without_d2h_match_legacy_h2d() {
        let (block, affinity) = placements();
        let plan = MigrationPlan::between(&block, &affinity, 4096);
        let h2d = LinkModel::new(0.125, 1024.0);
        let mut legacy = Sim::new();
        plan.add_h2d_tasks(&mut legacy, &h2d);
        let mut new = Sim::new();
        plan.add_transfer_tasks(&mut new, &h2d, None, 0);
        let (ls, ns) = (legacy.run(), new.run());
        assert_eq!(ls.len(), ns.len());
        for (a, b) in ls.iter().zip(&ns) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.resource, b.resource);
            assert_eq!(a.start, b.start);
            assert_eq!(a.end, b.end);
        }
        assert_eq!(plan.transfer_time(&h2d, None), plan.time(&h2d));
    }

    #[test]
    fn infinite_d2h_bandwidth_is_bit_exact_with_none() {
        let (block, affinity) = placements();
        let plan = MigrationPlan::between(&block, &affinity, 4096);
        let h2d = LinkModel::new(0.125, 1024.0);
        let free = LinkModel::new(0.0, f64::INFINITY);
        assert_eq!(plan.transfer_time(&h2d, Some(&free)),
                   plan.transfer_time(&h2d, None));
    }

    #[test]
    fn d2h_source_engine_serializes_the_read_outs() {
        // both experts leave device 0: their D2H read-outs serialize on
        // the one source engine, so the second H2D write starts late
        // even though the destinations differ
        let old = Placement::custom(2, 3, vec![0, 0]);
        let new = Placement::custom(2, 3, vec![1, 2]);
        let plan = MigrationPlan::between(&old, &new, 1000);
        let h2d = LinkModel::new(0.0, 1000.0); // 1.0 per move
        let d2h = LinkModel::new(0.0, 2000.0); // 0.5 per move
        // engine trace: D2H(0) runs 0.5 + 0.5; H2D(1) spans [0.5, 1.5];
        // H2D(2) spans [1.0, 2.0]
        assert!((plan.transfer_time(&h2d, Some(&d2h)) - 2.0).abs() < 1e-15);
        // the analytic destination-only serialization would claim 1.0
        assert!((plan.time(&h2d) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn device_offset_shifts_every_engine() {
        let (block, affinity) = placements();
        let plan = MigrationPlan::between(&block, &affinity, 4096);
        let h2d = LinkModel::new(0.125, 1024.0);
        let d2h = LinkModel::new(0.25, 2048.0);
        let mut sim = Sim::new();
        plan.add_transfer_tasks(&mut sim, &h2d, Some(&d2h), 8);
        for s in sim.run() {
            match s.resource {
                Resource::H2D(d) | Resource::D2H(d) => assert!(d >= 8),
                r => panic!("unexpected resource {r:?}"),
            }
        }
    }

    #[test]
    fn policy_decisions() {
        assert!(!ReplacePolicy::Never.should_migrate(0, 10, 1.0, 0.0));
        let eager = ReplacePolicy::EveryK { k: 1 };
        assert!(eager.should_migrate(0, 10, 0.0, 100.0));
        let every3 = ReplacePolicy::EveryK { k: 3 };
        assert!(!every3.should_migrate(0, 10, 0.0, 0.0));
        assert!(every3.should_migrate(2, 10, 0.0, 0.0));
        let be = ReplacePolicy::BreakEven;
        assert!(be.should_migrate(0, 10, 1.0, 5.0)); // 10 > 5
        assert!(!be.should_migrate(0, 4, 1.0, 5.0)); // 4 < 5
        assert!(!be.should_migrate(0, 10, -1.0, 0.0)); // regression never pays
        assert_eq!(be.label(), "break-even");
        assert_eq!(every3.label(), "every-3");
    }

    #[test]
    #[should_panic(expected = "at least 1")]
    fn zero_period_policy_is_rejected() {
        ReplacePolicy::EveryK { k: 0 }.should_migrate(0, 10, 0.0, 0.0);
    }
}
