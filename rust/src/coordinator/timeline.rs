//! ASCII timeline renderer for DES spans — regenerates Fig. 6.
//!
//! Spans are grouped into rows by resource (compute stream, comm stream,
//! H2D engine) and drawn as labelled bars on a shared time axis.

use std::collections::{BTreeMap, BTreeSet};

use crate::simtime::{Resource, Span, TaskId};
use crate::util::stats::fmt_secs;

/// Render spans as an ASCII chart `width` characters wide. Rows are
/// ordered by the `Resource` enum (all compute streams in device order,
/// then comm streams, then node links), so multi-device fleet renders
/// stay numerically ordered past device 9.
pub fn render(spans: &[Span], width: usize) -> String {
    render_impl(spans, width, None)
}

/// Like [`render`], but spans whose task id is in `critical` are drawn
/// with `#` bars instead of `=` (the `timeline_explorer --critpath`
/// view). With an empty set the output is byte-identical to [`render`].
pub fn render_marked(spans: &[Span], width: usize,
                     critical: &BTreeSet<TaskId>) -> String {
    render_impl(spans, width, Some(critical))
}

fn render_impl(spans: &[Span], width: usize,
               critical: Option<&BTreeSet<TaskId>>) -> String {
    if spans.is_empty() {
        return String::from("(empty timeline)\n");
    }
    let t_end = spans.iter().fold(0.0f64, |m, s| m.max(s.end));
    if t_end <= 0.0 {
        return String::from("(zero-length timeline)\n");
    }
    let scale = width as f64 / t_end;

    let mut rows: BTreeMap<Resource, Vec<&Span>> = BTreeMap::new();
    for s in spans {
        rows.entry(s.resource).or_default().push(s);
    }
    let label_w = rows
        .keys()
        .map(|r| r.row_label().len())
        .max()
        .unwrap_or(0);

    let mut out = String::new();
    for (res, mut row_spans) in rows {
        row_spans.sort_by(|a, b| a.start.total_cmp(&b.start));
        let mut line = vec![b' '; width];
        for s in &row_spans {
            let a = ((s.start * scale) as usize).min(width.saturating_sub(1));
            let b = ((s.end * scale) as usize).clamp(a + 1, width);
            let bar = match critical {
                Some(set) if set.contains(&s.id) => b'#',
                _ => b'=',
            };
            // bar body
            for c in line.iter_mut().take(b).skip(a) {
                *c = bar;
            }
            line[a] = b'|';
            // inscribe label if it fits
            let label: Vec<u8> = s.label.bytes().take(b - a - 1).collect();
            for (i, ch) in label.iter().enumerate() {
                if a + 1 + i < b {
                    line[a + 1 + i] = *ch;
                }
            }
        }
        out.push_str(&format!("{:<label_w$} {}\n", res.row_label(),
                              String::from_utf8(line).unwrap()));
    }
    out.push_str(&format!("total: {}\n", fmt_secs(t_end)));
    out
}

/// Compact per-op summary: label -> (start, end), sorted by start.
pub fn summary(spans: &[Span]) -> String {
    let mut sorted: Vec<&Span> = spans.iter().collect();
    sorted.sort_by(|a, b| a.start.total_cmp(&b.start));
    let mut out = String::new();
    for s in sorted {
        out.push_str(&format!(
            "{:12} {:>10} .. {:>10}  [{}]\n",
            s.label,
            fmt_secs(s.start),
            fmt_secs(s.end),
            s.resource.row_label().trim()
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::Sim;

    #[test]
    fn renders_rows_for_each_resource() {
        let mut sim = Sim::new();
        let a = sim.add("comp", Resource::Compute(0), 1.0, &[]);
        sim.add("comm", Resource::Comm(0), 1.0, &[a]);
        let spans = sim.run();
        let txt = render(&spans, 40);
        assert!(txt.contains("compute[0]"));
        assert!(txt.contains("comm[0]"));
        assert!(txt.contains("total:"));
    }

    #[test]
    fn summary_sorted_by_start() {
        let mut sim = Sim::new();
        let a = sim.add("first", Resource::Compute(0), 1.0, &[]);
        sim.add("second", Resource::Compute(0), 1.0, &[a]);
        let txt = summary(&sim.run());
        let p1 = txt.find("first").unwrap();
        let p2 = txt.find("second").unwrap();
        assert!(p1 < p2);
    }

    #[test]
    fn empty_ok() {
        assert!(render(&[], 40).contains("empty"));
    }

    #[test]
    fn marked_render_reduces_to_plain_on_empty_set() {
        let mut sim = Sim::new();
        let a = sim.add("comp", Resource::Compute(0), 1.0, &[]);
        sim.add("comm", Resource::Comm(0), 1.0, &[a]);
        let spans = sim.run();
        assert_eq!(render_marked(&spans, 40, &BTreeSet::new()),
                   render(&spans, 40));
        let marked = render_marked(&spans, 40,
                                   &BTreeSet::from([a]));
        assert!(marked.contains('#'), "{marked}");
    }

    #[test]
    fn rows_ordered_numerically_past_device_nine() {
        let mut sim = Sim::new();
        for d in [0usize, 2, 10] {
            sim.add("t", Resource::Compute(d), 1.0, &[]);
        }
        sim.add("x", Resource::Comm(0), 1.0, &[]);
        let txt = render(&sim.run(), 20);
        let p0 = txt.find("compute[0]").unwrap();
        let p2 = txt.find("compute[2]").unwrap();
        let p10 = txt.find("compute[10]").unwrap();
        let pc = txt.find("comm[0]").unwrap();
        // device order is numeric (2 before 10), compute before comm
        assert!(p0 < p2 && p2 < p10 && p10 < pc, "{txt}");
    }
}
