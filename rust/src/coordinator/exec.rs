//! Real threaded execution of the ScMoE schedules against PJRT artifacts.
//!
//! One OS thread per simulated device owns that device's expert weights and
//! executes the `expert_op` artifact; the leader thread runs the backbone
//! operators and the routing/encode/decode data plane; link latencies are
//! injected as scaled sleeps on dedicated comm threads so that transfers
//! genuinely overlap leader compute (the DES's two-stream model, made
//! physical). Numerics are integration-tested against the fused-HLO oracle.

use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::cluster::LinkModel;
use crate::moe::{decode, encode, Placement, RoutingTable};
use crate::runtime::{ArtifactSet, Executable, HostTensor};

use super::costs::{Strategy, TopoCosts};
use super::spec::{CostModel, PhaseDir, PhaseScope, ScheduleSpec};

// SAFETY: the PJRT CPU client is internally synchronized; executables are
// immutable after compilation and `execute` is thread-safe per the PJRT API
// contract. The `xla` crate just doesn't declare it.
struct SendExe(Arc<Executable>);
unsafe impl Send for SendExe {}

struct WorkerMsg {
    /// [E_local * C * D] dispatched tokens for this device's experts.
    shard: Vec<f32>,
    reply: mpsc::Sender<(usize, Vec<f32>)>,
    device: usize,
}

/// A simulated expert-parallel device fleet executing real HLO experts.
pub struct Cluster {
    placement: Placement,
    senders: Vec<mpsc::Sender<WorkerMsg>>,
    handles: Vec<thread::JoinHandle<()>>,
    capacity: usize,
    d_model: usize,
    /// Expert weights [E, ...] kept by the leader for encode bookkeeping.
    pub weights: ClusterWeights,
}

/// Stacked expert weights `[E, ...]` plus the gate/layer-norm tensors the
/// leader keeps for routing and encode bookkeeping.
#[derive(Clone)]
pub struct ClusterWeights {
    pub ln_g: HostTensor,
    pub ln_b: HostTensor,
    pub wg: HostTensor,
    pub w1: HostTensor,
    pub b1: HostTensor,
    pub w2: HostTensor,
    pub b2: HostTensor,
}

impl Cluster {
    /// Spawn `n_devices` workers; device i owns experts [i*per, (i+1)*per).
    /// Expert weights are sliced from the stacked `ops_init` tensors
    /// (contiguous along axis 0).
    pub fn spawn(set: &ArtifactSet, n_devices: usize, k: usize) -> Result<Cluster> {
        let m = &set.manifest;
        let e = m.config.n_experts;
        let d = m.config.d_model;
        let f = m.config.d_ff;
        let cap = *m.capacities.get(&k).context("capacity for k")?;
        let placement = Placement::new(e, n_devices);
        let per = placement.experts_per_device();

        let weights_raw = set.get("ops_init")?.run(&[HostTensor::scalar_i32(7)])?;
        let weights = ClusterWeights {
            ln_g: weights_raw[0].clone(),
            ln_b: weights_raw[1].clone(),
            wg: weights_raw[10].clone(),
            w1: weights_raw[11].clone(),
            b1: weights_raw[12].clone(),
            w2: weights_raw[13].clone(),
            b2: weights_raw[14].clone(),
        };

        // each worker runs the single-expert artifact once per local expert
        let exe = set.get(&format!("expert_op_c{cap}"))?;
        let mut senders = Vec::new();
        let mut handles = Vec::new();
        for dev in 0..n_devices {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            senders.push(tx);
            // per-device expert weight slices [per, ...] (axis-0 contiguous)
            let slice = |t: &HostTensor, inner: usize| -> HostTensor {
                let v = t.as_f32().unwrap();
                let start = dev * per * inner;
                let mut shape = t.shape.clone();
                shape[0] = per;
                HostTensor::f32(shape, v[start..start + per * inner].to_vec())
            };
            let w1 = slice(&weights.w1, d * f);
            let b1 = slice(&weights.b1, f);
            let w2 = slice(&weights.w2, f * d);
            let b2 = slice(&weights.b2, d);
            let exe = SendExe(Arc::clone(&exe));
            let handle = thread::spawn(move || {
                let exe = exe;
                let slice1 = |t: &HostTensor, li: usize, inner: usize| -> HostTensor {
                    let v = t.as_f32().unwrap();
                    let shape: Vec<usize> = t.shape[1..].to_vec();
                    HostTensor::f32(shape, v[li * inner..(li + 1) * inner].to_vec())
                };
                while let Ok(msg) = rx.recv() {
                    let mut out_all = Vec::with_capacity(per * cap * d);
                    for li in 0..per {
                        let xe = HostTensor::f32(
                            vec![cap, d],
                            msg.shard[li * cap * d..(li + 1) * cap * d].to_vec());
                        let out = exe.0
                            .run(&[xe,
                                   slice1(&w1, li, d * f),
                                   slice1(&b1, li, f),
                                   slice1(&w2, li, f * d),
                                   slice1(&b2, li, d)])
                            .expect("expert execution failed");
                        let ye = out.into_iter().next().unwrap();
                        match ye.data {
                            crate::runtime::TensorData::F32(v) => out_all.extend(v),
                            _ => unreachable!(),
                        }
                    }
                    let _ = msg.reply.send((msg.device, out_all));
                }
            });
            handles.push(handle);
        }
        Ok(Cluster {
            placement,
            senders,
            handles,
            capacity: cap,
            d_model: d,
            weights,
        })
    }

    /// Number of simulated expert-parallel devices (worker threads).
    pub fn n_devices(&self) -> usize {
        self.placement.n_devices
    }

    /// Per-expert capacity (tokens) of the compiled expert artifact.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Asynchronously dispatch encoded expert buffers ([E, C, D]) to the
    /// workers through simulated links; returns a receiver that yields each
    /// device's results after its combine-path delay.
    ///
    /// `dispatch_delay`/`combine_delay`: one-way link times (already scaled
    /// for wall-clock execution).
    pub fn dispatch_async(
        &self,
        enc: Vec<f32>,
        dispatch_delay: Duration,
        combine_delay: Duration,
    ) -> mpsc::Receiver<(usize, Vec<f32>)> {
        let per = self.placement.experts_per_device();
        let shard_len = per * self.capacity * self.d_model;
        let (final_tx, final_rx) = mpsc::channel();
        let (reply_tx, reply_rx) = mpsc::channel::<(usize, Vec<f32>)>();
        let n = self.n_devices();

        // comm thread: per-device dispatch after the link delay
        let senders = self.senders.clone();
        thread::spawn(move || {
            thread::sleep(dispatch_delay);
            for (dev, tx) in senders.iter().enumerate() {
                let shard = enc[dev * shard_len..(dev + 1) * shard_len].to_vec();
                let _ = tx.send(WorkerMsg { shard, reply: reply_tx.clone(), device: dev });
            }
        });
        // combine thread: collect replies, apply return-path delay
        thread::spawn(move || {
            let mut got = 0;
            while got < n {
                match reply_rx.recv() {
                    Ok(r) => {
                        got += 1;
                        thread::sleep(combine_delay / n as u32);
                        let _ = final_tx.send(r);
                    }
                    Err(_) => break,
                }
            }
        });
        final_rx
    }

    /// Collect all device results into one [E, C, D] buffer.
    pub fn collect(&self, rx: mpsc::Receiver<(usize, Vec<f32>)>) -> Vec<f32> {
        let per = self.placement.experts_per_device();
        let shard_len = per * self.capacity * self.d_model;
        let mut out = vec![0.0f32; self.n_devices() * shard_len];
        for _ in 0..self.n_devices() {
            let (dev, v) = rx.recv().expect("worker died");
            out[dev * shard_len..(dev + 1) * shard_len].copy_from_slice(&v);
        }
        out
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.senders.clear(); // closes channels; workers exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// One measured operator span in a real run.
#[derive(Debug, Clone)]
pub struct WallSpan {
    pub label: String,
    pub start: f64,
    pub end: f64,
}

/// Worst-phase scalar one-way delays `(dispatch, combine)` a routed
/// [`TopoCosts`] implies for `k` routed experts: the slowest device
/// intra phase or node uplink phase per direction — the barrier time
/// the DES charges the collective. This is the first place the *real*
/// executor sees placement effects: an affinity-packed layout shrinks
/// both scalars, and a routing whose byte matrix is asymmetric (e.g. a
/// fan-in onto one device) prices dispatch and combine differently.
pub fn routed_pair_delays(tc: &TopoCosts, k: usize) -> (f64, f64) {
    tc.assert_valid();
    let worst = |dir: PhaseDir| -> f64 {
        let mut w = 0.0f64;
        for d in 0..tc.n_devices() {
            w = w.max(tc.phase(dir, PhaseScope::Intra, d, k));
        }
        for n in 0..CostModel::n_links(tc) {
            w = w.max(tc.phase(dir, PhaseScope::Inter, n, k));
        }
        w
    };
    (worst(PhaseDir::Dispatch), worst(PhaseDir::Combine))
}

/// Execute one Block-MLP + Block-MoE pair for real, driven by the same
/// [`ScheduleSpec`] the DES builders consume: sequential strategies run
/// the blocking MoE chain after the backbone, overlap strategies launch
/// the MoE stream from the preceding layer's intermediate and hide the
/// injected link delays behind backbone compute. Returns the MoE output
/// and measured spans. The spec's kind supplies the routed `k` (its
/// capacity artifact must exist in `set`); chunked strategies execute
/// like their unchunked parents — the thread executor has no chunk-level
/// streams (the DES models those).
///
/// With `topo: Some(tc)` the injected one-way delays come from the cost
/// model's routed phase totals ([`routed_pair_delays`]) — dispatch and
/// combine priced separately, so placement effects reach the wall-clock
/// run; with `None` the raw scalar `link` model prices both directions
/// symmetrically (the legacy path).
#[allow(clippy::too_many_arguments)]
pub fn run_pair_real(
    set: &ArtifactSet,
    cluster: &Cluster,
    x: &HostTensor,
    spec: &ScheduleSpec,
    topo: Option<&TopoCosts>,
    link: LinkModel,
    time_scale: f64,
    backbone_reps: usize,
) -> Result<(Vec<f32>, Vec<WallSpan>)> {
    let k = spec.kind.routed_k();
    let overlap = matches!(spec.strategy,
                           Strategy::Overlap | Strategy::OverlapPipelined { .. });
    let m = &set.manifest;
    let t = m.tokens;
    let d = m.config.d_model;
    let e = m.config.n_experts;
    let cap = cluster.capacity();
    let w = &cluster.weights;

    // modeled one-way A2A times, scaled to wall-clock: routed phase
    // totals when a cost model is supplied, the scalar link otherwise
    let (disp_secs, comb_secs) = match topo {
        Some(tc) => routed_pair_delays(tc, k),
        None => {
            let bytes_out = t * k * m.token_bytes;
            let one_way = link.transfer_time(bytes_out);
            (one_way, one_way)
        }
    };
    let delay = Duration::from_secs_f64(disp_secs * time_scale);
    let combine_delay = Duration::from_secs_f64(comb_secs * time_scale);

    let t0 = Instant::now();
    let mut spans = Vec::new();
    fn mark_into(spans: &mut Vec<WallSpan>, t0: Instant, label: &str,
                 s: Instant, e_: Instant) {
        spans.push(WallSpan {
            label: label.into(),
            start: s.duration_since(t0).as_secs_f64(),
            end: e_.duration_since(t0).as_secs_f64(),
        });
    }

    let gate_exe = set.get(&format!("gate_op_k{k}"))?;
    let attn_exe = set.get("attn_op")?;
    let weights_raw = set.get("ops_init")?.run(&[HostTensor::scalar_i32(7)])?;
    let backbone_args = vec![
        x.clone(),
        weights_raw[0].clone(), weights_raw[1].clone(),
        weights_raw[2].clone(), weights_raw[3].clone(),
        weights_raw[4].clone(), weights_raw[5].clone(),
    ];

    // --- MoE stream head: gate + encode (earliest viable position) ---
    let s = Instant::now();
    let gout = gate_exe.run(&[x.clone(), w.ln_g.clone(), w.ln_b.clone(), w.wg.clone()])?;
    let h = gout[0].as_f32()?;
    let idx = gout[1].as_i32()?;
    let wts = gout[2].as_f32()?;
    let table = RoutingTable::build(idx, wts, t, k, e, cap);
    let enc = encode(&table, h, d);
    mark_into(&mut spans, t0, "Gate+Encode", s, Instant::now());

    let run_backbone = |spans: &mut Vec<WallSpan>| -> Result<()> {
        for i in 0..backbone_reps {
            let s = Instant::now();
            let _ = attn_exe.run(&backbone_args)?;
            let e_ = Instant::now();
            spans.push(WallSpan {
                label: format!("Backbone{i}"),
                start: s.duration_since(t0).as_secs_f64(),
                end: e_.duration_since(t0).as_secs_f64(),
            });
        }
        Ok(())
    };

    let expert_out: Vec<f32>;
    if overlap {
        // launch comm + experts, then run the backbone concurrently
        let rx = cluster.dispatch_async(enc, delay, combine_delay);
        run_backbone(&mut spans)?;
        let s = Instant::now();
        expert_out = cluster.collect(rx);
        mark_into(&mut spans, t0, "Wait+Combine", s, Instant::now());
    } else {
        // sequential: backbone first, then the blocking MoE chain
        run_backbone(&mut spans)?;
        let s = Instant::now();
        thread::sleep(delay); // A2A dispatch
        let rx = cluster.dispatch_async(enc, Duration::ZERO, Duration::ZERO);
        expert_out = cluster.collect(rx);
        thread::sleep(combine_delay); // A2A combine
        mark_into(&mut spans, t0, "MoE(serial)", s, Instant::now());
    }

    let s = Instant::now();
    let y = decode(&table, &expert_out, d);
    mark_into(&mut spans, t0, "Decode", s, Instant::now());
    let _ = cap;
    Ok((y, spans))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Topology;
    use crate::coordinator::costs::ComputeCosts;

    fn base() -> ComputeCosts {
        ComputeCosts {
            attn: 1.0, mlp: 0.75, se: 0.75, gate: 0.0625, encode: 0.0625,
            decode: 0.0625, expert_k1: 0.5,
        }
    }

    #[test]
    fn routed_delays_are_direction_aware() {
        // two tokens (sourced on devices 0 and 1) both route to device
        // 2's expert: dispatch is two single-message sends, combine is
        // one two-message fan-out from device 2 — the combine delay
        // pays the extra launch latency and double volume.
        let rt = RoutingTable::build(&[2, 2], &[1.0, 1.0], 2, 1, 3, 2);
        let topo = Topology {
            n_devices: 3,
            devices_per_node: 3,
            intra: LinkModel::new(0.0625, 1024.0),
            inter: None,
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let tc = TopoCosts::from_routing(&base(), &topo, &rt,
                                         &Placement::new(3, 3), 64);
        let (disp, comb) = routed_pair_delays(&tc, 1);
        assert_eq!(disp, 0.0625 + 64.0 / 1024.0);
        assert_eq!(comb, 0.125 + 128.0 / 1024.0);
    }

    #[test]
    fn affinity_packing_shrinks_routed_delays() {
        // the dyadic routed corpus fleet: affinity packing keeps every
        // route node-local, so both scalar delays drop vs the block
        // layout (the values the real executor now injects)
        let idx: Vec<i32> =
            vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let w = vec![1.0f32; 16];
        let rt = RoutingTable::build(&idx, &w, 16, 1, 4, 16);
        let topo = Topology {
            n_devices: 4,
            devices_per_node: 2,
            intra: LinkModel::new(0.0625, 1024.0),
            inter: Some(LinkModel::new(0.125, 512.0)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let delays = |p: &Placement| {
            routed_pair_delays(
                &TopoCosts::from_routing(&base(), &topo, &rt, p, 64), 1)
        };
        let (bd, bc) = delays(&Placement::new(4, 4));
        let (ad, ac) = delays(&Placement::affinity_packed(&rt, 4, 2));
        assert_eq!((bd, bc), (0.625, 0.625));
        assert_eq!((ad, ac), (0.25, 0.25));
    }
}
