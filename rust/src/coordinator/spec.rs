//! The one entry point for schedule construction: a declarative
//! [`ScheduleSpec`] built against any [`CostModel`] back end.
//!
//! Before this module the construction API had sprawled: `BlockCosts` and
//! `TopoCosts` each exposed their own accessor families (8+ parallel
//! phase accessors on the topology side alone) and three positional-arg
//! topo builders (`build_pair_schedule_topo{,_with,_auto}`) widened with
//! every new dimension. The redesign follows the separation MoNTA draws
//! between its traffic model and its pipeline scheduler: everything the
//! builders need from a cost back end is behind the [`CostModel`] trait's
//! `phase(dir, scope, idx, k)`-style queries, and everything that selects
//! *which* schedule to build lives in the [`ScheduleSpec`] value.
//!
//! ```no_run
//! use scmoe::coordinator::costs::{MoEKind, Strategy, TopoCosts};
//! use scmoe::coordinator::spec::ScheduleSpec;
//! # fn get_costs() -> TopoCosts { unimplemented!() }
//! let tc: TopoCosts = get_costs();
//! let sched = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap)
//!     .adaptive()
//!     .build(&tc);
//! println!("fleet makespan: {}", sched.makespan());
//! ```
//!
//! Both back ends implement [`CostModel`]:
//!
//! - [`BlockCosts`](super::costs::BlockCosts) — the paper's
//!   single-representative-device model, presented as a degenerate
//!   one-device fleet;
//! - [`TopoCosts`](super::costs::TopoCosts) — the topology-aware fleet
//!   model (per-device compute, per-link phases, optional routed
//!   [`ChunkSource`](super::costs::ChunkSource) and per-device
//!   [`ExpertLoad`](crate::moe::ExpertLoad)).
//!
//! A one-device `TopoCosts` and the `BlockCosts` it came from produce the
//! *identical* task graph (same ids, deps, durations) — property-tested in
//! `rust/tests/simtime_props.rs` and pinned by the golden corpus.

use std::ops::Range;

use crate::simtime::{GraphShape, SimArena};

use super::costs::{BlockCosts, ChunkedA2a, MoEKind, Strategy};
use super::schedule::{build_from_spec, build_from_spec_into, built_meta,
                      ChunkPipelining, PairSchedule};

/// Which direction of the All-to-All a phase query refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseDir {
    /// Token dispatch (encode → experts).
    Dispatch,
    /// Result combine (experts → decode). Back ends with symmetric
    /// traffic answer combine queries with the dispatch values.
    Combine,
}

/// Which link level of the All-to-All a phase query refers to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseScope {
    /// Per-device intra-node phase (`idx` = device id, `Comm(idx)`).
    Intra,
    /// Per-node inter-node phase (`idx` = node id, `Link(idx)`).
    Inter,
}

/// How the expert-computation slot is chosen for overlap strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SlotPolicy {
    /// Use the given slot (0..=3) verbatim; ignored by non-overlap
    /// strategies.
    Fixed(usize),
    /// Simulate all four candidate slots (§3.2) and keep the argmin of
    /// the fleet makespan. Requires the shortcut architecture for overlap
    /// strategies.
    Adaptive,
}

/// The unified phase-query interface every schedule builder consumes.
///
/// `idx` is a device id for [`PhaseScope::Intra`] queries and a node id
/// for [`PhaseScope::Inter`] queries; `k` is the routed-expert count the
/// per-`k = 1` stored volumes are scaled by. Implementations must answer
/// combine queries with their dispatch values when traffic is symmetric,
/// so schedules built on symmetric back ends stay bit-exact with the
/// pre-redesign model.
pub trait CostModel {
    /// Number of modeled devices.
    fn n_devices(&self) -> usize;
    /// Devices per node (contiguous block node layout).
    fn devices_per_node(&self) -> usize;
    /// Number of shared inter-node uplinks the builders must emit `Link`
    /// tasks for (0 on single-node back ends).
    fn n_links(&self) -> usize;
    /// Device `d`'s operator durations (already compute-scaled).
    fn device(&self, d: usize) -> &BlockCosts;
    /// One-way All-to-All phase duration (seconds).
    fn phase(&self, dir: PhaseDir, scope: PhaseScope, idx: usize, k: usize) -> f64;
    /// Launch-latency (α) component of [`Self::phase`] — the part every
    /// pipeline chunk pays in full while the byte term divides.
    fn phase_alpha(&self, dir: PhaseDir, scope: PhaseScope, idx: usize,
                   k: usize) -> f64;
    /// Device `d`'s expert-computation time for k routed experts,
    /// *load-scaled*: back ends carrying an `ExpertLoad` stretch hot
    /// devices by `load_d / mean_load` (balanced loads are exactly 1.0).
    fn expert_time(&self, d: usize, k: usize) -> f64;
    /// Per-chunk phase + expert durations for a `chunks`-way pipelined
    /// MoE stream (token-true when the back end carries routing
    /// information; α-true analytic otherwise).
    fn chunk_phases(&self, k: usize, chunks: usize) -> ChunkedA2a;
    /// Validate internal consistency; called once per build.
    fn validate(&self);

    /// Number of nodes covering the modeled devices.
    fn n_nodes(&self) -> usize {
        self.n_devices().div_ceil(self.devices_per_node())
    }

    /// Node owning a device (contiguous block layout).
    fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node()
    }

    /// Devices belonging to a node (contiguous block layout).
    fn devices_of(&self, node: usize) -> Range<usize> {
        let lo = node * self.devices_per_node();
        lo..(lo + self.devices_per_node()).min(self.n_devices())
    }
}

/// Declarative description of one Block-MLP + Block-MoE pair schedule:
/// what to build (`kind` × `strategy`, chunk count inside the strategy),
/// where the experts sit (`slot`), and how chunk phases pipeline
/// (`pipelining`). Construction itself is `spec.build(&cost_model)`.
///
/// The optional routing + placement source and the per-device expert
/// loads are properties of the *cost model* (`TopoCosts::from_routing`
/// carries both), not of the spec: the same spec builds against any back
/// end.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ScheduleSpec {
    /// MoE architecture (paper Fig. 6 rows).
    pub kind: MoEKind,
    /// Execution strategy, including the pipeline chunk count.
    pub strategy: Strategy,
    /// Expert-slot policy for overlap strategies.
    pub slot: SlotPolicy,
    /// Chunk pipelining model for `chunks > 1` strategies.
    pub pipelining: ChunkPipelining,
}

impl ScheduleSpec {
    /// Spec with the defaults every report used implicitly: fixed slot 0
    /// and MoNTA-style staged chunk pipelining.
    pub fn new(kind: MoEKind, strategy: Strategy) -> ScheduleSpec {
        ScheduleSpec {
            kind,
            strategy,
            slot: SlotPolicy::Fixed(0),
            pipelining: ChunkPipelining::Staged,
        }
    }

    /// Use a fixed expert slot (0..=3).
    pub fn with_slot(mut self, slot: usize) -> ScheduleSpec {
        self.slot = SlotPolicy::Fixed(slot);
        self
    }

    /// Choose the expert slot adaptively (argmin over simulated slots).
    pub fn adaptive(mut self) -> ScheduleSpec {
        self.slot = SlotPolicy::Adaptive;
        self
    }

    /// Override the chunk pipelining model (`PhaseChained` is the
    /// measured-slower A/B baseline).
    pub fn with_pipelining(mut self, pipelining: ChunkPipelining) -> ScheduleSpec {
        self.pipelining = pipelining;
        self
    }

    /// Build the schedule against a cost back end. With
    /// [`SlotPolicy::Adaptive`] and an overlap strategy this simulates all
    /// four slots first (and asserts the shortcut architecture, which the
    /// overlap strategies require).
    pub fn build(&self, cm: &dyn CostModel) -> PairSchedule {
        cm.validate();
        let slot = self.resolve_slot(cm);
        build_from_spec(self, cm, slot)
    }

    /// The slot [`Self::build`] will use, plus its simulated makespan —
    /// the §3.2 adaptive search as a first-class query (argmin over the
    /// four candidate locations; non-overlap strategies pin slot 0).
    /// Asserts the shortcut architecture for overlap strategies, so this
    /// and [`Self::build`] with [`SlotPolicy::Adaptive`] cannot disagree
    /// on legality.
    pub fn choose_slot(&self, cm: &dyn CostModel) -> (usize, f64) {
        cm.validate();
        match self.strategy {
            Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
                assert!(matches!(self.kind, MoEKind::ScMoE { .. }),
                        "overlap strategy requires the shortcut architecture");
                let mut best = (0usize, f64::INFINITY);
                for slot in 0..4 {
                    let t = build_from_spec(self, cm, slot).makespan();
                    if t < best.1 {
                        best = (slot, t);
                    }
                }
                best
            }
            _ => (0, build_from_spec(self, cm, 0).makespan()),
        }
    }

    fn resolve_slot(&self, cm: &dyn CostModel) -> usize {
        match self.slot {
            SlotPolicy::Fixed(slot) => slot,
            SlotPolicy::Adaptive => match self.strategy {
                // choose_slot asserts the shortcut architecture
                Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
                    self.choose_slot(cm).0
                }
                _ => 0,
            },
        }
    }

    /// [`Self::build`] through a [`SimArena`]: if the arena holds a
    /// skeleton for this spec's [`Self::shape`], the builder re-prices its
    /// durations in place (warm start — no allocation, no label
    /// formatting); otherwise it builds cold into a cached slot. Either
    /// way `arena.sim()` / `arena.makespan()` afterwards are bit-identical
    /// to a fresh `self.build(cm)`. Adaptive slot resolution runs through
    /// the same arena, so the four candidate probes warm-start too.
    pub fn build_into(&self, cm: &dyn CostModel, arena: &mut SimArena)
                      -> BuiltInto {
        cm.validate();
        let slot = self.resolve_slot_in(cm, arena);
        self.build_resolved_into(cm, slot, arena)
    }

    /// [`Self::choose_slot`] through a [`SimArena`] (bit-identical result;
    /// the four candidate builds warm-start on repeat calls).
    pub fn choose_slot_in(&self, cm: &dyn CostModel, arena: &mut SimArena)
                          -> (usize, f64) {
        cm.validate();
        match self.strategy {
            Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
                assert!(matches!(self.kind, MoEKind::ScMoE { .. }),
                        "overlap strategy requires the shortcut architecture");
                let mut best = (0usize, f64::INFINITY);
                for slot in 0..4 {
                    self.build_resolved_into(cm, slot, arena);
                    let t = arena.makespan();
                    if t < best.1 {
                        best = (slot, t);
                    }
                }
                best
            }
            _ => {
                self.build_resolved_into(cm, 0, arena);
                (0, arena.makespan())
            }
        }
    }

    fn build_resolved_into(&self, cm: &dyn CostModel, slot: usize,
                           arena: &mut SimArena) -> BuiltInto {
        let warm = arena.begin(self.shape(cm, slot));
        build_from_spec_into(self, cm, slot, arena.sim_mut());
        arena.finish();
        BuiltInto { expert_slot: slot, warm }
    }

    fn resolve_slot_in(&self, cm: &dyn CostModel, arena: &mut SimArena)
                       -> usize {
        match self.slot {
            SlotPolicy::Fixed(slot) => slot,
            SlotPolicy::Adaptive => match self.strategy {
                // choose_slot_in asserts the shortcut architecture
                Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
                    self.choose_slot_in(cm, arena).0
                }
                _ => 0,
            },
        }
    }

    /// Injective structural key for the graph this spec builds against
    /// `cm` at `slot`: every input that steers the builders' control flow
    /// (task order, resources, labels, dependency lists) is a coordinate
    /// — kind (tag + routed k), strategy (tag + chunk count), pipelining,
    /// slot, and the fleet dimensions — and nothing that only prices
    /// durations is. Two specs with equal shapes therefore build the
    /// identical skeleton, which is what makes a `SimArena` warm hit
    /// sound, and a stale hit impossible rather than improbable (the key
    /// is a full encoding, not a hash).
    pub fn shape(&self, cm: &dyn CostModel, slot: usize) -> GraphShape {
        let (kind_tag, k) = match self.kind {
            MoEKind::Standard { k } => (0u64, k as u64),
            MoEKind::SharedExpert => (1, 0),
            MoEKind::ScMoE { k } => (2, k as u64),
        };
        let (strat_tag, chunks) = match self.strategy {
            Strategy::Sequential => (0u64, 1u64),
            Strategy::Pipelined { chunks } => (1, chunks as u64),
            Strategy::Overlap => (2, 1),
            Strategy::OverlapPipelined { chunks } => (3, chunks as u64),
        };
        let pipelining = match self.pipelining {
            ChunkPipelining::Staged => 0u64,
            ChunkPipelining::PhaseChained => 1,
        };
        GraphShape([
            kind_tag,
            k,
            strat_tag,
            chunks,
            (pipelining << 32) | slot as u64,
            cm.n_devices() as u64,
            cm.devices_per_node() as u64,
            cm.n_links() as u64,
        ])
    }

    /// The `(strategy, expert_slot)` metadata [`PairSchedule`] would carry
    /// for this spec built at `slot` — for call sites that consume an
    /// arena-built sim but still need the normalized strategy.
    pub fn built_meta(&self, slot: usize) -> (Strategy, usize) {
        built_meta(self, slot)
    }
}

/// Outcome of [`ScheduleSpec::build_into`].
#[derive(Debug, Clone, Copy)]
pub struct BuiltInto {
    /// Expert slot the build used (resolved from the spec's slot policy).
    pub expert_slot: usize,
    /// `true` when the arena re-priced a cached skeleton instead of
    /// building cold.
    pub warm: bool,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::costs::TopoCosts;

    fn costs() -> BlockCosts {
        BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: 0.9,
            a2a_alpha_k1: 0.05,
        }
    }

    #[test]
    fn both_back_ends_build_identical_graphs() {
        let c = costs();
        let tc = TopoCosts::from_block(&c);
        for strategy in [Strategy::Sequential, Strategy::Pipelined { chunks: 3 }] {
            let spec = ScheduleSpec::new(MoEKind::Standard { k: 2 }, strategy);
            let (a, b) = (spec.build(&c).run(), spec.build(&tc).run());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!((x.start, x.end), (y.start, y.end), "{}", x.label);
            }
        }
    }

    #[test]
    fn adaptive_slot_matches_fixed_argmin() {
        let c = costs();
        let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        let (slot, best) = spec.choose_slot(&c);
        assert_eq!(spec.adaptive().build(&c).expert_slot, slot);
        for s in 0..4 {
            assert!(spec.with_slot(s).build(&c).makespan() >= best - 1e-12);
        }
    }

    #[test]
    #[should_panic(expected = "shortcut architecture")]
    fn adaptive_overlap_rejects_non_shortcut_kinds() {
        let c = costs();
        ScheduleSpec::new(MoEKind::Standard { k: 2 }, Strategy::Overlap)
            .adaptive()
            .build(&c);
    }

    #[test]
    fn phase_queries_fall_back_symmetrically() {
        let c = costs();
        let tc = TopoCosts::from_block(&c);
        assert_eq!(tc.phase(PhaseDir::Combine, PhaseScope::Intra, 0, 2),
                   tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2));
        assert_eq!(c.phase(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2),
                   c.a2a(2));
    }
}
