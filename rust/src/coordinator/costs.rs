//! Operator durations and communication volumes for one
//! Block-MLP + Block-MoE pair — the inputs to every schedule builder.

use crate::cluster::{a2a_time, uniform_a2a_bytes, Topology};

/// Which MoE architecture a schedule models (paper Fig. 6 / Fig. 8 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoEKind {
    /// Standard top-k MoE (k = 1, 2, 3): MoE input is the current layer.
    Standard { k: usize },
    /// Shared-expert MoE: SE + top-1, current layer ("Top1+SE1").
    SharedExpert,
    /// ScMoE: SE on current layer + top-k on the *preceding* layer
    /// via the shortcut (k=1 default; k=2 is "ScMoE-2").
    ScMoE { k: usize },
}

impl MoEKind {
    pub fn label(&self) -> String {
        match self {
            MoEKind::Standard { k } => format!("Top{k}"),
            MoEKind::SharedExpert => "Top1+SE1".into(),
            MoEKind::ScMoE { k } => {
                if *k == 1 { "ScMoE".into() } else { format!("ScMoE-{k}") }
            }
        }
    }

    /// Number of gate-selected experts routed through All-to-All.
    pub fn routed_k(&self) -> usize {
        match self {
            MoEKind::Standard { k } => *k,
            MoEKind::SharedExpert => 1,
            MoEKind::ScMoE { k } => *k,
        }
    }

    pub fn has_shared_expert(&self) -> bool {
        matches!(self, MoEKind::SharedExpert | MoEKind::ScMoE { .. })
    }
}

/// Execution strategy for the MoE stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Fully sequential (the naive baseline).
    Sequential,
    /// Tutel-style pipelining: tokens split into `chunks`; chunk i's expert
    /// compute overlaps chunk i+1's dispatch / chunk i-1's combine.
    Pipelined { chunks: usize },
    /// The paper's overlapping strategy (requires a shortcut architecture).
    Overlap,
    /// Overlap augmented with pipelining (Fig. 6, 5th timeline).
    OverlapPipelined { chunks: usize },
}

impl Strategy {
    pub fn label(&self) -> String {
        match self {
            Strategy::Sequential => "seq".into(),
            Strategy::Pipelined { chunks } => format!("pipe{chunks}"),
            Strategy::Overlap => "overlap".into(),
            Strategy::OverlapPipelined { chunks } => format!("overlap+pipe{chunks}"),
        }
    }
}

/// Durations (seconds) of the operators in one Block-MLP/Block-MoE pair,
/// plus the communication volumes needed to derive A2A times.
#[derive(Debug, Clone)]
pub struct BlockCosts {
    /// Attention sub-layer (one per block; assumed equal across the pair).
    pub attn: f64,
    /// Dense MLP sub-layer of the Block-MLP.
    pub mlp: f64,
    /// Shared expert (an MLP on the current layer).
    pub se: f64,
    /// Gate routing (+ encode) per routed-expert set.
    pub gate: f64,
    /// Encode (layout aggregation before dispatch).
    pub encode: f64,
    /// Decode (inverse of encode, after combine).
    pub decode: f64,
    /// Expert FFN over one capacity batch with k routed experts.
    pub expert_k1: f64,
    /// One-way All-to-All time for k = 1 volume.
    pub a2a_k1: f64,
}

impl BlockCosts {
    /// Expert computation time for k routed experts (capacity ∝ k; linear —
    /// the conservative model, see EXPERIMENTS.md §Deviations for the
    /// effect on the paper's Table 4 top-3 row).
    pub fn expert(&self, k: usize) -> f64 {
        self.expert_k1 * k as f64
    }

    /// One-way All-to-All (dispatch or combine) for k routed experts.
    pub fn a2a(&self, k: usize) -> f64 {
        self.a2a_k1 * k as f64
    }

    /// Total MoE-path time under naive sequential execution (for the
    /// comm-fraction metrics of Fig. 1).
    pub fn moe_sequential(&self, k: usize) -> f64 {
        self.gate + self.encode + self.a2a(k) + self.expert(k) + self.a2a(k) + self.decode
    }

    /// Communication share of the sequential MoE path.
    pub fn comm_fraction(&self, k: usize) -> f64 {
        2.0 * self.a2a(k) / self.moe_sequential(k)
    }

    /// Build costs from compute-op durations measured on the A30-relative
    /// scale plus a topology (which supplies A2A time and compute scaling).
    pub fn from_topology(base: &ComputeCosts, topo: &Topology,
                         tokens_per_device: usize, token_bytes: usize,
                         capacity_factor: f64) -> BlockCosts {
        let s = topo.compute_scale;
        // k=1 volume: each device dispatches its tokens' routed copies;
        // under uniform routing a (1 - 1/n) fraction crosses the link, with
        // capacity_factor headroom in buffer sizing.
        let bytes_per_pair = ((tokens_per_device as f64 * capacity_factor
            / topo.n_devices as f64) * token_bytes as f64) as usize;
        let m = uniform_a2a_bytes(topo.n_devices, bytes_per_pair);
        let a2a_k1 = a2a_time(&m, topo.n_devices, topo.devices_per_node,
                              topo.intra, topo.inter);
        BlockCosts {
            attn: base.attn / s,
            mlp: base.mlp / s,
            se: base.se / s,
            gate: base.gate / s,
            encode: base.encode / s,
            decode: base.decode / s,
            expert_k1: base.expert_k1 / s,
            a2a_k1,
        }
    }
}

/// Pure compute-op durations on the baseline device (A30 scale = 1.0).
/// Produced by the calibration harness (`scmoe bench-calib`) from real CPU
/// measurements of the AOT operator artifacts, then scaled to GPU-class
/// throughput ratios; or taken from the built-in proxy preset.
#[derive(Debug, Clone)]
pub struct ComputeCosts {
    pub attn: f64,
    pub mlp: f64,
    pub se: f64,
    pub gate: f64,
    pub encode: f64,
    pub decode: f64,
    pub expert_k1: f64,
}

impl ComputeCosts {
    /// SwinV2-MoE-S block proxy (paper Fig. 1/8 shapes): ratios measured
    /// from the ops_tiny artifacts on CPU (see EXPERIMENTS.md §Calibration),
    /// absolute scale normalized so attn ≈ 1 ms on the A30 baseline.
    pub fn swin_proxy() -> ComputeCosts {
        ComputeCosts {
            attn: 1.00e-3,
            mlp: 0.75e-3,
            se: 0.75e-3,
            gate: 0.06e-3,
            encode: 0.05e-3,
            decode: 0.05e-3,
            expert_k1: 0.80e-3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Scenario;

    #[test]
    fn comm_fraction_matches_paper_bands() {
        // Fig. 1: top-2 comm share ≈ 60% on PCIe, ≈ 15% on NVLink,
        // ≈ 50% across 2 nodes. The presets must land in those bands.
        let base = ComputeCosts::swin_proxy();
        let costs = |sc: Scenario| {
            let t = sc.topology();
            BlockCosts::from_topology(&base, &t, 4096, 384, 1.25)
        };
        let f_pcie = costs(Scenario::PcieA30x8).comm_fraction(2);
        let f_nv = costs(Scenario::NvlinkA800x8).comm_fraction(2);
        let f_2n = costs(Scenario::TwoNodeA800x16).comm_fraction(2);
        assert!((0.50..0.70).contains(&f_pcie), "pcie comm frac {f_pcie}");
        assert!((0.08..0.25).contains(&f_nv), "nvlink comm frac {f_nv}");
        assert!((0.35..0.60).contains(&f_2n), "2node comm frac {f_2n}");
    }

    #[test]
    fn expert_and_a2a_scale_with_k() {
        let c = BlockCosts {
            attn: 1.0, mlp: 1.0, se: 1.0, gate: 0.1, encode: 0.1,
            decode: 0.1, expert_k1: 0.5, a2a_k1: 0.3,
        };
        assert_eq!(c.expert(2), 1.0);
        assert_eq!(c.a2a(3), 0.3 * 3.0);
    }
}
