//! Operator durations and communication volumes for one
//! Block-MLP + Block-MoE pair — the inputs to every schedule builder.
//!
//! Two granularities coexist:
//!
//! - [`BlockCosts`] — the paper's single-representative-device model: one
//!   scalar one-way All-to-All time (`a2a_k1`) per routed-expert volume;
//! - [`TopoCosts`] — the topology-aware model: per-device operator
//!   durations (heterogeneous fleets run slower on some devices) plus a
//!   MoNTA-style per-link decomposition of each All-to-All into per-device
//!   intra-node and per-node inter-node phases, derived from topology +
//!   token counts instead of scalar constants.
//!
//! `TopoCosts::from_block` embeds a `BlockCosts` as the degenerate
//! one-modeled-device topology; schedules built from it reproduce the
//! legacy single-device schedules bit-exactly (property-tested in
//! `rust/tests/simtime_props.rs`).
//!
//! Communication volume likewise comes in two granularities:
//! [`TopoCosts::from_topology`] feeds the decomposition a *uniform* byte
//! matrix (every device pair exchanges the same volume), while
//! [`TopoCosts::from_routing`] derives the matrix from an actual
//! `moe::RoutingTable` and `moe::Placement`, so skewed routing or
//! ExFlow-style placements change the simulated per-link phase times —
//! including asymmetric dispatch vs. combine phases when the routed matrix
//! is not symmetric.
//!
//! Pipeline chunking is priced honestly at both granularities: every
//! phase carries its launch-latency (α) component separately from the
//! byte term, so a chunk pays the full α and only its byte share
//! ([`BlockCosts::a2a_chunk`], [`CostModel::chunk_phases`]); routed costs
//! additionally carry a [`ChunkSource`] so per-chunk phases are
//! recomputed from each chunk's own token range (token-true chunking —
//! see docs/ARCHITECTURE.md §"The chunked A2A model").
//!
//! Schedule builders consume both granularities through ONE interface:
//! the [`CostModel`] trait (`phase(dir, scope, idx, k)`-style queries,
//! defined in [`super::spec`]), which `BlockCosts` implements as a
//! degenerate one-device fleet and `TopoCosts` implements over its stored
//! phase vectors. `TopoCosts::from_routing` additionally derives a
//! per-device [`ExpertLoad`] (`RoutingTable::load` × [`Placement`]), so a
//! hot device's Expert duration stretches by `load / mean` — balanced
//! routing multiplies by exactly 1.0 and reduces bit-exactly to the
//! balanced-capacity-batch model.

use crate::cluster::{
    a2a_chunk_time, a2a_decompose_per_node, a2a_time_split_per_node,
    a2a_transpose, uniform_a2a_bytes, LinkModel, Topology,
};
use crate::moe::{ExpertLoad, Placement, RoutingTable};

use super::spec::{CostModel, PhaseDir, PhaseScope};

/// Which MoE architecture a schedule models (paper Fig. 6 / Fig. 8 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoEKind {
    /// Standard top-k MoE (k = 1, 2, 3): MoE input is the current layer.
    Standard { k: usize },
    /// Shared-expert MoE: SE + top-1, current layer ("Top1+SE1").
    SharedExpert,
    /// ScMoE: SE on current layer + top-k on the *preceding* layer
    /// via the shortcut (k=1 default; k=2 is "ScMoE-2").
    ScMoE { k: usize },
}

impl MoEKind {
    /// Display label matching the paper's table rows ("Top2", "ScMoE", …).
    pub fn label(&self) -> String {
        match self {
            MoEKind::Standard { k } => format!("Top{k}"),
            MoEKind::SharedExpert => "Top1+SE1".into(),
            MoEKind::ScMoE { k } => {
                if *k == 1 { "ScMoE".into() } else { format!("ScMoE-{k}") }
            }
        }
    }

    /// Number of gate-selected experts routed through All-to-All.
    pub fn routed_k(&self) -> usize {
        match self {
            MoEKind::Standard { k } => *k,
            MoEKind::SharedExpert => 1,
            MoEKind::ScMoE { k } => *k,
        }
    }

    /// Whether the architecture adds a shared-expert MLP on the backbone.
    pub fn has_shared_expert(&self) -> bool {
        matches!(self, MoEKind::SharedExpert | MoEKind::ScMoE { .. })
    }
}

/// Execution strategy for the MoE stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Fully sequential (the naive baseline).
    Sequential,
    /// Tutel-style pipelining: tokens split into `chunks`; chunk i's expert
    /// compute overlaps chunk i+1's dispatch / chunk i-1's combine.
    Pipelined { chunks: usize },
    /// The paper's overlapping strategy (requires a shortcut architecture).
    Overlap,
    /// Overlap augmented with pipelining (Fig. 6, 5th timeline).
    OverlapPipelined { chunks: usize },
}

impl Strategy {
    /// Display label ("seq", "pipe2", "overlap", "overlap+pipe2", …).
    pub fn label(&self) -> String {
        match self {
            Strategy::Sequential => "seq".into(),
            Strategy::Pipelined { chunks } => format!("pipe{chunks}"),
            Strategy::Overlap => "overlap".into(),
            Strategy::OverlapPipelined { chunks } => format!("overlap+pipe{chunks}"),
        }
    }
}

/// Durations (seconds) of the operators in one Block-MLP/Block-MoE pair,
/// plus the communication volumes needed to derive A2A times.
#[derive(Debug, Clone)]
pub struct BlockCosts {
    /// Attention sub-layer (one per block; assumed equal across the pair).
    pub attn: f64,
    /// Dense MLP sub-layer of the Block-MLP.
    pub mlp: f64,
    /// Shared expert (an MLP on the current layer).
    pub se: f64,
    /// Gate routing (+ encode) per routed-expert set.
    pub gate: f64,
    /// Encode (layout aggregation before dispatch).
    pub encode: f64,
    /// Decode (inverse of encode, after combine).
    pub decode: f64,
    /// Expert FFN over one capacity batch with k routed experts.
    pub expert_k1: f64,
    /// One-way All-to-All time for k = 1 volume.
    pub a2a_k1: f64,
    /// Launch-latency (α) component of `a2a_k1`: the part of the one-way
    /// time every pipeline chunk pays in full, while the remaining byte
    /// term divides across chunks (see [`Self::a2a_chunk`]). Zero models a
    /// latency-free link, under which chunking is free — the seed's
    /// (buggy) behavior for every link.
    pub a2a_alpha_k1: f64,
}

impl BlockCosts {
    /// Expert computation time for k routed experts (capacity ∝ k; linear —
    /// the conservative model, see EXPERIMENTS.md §Deviations for the
    /// effect on the paper's Table 4 top-3 row).
    pub fn expert(&self, k: usize) -> f64 {
        self.expert_k1 * k as f64
    }

    /// One-way All-to-All (dispatch or combine) for k routed experts.
    pub fn a2a(&self, k: usize) -> f64 {
        self.a2a_k1 * k as f64
    }

    /// Launch-latency component of [`Self::a2a`] (k-scaled like the phase
    /// itself, matching the flat model's volume convention).
    pub fn a2a_alpha(&self, k: usize) -> f64 {
        self.a2a_alpha_k1 * k as f64
    }

    /// One chunk's share of a `chunks`-way-pipelined one-way All-to-All:
    /// `α + (bytes / chunks) / β`, i.e. every chunk message pays the full
    /// launch latency and only the byte term divides. `chunks == 1`
    /// returns [`Self::a2a`] bit-exactly. Shared arithmetic with the
    /// topology-aware path via [`cluster::a2a_chunk_time`], so the two
    /// models can never disagree on chunking.
    ///
    /// [`cluster::a2a_chunk_time`]: crate::cluster::a2a_chunk_time
    pub fn a2a_chunk(&self, k: usize, chunks: usize) -> f64 {
        a2a_chunk_time(self.a2a(k), self.a2a_alpha(k), chunks)
    }

    /// Total MoE-path time under naive sequential execution (for the
    /// comm-fraction metrics of Fig. 1).
    pub fn moe_sequential(&self, k: usize) -> f64 {
        self.gate + self.encode + self.a2a(k) + self.expert(k) + self.a2a(k) + self.decode
    }

    /// Communication share of the sequential MoE path.
    pub fn comm_fraction(&self, k: usize) -> f64 {
        2.0 * self.a2a(k) / self.moe_sequential(k)
    }

    /// Build costs from compute-op durations measured on the A30-relative
    /// scale plus a topology (which supplies A2A time and compute scaling).
    /// On heterogeneous fleets the representative device is the slowest
    /// one (`Topology::min_compute_scale`): barrier collectives are gated
    /// by the stragglers, so a faster representative would understate the
    /// fleet makespan.
    pub fn from_topology(base: &ComputeCosts, topo: &Topology,
                         tokens_per_device: usize, token_bytes: usize,
                         capacity_factor: f64) -> BlockCosts {
        topo.assert_valid();
        let m = uniform_a2a_bytes(
            topo.n_devices,
            uniform_bytes_per_pair(topo, tokens_per_device, token_bytes,
                                   capacity_factor));
        let (a2a_k1, a2a_alpha_k1) = a2a_time_split_per_node(
            &m, topo.n_devices, topo.devices_per_node,
            &topo.intra_links(), topo.inter);
        base.scaled(topo.min_compute_scale(), a2a_k1, a2a_alpha_k1)
    }
}

/// k=1 uniform-routing volume: each device dispatches its tokens' routed
/// copies; under uniform routing a (1 - 1/n) fraction crosses the link,
/// with `capacity_factor` headroom in buffer sizing. Shared by the legacy
/// and topology-aware cost constructors so the two models can never
/// disagree on communication volume. Fractional bytes round to nearest
/// (half away from zero) rather than truncating, so a 2/3-byte pair no
/// longer loses volume to integer casting.
pub fn uniform_bytes_per_pair(topo: &Topology, tokens_per_device: usize,
                              token_bytes: usize,
                              capacity_factor: f64) -> usize {
    ((tokens_per_device as f64 * capacity_factor / topo.n_devices as f64)
        * token_bytes as f64).round() as usize
}

/// Topology-aware costs for one Block-MLP + Block-MoE pair across a
/// modeled device fleet (see the module docs for how this generalizes
/// [`BlockCosts`]).
#[derive(Debug, Clone)]
pub struct TopoCosts {
    /// Per modeled device: compute-op durations in seconds (already scaled
    /// by that device's compute speed) plus the flat one-way `a2a_k1` for
    /// reporting and the single-device reduction.
    pub per_device: Vec<BlockCosts>,
    /// Per-device one-way *dispatch* intra-node All-to-All phase (seconds)
    /// at k = 1 volume.
    pub a2a_intra_k1: Vec<f64>,
    /// Per-node one-way *dispatch* inter-node All-to-All phase (seconds)
    /// at k = 1 volume; empty for single-node (or single-modeled-device)
    /// topologies.
    pub a2a_inter_k1: Vec<f64>,
    /// Per-device *combine* intra-node phase (seconds) at k = 1 volume.
    /// Empty means the combine direction mirrors dispatch exactly (true
    /// for uniform routing, whose byte matrix is symmetric); routed
    /// constructors fill it from the transposed byte matrix.
    pub a2a_intra_combine_k1: Vec<f64>,
    /// Per-node *combine* inter-node phase (seconds) at k = 1 volume;
    /// empty under the same symmetric-fallback rule as
    /// `a2a_intra_combine_k1`.
    pub a2a_inter_combine_k1: Vec<f64>,
    /// Launch-latency (α) component of each dispatch intra phase — the
    /// part a pipeline chunk pays in full while the byte term divides.
    /// Empty models latency-free links (α = 0 everywhere), under which
    /// chunking divides phases exactly as the seed did.
    pub a2a_intra_alpha_k1: Vec<f64>,
    /// α component of each dispatch inter phase; empty = zero.
    pub a2a_inter_alpha_k1: Vec<f64>,
    /// α component of each combine intra phase; empty mirrors the
    /// dispatch α (same fallback rule as the combine phases).
    pub a2a_intra_combine_alpha_k1: Vec<f64>,
    /// α component of each combine inter phase; empty mirrors dispatch.
    pub a2a_inter_combine_alpha_k1: Vec<f64>,
    /// Token-true chunking source: when present, per-chunk phases are
    /// recomputed from the actual routing table split into contiguous
    /// token ranges (see [`CostModel::chunk_phases`]); when absent, chunks
    /// fall back to the α-true analytic split of the stored phase vectors.
    pub chunk_source: Option<ChunkSource>,
    /// Per-device routed compute load. When present, every device's
    /// Expert duration is stretched by `load_d / mean_load`
    /// ([`CostModel::expert_time`]) and chunked Expert spans split by each
    /// chunk's own token share instead of dividing evenly. `None` (and any
    /// perfectly balanced load vector) reduces unchunked Expert durations
    /// bit-exactly to the balanced-capacity-batch model the paper
    /// assumes; chunked spans also coincide whenever the chunking splits
    /// the balanced loads evenly (an uneven token split legitimately
    /// prices its hotter chunk higher — that is the token-true point).
    pub expert_load: Option<ExpertLoad>,
    /// Devices per node (contiguous block node layout).
    pub devices_per_node: usize,
}

/// Everything needed to recompute *token-true* per-chunk All-to-All
/// phases for any chunk count: the routing table is re-split into
/// contiguous token ranges ([`RoutingTable::chunk`]) and each range's
/// routed byte matrix is decomposed through the same link models as the
/// unchunked phase vectors, so a chunk only pays α toward destinations it
/// actually sends to and skewed routing skews per-chunk traffic.
#[derive(Debug, Clone)]
pub struct ChunkSource {
    /// The routing decisions the unchunked phases were derived from.
    pub rt: RoutingTable,
    /// Expert placement in force.
    pub placement: Placement,
    /// Payload bytes per routed token copy.
    pub token_bytes: usize,
    /// One intra-node link per node (same vector the unchunked
    /// decomposition used).
    pub intra_links: Vec<LinkModel>,
    /// Shared inter-node uplink, if any.
    pub inter: Option<LinkModel>,
    /// Per-token source devices when the unchunked phases were priced
    /// from explicit sources ([`TopoCosts::from_routing_with_sources`]);
    /// `None` keeps the even index-order split. Indexed by absolute
    /// token id, so chunked parts (which keep parent token ids) reuse
    /// the same vector.
    pub sources: Option<Vec<usize>>,
}

/// Per-chunk, per-link one-way All-to-All durations plus per-chunk expert
/// durations (seconds, already scaled to the requested k) for one
/// `chunks`-way pipelined collective.
/// Outer index = chunk, inner = device (intra/expert) or node (inter).
#[derive(Debug, Clone)]
pub struct ChunkedA2a {
    /// Dispatch intra-node phase per `[chunk][device]`.
    pub disp_intra: Vec<Vec<f64>>,
    /// Dispatch inter-node phase per `[chunk][node]`.
    pub disp_inter: Vec<Vec<f64>>,
    /// Combine intra-node phase per `[chunk][device]`.
    pub comb_intra: Vec<Vec<f64>>,
    /// Combine inter-node phase per `[chunk][node]`.
    pub comb_inter: Vec<Vec<f64>>,
    /// Expert-computation duration per `[chunk][device]` — token-true
    /// (proportional to the chunk's own kept token copies on that device)
    /// when the cost model carries a routed `ExpertLoad`; an even
    /// `expert_time / chunks` split otherwise.
    pub expert: Vec<Vec<f64>>,
}

impl TopoCosts {
    /// Number of modeled devices.
    pub fn n_devices(&self) -> usize {
        self.per_device.len()
    }

    /// Number of nodes covering the modeled devices.
    pub fn n_nodes(&self) -> usize {
        self.n_devices().div_ceil(self.devices_per_node)
    }

    /// Node owning a device (contiguous block layout).
    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    /// Devices belonging to a node (contiguous block layout).
    pub fn devices_of(&self, node: usize) -> std::ops::Range<usize> {
        let lo = node * self.devices_per_node;
        lo..(lo + self.devices_per_node).min(self.n_devices())
    }

    /// Validate internal consistency (the hand-construction twin of
    /// `Topology::assert_valid`): every device needs an intra phase, and
    /// the inter phases must cover every node or be absent entirely —
    /// the schedule builders size their `Link` task loops off
    /// `a2a_inter_k1.len()`, so a short vector would silently drop
    /// uplink tasks instead of failing.
    pub fn assert_valid(&self) {
        assert!(!self.per_device.is_empty(), "at least one modeled device");
        assert!(self.devices_per_node > 0);
        // Every cluster::a2a_* cost function requires whole nodes; a
        // ragged hand-built fleet would silently desync from the cost
        // model (n_nodes/devices_of tolerate it), so fail loudly here.
        assert!(self.n_devices() % self.devices_per_node == 0,
                "devices ({}) must divide into nodes of {}",
                self.n_devices(), self.devices_per_node);
        assert_eq!(self.a2a_intra_k1.len(), self.per_device.len(),
                   "one intra-node phase per device");
        assert!(self.a2a_inter_k1.is_empty()
                    || self.a2a_inter_k1.len() == self.n_nodes(),
                "inter-node phases must cover every node (or be empty)");
        assert!(self.a2a_intra_combine_k1.is_empty()
                    || self.a2a_intra_combine_k1.len() == self.per_device.len(),
                "combine intra phases must cover every device (or be empty)");
        assert!(self.a2a_inter_combine_k1.is_empty()
                    || self.a2a_inter_combine_k1.len() == self.a2a_inter_k1.len(),
                "combine inter phases must mirror the dispatch link set \
                 (or be empty)");
        assert!(self.a2a_intra_alpha_k1.is_empty()
                    || self.a2a_intra_alpha_k1.len() == self.per_device.len(),
                "intra α terms must cover every device (or be empty)");
        assert!(self.a2a_inter_alpha_k1.is_empty()
                    || self.a2a_inter_alpha_k1.len() == self.a2a_inter_k1.len(),
                "inter α terms must mirror the dispatch link set (or be empty)");
        assert!(self.a2a_intra_combine_alpha_k1.is_empty()
                    || self.a2a_intra_combine_alpha_k1.len()
                        == self.per_device.len(),
                "combine intra α terms must cover every device (or be empty)");
        assert!(self.a2a_inter_combine_alpha_k1.is_empty()
                    || self.a2a_inter_combine_alpha_k1.len()
                        == self.a2a_inter_k1.len(),
                "combine inter α terms must mirror the dispatch link set \
                 (or be empty)");
        if let Some(src) = &self.chunk_source {
            assert_eq!(src.placement.n_devices, self.n_devices(),
                       "chunk source placement must cover the fleet");
            assert_eq!(src.intra_links.len(), self.n_nodes(),
                       "chunk source needs one intra link per node");
        }
        if let Some(load) = &self.expert_load {
            assert_eq!(load.per_device.len(), self.n_devices(),
                       "one expert load per device");
            assert_eq!(load.per_device.iter().sum::<usize>(), load.total,
                       "expert load total must equal the per-device sum");
            if let Some(src) = &self.chunk_source {
                assert_eq!(load.total, src.rt.kept(),
                           "expert loads must sum to the routed token total");
            }
        }
    }

    /// Degenerate one-modeled-device view of legacy costs. Schedules built
    /// from this reduce bit-exactly to the legacy single-device schedules:
    /// the single intra phase carries the whole scalar `a2a_k1` and there
    /// is no inter-node resource.
    pub fn from_block(c: &BlockCosts) -> TopoCosts {
        TopoCosts {
            a2a_intra_k1: vec![c.a2a_k1],
            a2a_inter_k1: Vec::new(),
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: vec![c.a2a_alpha_k1],
            a2a_inter_alpha_k1: Vec::new(),
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            expert_load: None,
            per_device: vec![c.clone()],
            devices_per_node: 1,
        }
    }

    /// Build topology-aware costs under *uniform* routing: per-device
    /// compute durations from the device's own compute scale, All-to-All
    /// phases from the uniform byte matrix decomposed per link
    /// (`cluster::a2a_decompose_per_node`). The uniform matrix is
    /// symmetric, so the combine vectors stay empty and combine phases
    /// mirror dispatch bit-exactly — this is the N-devices degenerate case
    /// of [`Self::from_routing`].
    pub fn from_topology(base: &ComputeCosts, topo: &Topology,
                         tokens_per_device: usize, token_bytes: usize,
                         capacity_factor: f64) -> TopoCosts {
        topo.assert_valid();
        let m = uniform_a2a_bytes(
            topo.n_devices,
            uniform_bytes_per_pair(topo, tokens_per_device, token_bytes,
                                   capacity_factor));
        let links = topo.intra_links();
        let phases = a2a_decompose_per_node(&m, topo.n_devices,
                                            topo.devices_per_node,
                                            &links, topo.inter);
        let (flat, flat_alpha) = a2a_time_split_per_node(
            &m, topo.n_devices, topo.devices_per_node, &links, topo.inter);
        let per_device = (0..topo.n_devices)
            .map(|d| base.scaled(topo.device_compute_scale(d), flat, flat_alpha))
            .collect();
        TopoCosts {
            per_device,
            a2a_intra_k1: phases.intra,
            a2a_inter_k1: phases.inter,
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: phases.intra_alpha,
            a2a_inter_alpha_k1: phases.inter_alpha,
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            expert_load: None,
            devices_per_node: topo.devices_per_node,
        }
    }

    /// Build topology-aware costs from *actual routing decisions*: the
    /// dispatch byte matrix comes from `rt.a2a_bytes_placed(placement,
    /// token_bytes)` and the combine matrix is its transpose, so expert
    /// placement (block, affinity-packed, skewed) directly shapes the
    /// per-device intra-node and per-node inter-node phase times —
    /// including asymmetric dispatch vs. combine phases under skewed
    /// layouts. A placement that keeps every route node-local yields
    /// inter-node phases of exactly zero. The same routing × placement
    /// also yields the per-device [`ExpertLoad`] that stretches hot
    /// devices' Expert durations ([`CostModel::expert_time`]).
    ///
    /// Phases are normalized to k = 1 volume by dividing the routed phase
    /// times (which already include all `rt.k` route copies) by `rt.k`, so
    /// schedule builders that scale by `MoEKind::routed_k()` reproduce the
    /// full routed volume when the kind's k matches the table's.
    pub fn from_routing(base: &ComputeCosts, topo: &Topology,
                        rt: &RoutingTable, placement: &Placement,
                        token_bytes: usize) -> TopoCosts {
        TopoCosts::from_routing_with_sources(base, topo, rt, placement,
                                             token_bytes, None)
    }

    /// [`Self::from_routing`] with an explicit per-token *source device*
    /// map: `sources[t]` is the device holding token `t`'s activations
    /// when this layer's dispatch fires
    /// (`RoutingTable::a2a_bytes_from_sources`). The model composition
    /// layer passes the previous layer's landing devices here, so a
    /// layer's A2A volume depends on where the *previous* placement put
    /// each token's expert (the ExFlow execution model). `None` keeps
    /// the even index-order split bit-exactly — including through the
    /// token-true [`ChunkSource`], which records the map for per-chunk
    /// re-decomposition.
    pub fn from_routing_with_sources(base: &ComputeCosts, topo: &Topology,
                                     rt: &RoutingTable,
                                     placement: &Placement,
                                     token_bytes: usize,
                                     sources: Option<&[usize]>) -> TopoCosts {
        topo.assert_valid();
        assert_eq!(placement.n_devices, topo.n_devices,
                   "placement must cover the topology's device fleet");
        let disp = match sources {
            Some(s) => rt.a2a_bytes_from_sources(s, placement, token_bytes),
            None => rt.a2a_bytes_placed(placement, token_bytes),
        };
        let comb = a2a_transpose(&disp, topo.n_devices);
        let links = topo.intra_links();
        let pd = a2a_decompose_per_node(&disp, topo.n_devices,
                                        topo.devices_per_node,
                                        &links, topo.inter);
        let pc = a2a_decompose_per_node(&comb, topo.n_devices,
                                        topo.devices_per_node,
                                        &links, topo.inter);
        let kf = rt.k.max(1) as f64;
        let scale = |v: Vec<f64>| -> Vec<f64> {
            v.into_iter().map(|x| x / kf).collect()
        };
        let (td, ad) = a2a_time_split_per_node(&disp, topo.n_devices,
                                               topo.devices_per_node,
                                               &links, topo.inter);
        let (tcm, acm) = a2a_time_split_per_node(&comb, topo.n_devices,
                                                 topo.devices_per_node,
                                                 &links, topo.inter);
        let (flat, flat_alpha) = if tcm > td {
            (tcm / kf, acm / kf)
        } else {
            (td / kf, ad / kf)
        };
        let per_device = (0..topo.n_devices)
            .map(|d| base.scaled(topo.device_compute_scale(d), flat, flat_alpha))
            .collect();
        TopoCosts {
            per_device,
            a2a_intra_k1: scale(pd.intra),
            a2a_inter_k1: scale(pd.inter),
            a2a_intra_combine_k1: scale(pc.intra),
            a2a_inter_combine_k1: scale(pc.inter),
            a2a_intra_alpha_k1: scale(pd.intra_alpha),
            a2a_inter_alpha_k1: scale(pd.inter_alpha),
            a2a_intra_combine_alpha_k1: scale(pc.intra_alpha),
            a2a_inter_combine_alpha_k1: scale(pc.inter_alpha),
            chunk_source: Some(ChunkSource {
                rt: rt.clone(),
                placement: placement.clone(),
                token_bytes,
                intra_links: links,
                inter: topo.inter,
                sources: sources.map(|s| s.to_vec()),
            }),
            expert_load: Some(ExpertLoad::from_routing(rt, placement)),
            devices_per_node: topo.devices_per_node,
        }
    }
}

impl CostModel for TopoCosts {
    // geometry delegates to the inherent methods (one source of truth for
    // the contiguous-block node layout)
    fn n_devices(&self) -> usize {
        TopoCosts::n_devices(self)
    }

    fn devices_per_node(&self) -> usize {
        self.devices_per_node
    }

    fn n_links(&self) -> usize {
        self.a2a_inter_k1.len()
    }

    fn n_nodes(&self) -> usize {
        TopoCosts::n_nodes(self)
    }

    fn node_of(&self, device: usize) -> usize {
        TopoCosts::node_of(self, device)
    }

    fn devices_of(&self, node: usize) -> std::ops::Range<usize> {
        TopoCosts::devices_of(self, node)
    }

    fn device(&self, d: usize) -> &BlockCosts {
        &self.per_device[d]
    }

    /// Phase queries over the stored per-`k = 1` vectors. Empty combine
    /// vectors mirror dispatch (symmetric traffic), keeping
    /// uniform-routing schedules bit-exact with the pre-routed model.
    fn phase(&self, dir: PhaseDir, scope: PhaseScope, idx: usize, k: usize) -> f64 {
        match (dir, scope) {
            (PhaseDir::Dispatch, PhaseScope::Intra) => {
                self.a2a_intra_k1[idx] * k as f64
            }
            (PhaseDir::Dispatch, PhaseScope::Inter) => {
                self.a2a_inter_k1[idx] * k as f64
            }
            (PhaseDir::Combine, PhaseScope::Intra) => {
                if self.a2a_intra_combine_k1.is_empty() {
                    self.phase(PhaseDir::Dispatch, PhaseScope::Intra, idx, k)
                } else {
                    self.a2a_intra_combine_k1[idx] * k as f64
                }
            }
            (PhaseDir::Combine, PhaseScope::Inter) => {
                if self.a2a_inter_combine_k1.is_empty() {
                    self.phase(PhaseDir::Dispatch, PhaseScope::Inter, idx, k)
                } else {
                    self.a2a_inter_combine_k1[idx] * k as f64
                }
            }
        }
    }

    /// α queries with the matching fallbacks: empty dispatch α vectors
    /// model latency-free links (zero); empty combine α vectors mirror
    /// the dispatch α.
    fn phase_alpha(&self, dir: PhaseDir, scope: PhaseScope, idx: usize,
                   k: usize) -> f64 {
        match (dir, scope) {
            (PhaseDir::Dispatch, PhaseScope::Intra) => {
                if self.a2a_intra_alpha_k1.is_empty() {
                    0.0
                } else {
                    self.a2a_intra_alpha_k1[idx] * k as f64
                }
            }
            (PhaseDir::Dispatch, PhaseScope::Inter) => {
                if self.a2a_inter_alpha_k1.is_empty() {
                    0.0
                } else {
                    self.a2a_inter_alpha_k1[idx] * k as f64
                }
            }
            (PhaseDir::Combine, PhaseScope::Intra) => {
                if self.a2a_intra_combine_alpha_k1.is_empty() {
                    self.phase_alpha(PhaseDir::Dispatch, PhaseScope::Intra,
                                     idx, k)
                } else {
                    self.a2a_intra_combine_alpha_k1[idx] * k as f64
                }
            }
            (PhaseDir::Combine, PhaseScope::Inter) => {
                if self.a2a_inter_combine_alpha_k1.is_empty() {
                    self.phase_alpha(PhaseDir::Dispatch, PhaseScope::Inter,
                                     idx, k)
                } else {
                    self.a2a_inter_combine_alpha_k1[idx] * k as f64
                }
            }
        }
    }

    /// Load-scaled expert time: the balanced capacity batch stretched by
    /// device `d`'s share of the routed load (`load_d / mean`). Balanced
    /// loads multiply by exactly 1.0, so the pre-load model is reproduced
    /// bit-exactly; a device owning no experts computes for 0 seconds.
    fn expert_time(&self, d: usize, k: usize) -> f64 {
        let base = self.per_device[d].expert(k);
        match &self.expert_load {
            Some(load) => base * load.scale(d),
            None => base,
        }
    }

    /// Per-chunk, per-link phase + expert durations for a `chunks`-way
    /// pipelined All-to-All at k routed experts.
    ///
    /// With a [`ChunkSource`] (routed costs) the split is *token-true*:
    /// the routing table is divided into contiguous token ranges, each
    /// range's routed byte matrix is decomposed through the stored link
    /// models, and every chunk pays α only toward destinations it
    /// actually sends to — skewed routing therefore skews per-chunk
    /// traffic. A routed [`ExpertLoad`] additionally makes the per-chunk
    /// expert durations token-true (each chunk costs its own kept copies,
    /// so the chunk durations partition [`CostModel::expert_time`]).
    /// Without a source the split is *α-true analytic*: every chunk pays
    /// the stored phase's full α plus its `1/chunks` byte share
    /// ([`cluster::a2a_chunk_time`]) and an even expert split; with empty
    /// α vectors this reduces bit-exactly to the seed's plain division.
    ///
    /// [`cluster::a2a_chunk_time`]: crate::cluster::a2a_chunk_time
    fn chunk_phases(&self, k: usize, chunks: usize) -> ChunkedA2a {
        assert!(chunks >= 1);
        let n = self.n_devices();
        let n_links = self.a2a_inter_k1.len();
        let fc = chunks as f64;
        if let Some(src) = &self.chunk_source {
            let kf = src.rt.k.max(1) as f64;
            let scale = k as f64 / kf;
            let load = self.expert_load.as_ref().filter(|l| l.total > 0);
            let mut out = ChunkedA2a {
                disp_intra: Vec::with_capacity(chunks),
                disp_inter: Vec::with_capacity(chunks),
                comb_intra: Vec::with_capacity(chunks),
                comb_inter: Vec::with_capacity(chunks),
                expert: Vec::with_capacity(chunks),
            };
            for part in src.rt.chunk(chunks) {
                let disp = match &src.sources {
                    Some(s) => part.a2a_bytes_from_sources(
                        s, &src.placement, src.token_bytes),
                    None => part.a2a_bytes_placed(&src.placement,
                                                  src.token_bytes),
                };
                let comb = a2a_transpose(&disp, n);
                let pd = a2a_decompose_per_node(&disp, n,
                                                self.devices_per_node,
                                                &src.intra_links, src.inter);
                let pc = a2a_decompose_per_node(&comb, n,
                                                self.devices_per_node,
                                                &src.intra_links, src.inter);
                out.disp_intra.push(pd.intra.iter().map(|t| t * scale).collect());
                out.disp_inter.push(pd.inter.iter().map(|t| t * scale).collect());
                out.comb_intra.push(pc.intra.iter().map(|t| t * scale).collect());
                out.comb_inter.push(pc.inter.iter().map(|t| t * scale).collect());
                let ex_row: Vec<f64> = match load {
                    Some(load) => {
                        // token-true: charge each device this chunk's own
                        // kept copies relative to the fleet-wide balanced
                        // mean (the PARENT total, so the chunk durations
                        // partition the unchunked expert time)
                        let pl = ExpertLoad::from_routing(&part,
                                                          &src.placement);
                        (0..n)
                            .map(|d| {
                                let s = pl.per_device[d] as f64 * n as f64
                                    / load.total as f64;
                                self.per_device[d].expert(k) * s
                            })
                            .collect()
                    }
                    None => (0..n).map(|d| self.expert_time(d, k) / fc).collect(),
                };
                out.expert.push(ex_row);
            }
            out
        } else {
            let di: Vec<f64> = (0..n)
                .map(|d| a2a_chunk_time(
                    self.phase(PhaseDir::Dispatch, PhaseScope::Intra, d, k),
                    self.phase_alpha(PhaseDir::Dispatch, PhaseScope::Intra, d, k),
                    chunks))
                .collect();
            let dx: Vec<f64> = (0..n_links)
                .map(|nd| a2a_chunk_time(
                    self.phase(PhaseDir::Dispatch, PhaseScope::Inter, nd, k),
                    self.phase_alpha(PhaseDir::Dispatch, PhaseScope::Inter, nd, k),
                    chunks))
                .collect();
            let ci: Vec<f64> = (0..n)
                .map(|d| a2a_chunk_time(
                    self.phase(PhaseDir::Combine, PhaseScope::Intra, d, k),
                    self.phase_alpha(PhaseDir::Combine, PhaseScope::Intra, d, k),
                    chunks))
                .collect();
            let cx: Vec<f64> = (0..n_links)
                .map(|nd| a2a_chunk_time(
                    self.phase(PhaseDir::Combine, PhaseScope::Inter, nd, k),
                    self.phase_alpha(PhaseDir::Combine, PhaseScope::Inter, nd, k),
                    chunks))
                .collect();
            let ex: Vec<f64> =
                (0..n).map(|d| self.expert_time(d, k) / fc).collect();
            ChunkedA2a {
                disp_intra: vec![di; chunks],
                disp_inter: vec![dx; chunks],
                comb_intra: vec![ci; chunks],
                comb_inter: vec![cx; chunks],
                expert: vec![ex; chunks],
            }
        }
    }

    fn validate(&self) {
        self.assert_valid();
    }
}

impl CostModel for BlockCosts {
    fn n_devices(&self) -> usize {
        1
    }

    fn devices_per_node(&self) -> usize {
        1
    }

    fn n_links(&self) -> usize {
        0
    }

    fn device(&self, _d: usize) -> &BlockCosts {
        self
    }

    /// The single intra phase carries the whole scalar one-way time in
    /// both directions (the flat model has no routed asymmetry); there is
    /// no inter-node resource, so `Inter` is never queried.
    fn phase(&self, _dir: PhaseDir, _scope: PhaseScope, _idx: usize,
             k: usize) -> f64 {
        self.a2a(k)
    }

    fn phase_alpha(&self, _dir: PhaseDir, _scope: PhaseScope, _idx: usize,
                   k: usize) -> f64 {
        self.a2a_alpha(k)
    }

    fn expert_time(&self, _d: usize, k: usize) -> f64 {
        self.expert(k)
    }

    fn chunk_phases(&self, k: usize, chunks: usize) -> ChunkedA2a {
        assert!(chunks >= 1);
        let row = vec![self.a2a_chunk(k, chunks)];
        let ex = vec![self.expert(k) / chunks as f64];
        ChunkedA2a {
            disp_intra: vec![row.clone(); chunks],
            disp_inter: vec![Vec::new(); chunks],
            comb_intra: vec![row; chunks],
            comb_inter: vec![Vec::new(); chunks],
            expert: vec![ex; chunks],
        }
    }

    fn validate(&self) {}
}

/// Pure compute-op durations on the baseline device (A30 scale = 1.0).
/// Produced by the calibration harness (`scmoe bench-calib`) from real CPU
/// measurements of the AOT operator artifacts, then scaled to GPU-class
/// throughput ratios; or taken from the built-in proxy preset.
#[derive(Debug, Clone)]
pub struct ComputeCosts {
    pub attn: f64,
    pub mlp: f64,
    pub se: f64,
    pub gate: f64,
    pub encode: f64,
    pub decode: f64,
    pub expert_k1: f64,
}

impl ComputeCosts {
    /// Divide every op duration by a device compute speed and attach a
    /// flat one-way All-to-All time plus its launch-latency component —
    /// the one place op scaling happens, shared by the legacy and
    /// topology-aware cost constructors.
    pub fn scaled(&self, compute_scale: f64, a2a_k1: f64,
                  a2a_alpha_k1: f64) -> BlockCosts {
        let s = compute_scale;
        BlockCosts {
            attn: self.attn / s,
            mlp: self.mlp / s,
            se: self.se / s,
            gate: self.gate / s,
            encode: self.encode / s,
            decode: self.decode / s,
            expert_k1: self.expert_k1 / s,
            a2a_k1,
            a2a_alpha_k1,
        }
    }

    /// SwinV2-MoE-S block proxy (paper Fig. 1/8 shapes): ratios measured
    /// from the ops_tiny artifacts on CPU (see EXPERIMENTS.md §Calibration),
    /// absolute scale normalized so attn ≈ 1 ms on the A30 baseline.
    pub fn swin_proxy() -> ComputeCosts {
        ComputeCosts {
            attn: 1.00e-3,
            mlp: 0.75e-3,
            se: 0.75e-3,
            gate: 0.06e-3,
            encode: 0.05e-3,
            decode: 0.05e-3,
            expert_k1: 0.80e-3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Scenario;

    #[test]
    fn comm_fraction_matches_paper_bands() {
        // Fig. 1: top-2 comm share ≈ 60% on PCIe, ≈ 15% on NVLink,
        // ≈ 50% across 2 nodes. The presets must land in those bands.
        let base = ComputeCosts::swin_proxy();
        let costs = |sc: Scenario| {
            let t = sc.topology();
            BlockCosts::from_topology(&base, &t, 4096, 384, 1.25)
        };
        let f_pcie = costs(Scenario::PcieA30x8).comm_fraction(2);
        let f_nv = costs(Scenario::NvlinkA800x8).comm_fraction(2);
        let f_2n = costs(Scenario::TwoNodeA800x16).comm_fraction(2);
        assert!((0.50..0.70).contains(&f_pcie), "pcie comm frac {f_pcie}");
        assert!((0.08..0.25).contains(&f_nv), "nvlink comm frac {f_nv}");
        assert!((0.35..0.60).contains(&f_2n), "2node comm frac {f_2n}");
    }

    #[test]
    fn expert_and_a2a_scale_with_k() {
        let c = BlockCosts {
            attn: 1.0, mlp: 1.0, se: 1.0, gate: 0.1, encode: 0.1,
            decode: 0.1, expert_k1: 0.5, a2a_k1: 0.3, a2a_alpha_k1: 0.05,
        };
        assert_eq!(c.expert(2), 1.0);
        assert_eq!(c.a2a(3), 0.3 * 3.0);
        assert_eq!(c.a2a_alpha(2), 0.1);
        // chunks = 1 is the identity; chunks > 1 keep α whole
        assert_eq!(c.a2a_chunk(2, 1), c.a2a(2));
        assert!((c.a2a_chunk(2, 2) - (0.1 + 0.5 / 2.0)).abs() < 1e-15);
    }

    #[test]
    fn topo_from_block_is_exact_single_device_view() {
        let c = BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: 0.37, a2a_alpha_k1: 0.02,
        };
        let tc = TopoCosts::from_block(&c);
        assert_eq!(tc.n_devices(), 1);
        assert_eq!(tc.n_nodes(), 1);
        assert!(tc.a2a_inter_k1.is_empty());
        // bit-exact, same expression on both back ends
        assert_eq!(tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2),
                   c.a2a(2));
        assert_eq!(tc.phase_alpha(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2),
                   c.a2a_alpha(2));
        assert_eq!(tc.per_device[0].attn, c.attn);
    }

    #[test]
    fn uniform_bytes_per_pair_rounds_fractional_bytes() {
        // 50 tokens over 3 devices at 1 byte: 16.666… bytes per pair must
        // round to 17, not truncate to 16 (regression: `as usize` lost the
        // fraction on every non-divisible tokens/devices split).
        let topo = Topology {
            n_devices: 3,
            devices_per_node: 3,
            intra: LinkModel::new(0.0, 1e9),
            inter: None,
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        assert_eq!(uniform_bytes_per_pair(&topo, 50, 1, 1.0), 17);
        // divisible splits are untouched
        assert_eq!(uniform_bytes_per_pair(&topo, 48, 384, 1.0), 16 * 384);
    }

    #[test]
    #[should_panic(expected = "divide into nodes")]
    fn ragged_fleet_fails_validation() {
        let c = BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: 0.3, a2a_alpha_k1: 0.0,
        };
        let tc = TopoCosts {
            per_device: vec![c; 3],
            a2a_intra_k1: vec![0.1; 3],
            a2a_inter_k1: vec![0.2; 2],
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: Vec::new(),
            a2a_inter_alpha_k1: Vec::new(),
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            expert_load: None,
            devices_per_node: 2,
        };
        tc.assert_valid();
    }

    #[test]
    fn analytic_chunk_phases_pay_alpha_per_chunk() {
        let base = ComputeCosts::swin_proxy();
        let tc = TopoCosts::from_topology(
            &base, &Scenario::FourNodeA800IBx32.topology(), 4096, 384, 1.25);
        assert!(tc.chunk_source.is_none(), "uniform costs chunk analytically");
        for chunks in [2usize, 4, 8] {
            let ca = tc.chunk_phases(2, chunks);
            for d in 0..tc.n_devices() {
                let total: f64 = (0..chunks).map(|i| ca.disp_intra[i][d]).sum();
                let expect =
                    tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, d, 2)
                    + (chunks - 1) as f64
                        * tc.phase_alpha(PhaseDir::Dispatch, PhaseScope::Intra,
                                         d, 2);
                assert!((total - expect).abs() < 1e-12,
                        "device {d} x{chunks}: {total} vs {expect}");
            }
            for nd in 0..tc.a2a_inter_k1.len() {
                let total: f64 = (0..chunks).map(|i| ca.disp_inter[i][nd]).sum();
                let expect =
                    tc.phase(PhaseDir::Dispatch, PhaseScope::Inter, nd, 2)
                    + (chunks - 1) as f64
                        * tc.phase_alpha(PhaseDir::Dispatch, PhaseScope::Inter,
                                         nd, 2);
                assert!((total - expect).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn chunk_phases_with_zero_alpha_reduce_to_plain_division() {
        let c = BlockCosts {
            attn: 1.0, mlp: 0.75, se: 0.75, gate: 0.0625, encode: 0.0625,
            decode: 0.0625, expert_k1: 0.5, a2a_k1: 0.8125, a2a_alpha_k1: 0.0,
        };
        let mut tc = TopoCosts::from_block(&c);
        tc.a2a_intra_alpha_k1 = Vec::new(); // seed-style: no α information
        let ca = tc.chunk_phases(2, 2);
        assert_eq!(ca.disp_intra[0][0],
                   tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2) / 2.0);
        assert_eq!(ca.comb_intra[1][0],
                   tc.phase(PhaseDir::Combine, PhaseScope::Intra, 0, 2) / 2.0);
    }

    #[test]
    fn routed_chunk_phases_are_token_true() {
        use crate::moe::{Placement, RoutingTable};
        // 8 tokens on 2 devices (1 node each): the first 4 (device 0) all
        // route to device 1's expert, the last 4 stay local. Chunking in
        // half must put ALL cross-node traffic in chunk 0 and none in
        // chunk 1 — dividing phases evenly would put half in each.
        let idx = vec![1i32, 1, 1, 1, 1, 1, 1, 1];
        let w = vec![1.0f32; 8];
        let rt = RoutingTable::build(&idx, &w, 8, 1, 2, 8);
        let topo = Topology {
            n_devices: 2,
            devices_per_node: 1,
            intra: LinkModel::new(0.0, 1e9),
            inter: Some(LinkModel::new(1e-3, 1e6)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &Placement::new(2, 2), 1000);
        assert!(tc.chunk_source.is_some());
        let ca = tc.chunk_phases(1, 2);
        // chunk 0: node 0 sends 4 x 1000 B cross + pays α once
        assert!((ca.disp_inter[0][0] - (1e-3 + 4000.0 / 1e6)).abs() < 1e-15);
        // chunk 1: device 1's tokens route to its own expert - silence
        assert_eq!(ca.disp_inter[1][0], 0.0);
        assert_eq!(ca.disp_inter[1][1], 0.0);
        // combine mirrors: chunk 0's return traffic crosses from node 1
        assert!((ca.comb_inter[0][1] - (1e-3 + 4000.0 / 1e6)).abs() < 1e-15);
        assert_eq!(ca.comb_inter[1][1], 0.0);
    }

    #[test]
    fn topo_from_topology_scales_hetero_devices() {
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::HeteroA800A30x8.topology();
        let tc = TopoCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert_eq!(tc.n_devices(), 8);
        assert_eq!(tc.n_nodes(), 2);
        assert_eq!(tc.a2a_inter_k1.len(), 2);
        // A30 node (devices 4..8) is 1.9x slower on compute ops
        let fast = tc.per_device[0].attn;
        let slow = tc.per_device[7].attn;
        assert!((slow / fast - 1.9).abs() < 1e-12, "ratio {}", slow / fast);
    }

    #[test]
    fn legacy_hetero_costs_use_the_straggler_scale() {
        // single-representative-device view of the mixed fleet must model
        // the A30 stragglers (scale 1.0), not the A800s
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::HeteroA800A30x8.topology();
        let c = BlockCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert_eq!(c.attn, base.attn);
        assert_eq!(c.expert_k1, base.expert_k1);
    }

    #[test]
    fn topo_single_node_has_no_inter_phase() {
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::NvlinkA800x8.topology();
        let tc = TopoCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert!(tc.a2a_inter_k1.is_empty());
        assert_eq!(tc.a2a_intra_k1.len(), 8);
        // flat bound equals the per-device phase on a uniform single node
        assert!((tc.a2a_intra_k1[0] - tc.per_device[0].a2a_k1).abs() < 1e-15);
    }

    #[test]
    fn uniform_costs_leave_combine_symmetric() {
        let base = ComputeCosts::swin_proxy();
        for sc in Scenario::extended() {
            let tc = TopoCosts::from_topology(&base, &sc.topology(), 4096, 384, 1.25);
            assert!(tc.a2a_intra_combine_k1.is_empty());
            assert!(tc.a2a_inter_combine_k1.is_empty());
            // the combine queries mirror dispatch bit-exactly
            for d in 0..tc.n_devices() {
                assert_eq!(tc.phase(PhaseDir::Combine, PhaseScope::Intra, d, 2),
                           tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, d, 2));
            }
            for n in 0..tc.a2a_inter_k1.len() {
                assert_eq!(tc.phase(PhaseDir::Combine, PhaseScope::Inter, n, 2),
                           tc.phase(PhaseDir::Dispatch, PhaseScope::Inter, n, 2));
            }
        }
    }

    #[test]
    fn routed_costs_fill_combine_phases() {
        use crate::moe::{Placement, RoutingTable};
        // 4 tokens on 4 devices, each routed to the "next" device's expert:
        // the byte matrix is a rotation (not symmetric), so dispatch and
        // combine phases genuinely differ per device, yet every phase pair
        // is derived from the same transposed volume.
        let idx = vec![1i32, 2, 3, 0];
        let w = vec![1.0f32; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let topo = Scenario::HeteroA800A30x8.topology();
        // shrink to a 4-device view of the hetero fleet for the test
        let topo = crate::cluster::Topology {
            n_devices: 4,
            devices_per_node: 2,
            device_scales: None,
            node_intra: None,
            ..topo
        };
        let p = Placement::new(4, 4);
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &p, 1024);
        tc.assert_valid();
        assert_eq!(tc.a2a_intra_combine_k1.len(), 4);
        assert_eq!(tc.a2a_inter_combine_k1.len(), 2);
        // rotation: device d sends to d+1; device 1 sends to node 1 (cross)
        // while device 2 receives from node 0 — dispatch and combine phase
        // sums must both account for exactly the cross volume
        let cross_d: f64 = tc.a2a_inter_k1.iter().sum();
        let cross_c: f64 = tc.a2a_inter_combine_k1.iter().sum();
        assert!(cross_d > 0.0 && cross_c > 0.0);
    }

    #[test]
    fn routed_costs_normalize_per_k() {
        use crate::moe::{Placement, RoutingTable};
        // k = 2: every token routes to experts 0 and 1 (devices 0 and 1)
        let idx = vec![0i32, 1, 0, 1];
        let w = vec![0.5f32; 4];
        let rt = RoutingTable::build(&idx, &w, 2, 2, 2, 4);
        let topo = crate::cluster::Topology {
            n_devices: 2,
            devices_per_node: 2,
            intra: crate::cluster::LinkModel::new(0.0, 1e9),
            inter: None,
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &Placement::new(2, 2), 1000);
        // device 0 dispatches its token's remote copy (1000 B) once per k;
        // normalized per k then rescaled by k = 2 gives the full volume
        assert!((tc.phase(PhaseDir::Dispatch, PhaseScope::Intra, 0, 2)
                 - 1000.0 / 1e9).abs() < 1e-15);
    }

    #[test]
    fn explicit_home_sources_reduce_to_from_routing() {
        use crate::moe::{Placement, RoutingTable};
        use crate::coordinator::spec::ScheduleSpec;
        let idx = vec![0i32, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let w = vec![1.0f32; 16];
        let rt = RoutingTable::build(&idx, &w, 16, 1, 4, 16);
        let topo = Topology {
            n_devices: 4,
            devices_per_node: 2,
            intra: LinkModel::new(0.0625, 1024.0),
            inter: Some(LinkModel::new(0.125, 512.0)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let p = Placement::new(4, 4);
        let base = ComputeCosts::swin_proxy();
        let tpd = rt.n_tokens.div_ceil(4);
        let home: Vec<usize> =
            (0..rt.n_tokens).map(|t| (t / tpd).min(3)).collect();
        let a = TopoCosts::from_routing(&base, &topo, &rt, &p, 64);
        let b = TopoCosts::from_routing_with_sources(&base, &topo, &rt, &p,
                                                     64, Some(&home));
        // the stored phase vectors and a chunked build (exercising the
        // ChunkSource path) must agree bit-exactly
        assert_eq!(a.a2a_intra_k1, b.a2a_intra_k1);
        assert_eq!(a.a2a_inter_k1, b.a2a_inter_k1);
        assert_eq!(a.a2a_intra_combine_k1, b.a2a_intra_combine_k1);
        assert_eq!(a.a2a_inter_combine_k1, b.a2a_inter_combine_k1);
        let spec = ScheduleSpec::new(MoEKind::ScMoE { k: 1 },
                                     Strategy::Pipelined { chunks: 2 });
        assert_eq!(spec.build(&a).makespan(), spec.build(&b).makespan());
    }

    #[test]
    fn chained_sources_reshape_the_dispatch_rows() {
        use crate::moe::{Placement, RoutingTable};
        // every token sits on device 3 (a previous layer concentrated
        // them there): all dispatch must leave node 1, none from node 0
        let idx = vec![0i32, 1, 2, 3];
        let w = vec![1.0f32; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let topo = Topology {
            n_devices: 4,
            devices_per_node: 2,
            intra: LinkModel::new(0.0, 1e9),
            inter: Some(LinkModel::new(0.0, 1e6)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let base = ComputeCosts::swin_proxy();
        let tc = TopoCosts::from_routing_with_sources(
            &base, &topo, &rt, &Placement::new(4, 4), 1000, Some(&[3; 4]));
        // node 1 sends tokens 0/1 across (2000 B over 1e6 B/s)
        assert!((tc.a2a_inter_k1[1] - 2000.0 / 1e6).abs() < 1e-15);
        assert_eq!(tc.a2a_inter_k1[0], 0.0);
    }

    #[test]
    fn routed_costs_carry_expert_load_and_scale_expert_time() {
        use crate::moe::{Placement, RoutingTable};
        // all 8 tokens route to device 1's expert: device 0 computes
        // nothing, device 1 carries twice the balanced mean
        let idx = vec![1i32, 1, 1, 1, 1, 1, 1, 1];
        let w = vec![1.0f32; 8];
        let rt = RoutingTable::build(&idx, &w, 8, 1, 2, 8);
        let topo = Topology {
            n_devices: 2,
            devices_per_node: 1,
            intra: LinkModel::new(0.0, 1e9),
            inter: Some(LinkModel::new(1e-3, 1e6)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &Placement::new(2, 2), 1000);
        tc.assert_valid();
        let load = tc.expert_load.as_ref().unwrap();
        assert_eq!(load.per_device, vec![0, 8]);
        assert_eq!(tc.expert_time(0, 1), 0.0);
        assert_eq!(tc.expert_time(1, 1), tc.per_device[1].expert(1) * 2.0);
        // per-chunk expert durations are token-true and partition the
        // unchunked expert time (each chunk carries 4 of the 8 copies)
        let ca = tc.chunk_phases(1, 2);
        assert_eq!(ca.expert[0][0], 0.0);
        assert_eq!(ca.expert[0][1], tc.per_device[1].expert(1));
        assert_eq!(ca.expert[0][1] + ca.expert[1][1], tc.expert_time(1, 1));
    }
}
