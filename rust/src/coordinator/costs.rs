//! Operator durations and communication volumes for one
//! Block-MLP + Block-MoE pair — the inputs to every schedule builder.
//!
//! Two granularities coexist:
//!
//! - [`BlockCosts`] — the paper's single-representative-device model: one
//!   scalar one-way All-to-All time (`a2a_k1`) per routed-expert volume;
//! - [`TopoCosts`] — the topology-aware model: per-device operator
//!   durations (heterogeneous fleets run slower on some devices) plus a
//!   MoNTA-style per-link decomposition of each All-to-All into per-device
//!   intra-node and per-node inter-node phases, derived from topology +
//!   token counts instead of scalar constants.
//!
//! `TopoCosts::from_block` embeds a `BlockCosts` as the degenerate
//! one-modeled-device topology; schedules built from it reproduce the
//! legacy single-device schedules bit-exactly (property-tested in
//! `rust/tests/simtime_props.rs`).
//!
//! Communication volume likewise comes in two granularities:
//! [`TopoCosts::from_topology`] feeds the decomposition a *uniform* byte
//! matrix (every device pair exchanges the same volume), while
//! [`TopoCosts::from_routing`] derives the matrix from an actual
//! `moe::RoutingTable` and `moe::Placement`, so skewed routing or
//! ExFlow-style placements change the simulated per-link phase times —
//! including asymmetric dispatch vs. combine phases when the routed matrix
//! is not symmetric.

use crate::cluster::{
    a2a_decompose_per_node, a2a_time_per_node, a2a_transpose,
    uniform_a2a_bytes, Topology,
};
use crate::moe::{Placement, RoutingTable};

/// Which MoE architecture a schedule models (paper Fig. 6 / Fig. 8 rows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MoEKind {
    /// Standard top-k MoE (k = 1, 2, 3): MoE input is the current layer.
    Standard { k: usize },
    /// Shared-expert MoE: SE + top-1, current layer ("Top1+SE1").
    SharedExpert,
    /// ScMoE: SE on current layer + top-k on the *preceding* layer
    /// via the shortcut (k=1 default; k=2 is "ScMoE-2").
    ScMoE { k: usize },
}

impl MoEKind {
    /// Display label matching the paper's table rows ("Top2", "ScMoE", …).
    pub fn label(&self) -> String {
        match self {
            MoEKind::Standard { k } => format!("Top{k}"),
            MoEKind::SharedExpert => "Top1+SE1".into(),
            MoEKind::ScMoE { k } => {
                if *k == 1 { "ScMoE".into() } else { format!("ScMoE-{k}") }
            }
        }
    }

    /// Number of gate-selected experts routed through All-to-All.
    pub fn routed_k(&self) -> usize {
        match self {
            MoEKind::Standard { k } => *k,
            MoEKind::SharedExpert => 1,
            MoEKind::ScMoE { k } => *k,
        }
    }

    /// Whether the architecture adds a shared-expert MLP on the backbone.
    pub fn has_shared_expert(&self) -> bool {
        matches!(self, MoEKind::SharedExpert | MoEKind::ScMoE { .. })
    }
}

/// Execution strategy for the MoE stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Fully sequential (the naive baseline).
    Sequential,
    /// Tutel-style pipelining: tokens split into `chunks`; chunk i's expert
    /// compute overlaps chunk i+1's dispatch / chunk i-1's combine.
    Pipelined { chunks: usize },
    /// The paper's overlapping strategy (requires a shortcut architecture).
    Overlap,
    /// Overlap augmented with pipelining (Fig. 6, 5th timeline).
    OverlapPipelined { chunks: usize },
}

impl Strategy {
    /// Display label ("seq", "pipe2", "overlap", "overlap+pipe2", …).
    pub fn label(&self) -> String {
        match self {
            Strategy::Sequential => "seq".into(),
            Strategy::Pipelined { chunks } => format!("pipe{chunks}"),
            Strategy::Overlap => "overlap".into(),
            Strategy::OverlapPipelined { chunks } => format!("overlap+pipe{chunks}"),
        }
    }
}

/// Durations (seconds) of the operators in one Block-MLP/Block-MoE pair,
/// plus the communication volumes needed to derive A2A times.
#[derive(Debug, Clone)]
pub struct BlockCosts {
    /// Attention sub-layer (one per block; assumed equal across the pair).
    pub attn: f64,
    /// Dense MLP sub-layer of the Block-MLP.
    pub mlp: f64,
    /// Shared expert (an MLP on the current layer).
    pub se: f64,
    /// Gate routing (+ encode) per routed-expert set.
    pub gate: f64,
    /// Encode (layout aggregation before dispatch).
    pub encode: f64,
    /// Decode (inverse of encode, after combine).
    pub decode: f64,
    /// Expert FFN over one capacity batch with k routed experts.
    pub expert_k1: f64,
    /// One-way All-to-All time for k = 1 volume.
    pub a2a_k1: f64,
}

impl BlockCosts {
    /// Expert computation time for k routed experts (capacity ∝ k; linear —
    /// the conservative model, see EXPERIMENTS.md §Deviations for the
    /// effect on the paper's Table 4 top-3 row).
    pub fn expert(&self, k: usize) -> f64 {
        self.expert_k1 * k as f64
    }

    /// One-way All-to-All (dispatch or combine) for k routed experts.
    pub fn a2a(&self, k: usize) -> f64 {
        self.a2a_k1 * k as f64
    }

    /// Total MoE-path time under naive sequential execution (for the
    /// comm-fraction metrics of Fig. 1).
    pub fn moe_sequential(&self, k: usize) -> f64 {
        self.gate + self.encode + self.a2a(k) + self.expert(k) + self.a2a(k) + self.decode
    }

    /// Communication share of the sequential MoE path.
    pub fn comm_fraction(&self, k: usize) -> f64 {
        2.0 * self.a2a(k) / self.moe_sequential(k)
    }

    /// Build costs from compute-op durations measured on the A30-relative
    /// scale plus a topology (which supplies A2A time and compute scaling).
    /// On heterogeneous fleets the representative device is the slowest
    /// one (`Topology::min_compute_scale`): barrier collectives are gated
    /// by the stragglers, so a faster representative would understate the
    /// fleet makespan.
    pub fn from_topology(base: &ComputeCosts, topo: &Topology,
                         tokens_per_device: usize, token_bytes: usize,
                         capacity_factor: f64) -> BlockCosts {
        topo.assert_valid();
        let m = uniform_a2a_bytes(
            topo.n_devices,
            uniform_bytes_per_pair(topo, tokens_per_device, token_bytes,
                                   capacity_factor));
        let a2a_k1 = a2a_time_per_node(&m, topo.n_devices,
                                       topo.devices_per_node,
                                       &topo.intra_links(), topo.inter);
        base.scaled(topo.min_compute_scale(), a2a_k1)
    }
}

/// k=1 uniform-routing volume: each device dispatches its tokens' routed
/// copies; under uniform routing a (1 - 1/n) fraction crosses the link,
/// with `capacity_factor` headroom in buffer sizing. Shared by the legacy
/// and topology-aware cost constructors so the two models can never
/// disagree on communication volume.
fn uniform_bytes_per_pair(topo: &Topology, tokens_per_device: usize,
                          token_bytes: usize, capacity_factor: f64) -> usize {
    ((tokens_per_device as f64 * capacity_factor / topo.n_devices as f64)
        * token_bytes as f64) as usize
}

/// Topology-aware costs for one Block-MLP + Block-MoE pair across a
/// modeled device fleet (see the module docs for how this generalizes
/// [`BlockCosts`]).
#[derive(Debug, Clone)]
pub struct TopoCosts {
    /// Per modeled device: compute-op durations in seconds (already scaled
    /// by that device's compute speed) plus the flat one-way `a2a_k1` for
    /// reporting and the single-device reduction.
    pub per_device: Vec<BlockCosts>,
    /// Per-device one-way *dispatch* intra-node All-to-All phase (seconds)
    /// at k = 1 volume.
    pub a2a_intra_k1: Vec<f64>,
    /// Per-node one-way *dispatch* inter-node All-to-All phase (seconds)
    /// at k = 1 volume; empty for single-node (or single-modeled-device)
    /// topologies.
    pub a2a_inter_k1: Vec<f64>,
    /// Per-device *combine* intra-node phase (seconds) at k = 1 volume.
    /// Empty means the combine direction mirrors dispatch exactly (true
    /// for uniform routing, whose byte matrix is symmetric); routed
    /// constructors fill it from the transposed byte matrix.
    pub a2a_intra_combine_k1: Vec<f64>,
    /// Per-node *combine* inter-node phase (seconds) at k = 1 volume;
    /// empty under the same symmetric-fallback rule as
    /// `a2a_intra_combine_k1`.
    pub a2a_inter_combine_k1: Vec<f64>,
    /// Devices per node (contiguous block node layout).
    pub devices_per_node: usize,
}

impl TopoCosts {
    /// Number of modeled devices.
    pub fn n_devices(&self) -> usize {
        self.per_device.len()
    }

    /// Number of nodes covering the modeled devices.
    pub fn n_nodes(&self) -> usize {
        self.n_devices().div_ceil(self.devices_per_node)
    }

    /// Node owning a device (contiguous block layout).
    pub fn node_of(&self, device: usize) -> usize {
        device / self.devices_per_node
    }

    /// Devices belonging to a node (contiguous block layout).
    pub fn devices_of(&self, node: usize) -> std::ops::Range<usize> {
        let lo = node * self.devices_per_node;
        lo..(lo + self.devices_per_node).min(self.n_devices())
    }

    /// Validate internal consistency (the hand-construction twin of
    /// `Topology::assert_valid`): every device needs an intra phase, and
    /// the inter phases must cover every node or be absent entirely —
    /// the schedule builders size their `Link` task loops off
    /// `a2a_inter_k1.len()`, so a short vector would silently drop
    /// uplink tasks instead of failing.
    pub fn assert_valid(&self) {
        assert!(!self.per_device.is_empty(), "at least one modeled device");
        assert!(self.devices_per_node > 0);
        assert_eq!(self.a2a_intra_k1.len(), self.per_device.len(),
                   "one intra-node phase per device");
        assert!(self.a2a_inter_k1.is_empty()
                    || self.a2a_inter_k1.len() == self.n_nodes(),
                "inter-node phases must cover every node (or be empty)");
        assert!(self.a2a_intra_combine_k1.is_empty()
                    || self.a2a_intra_combine_k1.len() == self.per_device.len(),
                "combine intra phases must cover every device (or be empty)");
        assert!(self.a2a_inter_combine_k1.is_empty()
                    || self.a2a_inter_combine_k1.len() == self.a2a_inter_k1.len(),
                "combine inter phases must mirror the dispatch link set \
                 (or be empty)");
    }

    /// One-way *dispatch* intra-node phase (seconds) for device `d` at
    /// k routed experts.
    pub fn a2a_intra(&self, d: usize, k: usize) -> f64 {
        self.a2a_intra_k1[d] * k as f64
    }

    /// One-way *dispatch* inter-node phase (seconds) for node `n` at
    /// k routed experts.
    pub fn a2a_inter(&self, n: usize, k: usize) -> f64 {
        self.a2a_inter_k1[n] * k as f64
    }

    /// *Combine* intra-node phase (seconds) for device `d` at k routed
    /// experts; falls back to the dispatch phase when the combine vectors
    /// are empty (symmetric traffic), keeping uniform-routing schedules
    /// bit-exact with the pre-routed model.
    pub fn a2a_intra_combine(&self, d: usize, k: usize) -> f64 {
        if self.a2a_intra_combine_k1.is_empty() {
            self.a2a_intra(d, k)
        } else {
            self.a2a_intra_combine_k1[d] * k as f64
        }
    }

    /// *Combine* inter-node phase (seconds) for node `n` at k routed
    /// experts, with the same symmetric fallback as
    /// [`Self::a2a_intra_combine`].
    pub fn a2a_inter_combine(&self, n: usize, k: usize) -> f64 {
        if self.a2a_inter_combine_k1.is_empty() {
            self.a2a_inter(n, k)
        } else {
            self.a2a_inter_combine_k1[n] * k as f64
        }
    }

    /// Degenerate one-modeled-device view of legacy costs. Schedules built
    /// from this reduce bit-exactly to the legacy single-device schedules:
    /// the single intra phase carries the whole scalar `a2a_k1` and there
    /// is no inter-node resource.
    pub fn from_block(c: &BlockCosts) -> TopoCosts {
        TopoCosts {
            a2a_intra_k1: vec![c.a2a_k1],
            a2a_inter_k1: Vec::new(),
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            per_device: vec![c.clone()],
            devices_per_node: 1,
        }
    }

    /// Build topology-aware costs under *uniform* routing: per-device
    /// compute durations from the device's own compute scale, All-to-All
    /// phases from the uniform byte matrix decomposed per link
    /// (`cluster::a2a_decompose_per_node`). The uniform matrix is
    /// symmetric, so the combine vectors stay empty and combine phases
    /// mirror dispatch bit-exactly — this is the N-devices degenerate case
    /// of [`Self::from_routing`].
    pub fn from_topology(base: &ComputeCosts, topo: &Topology,
                         tokens_per_device: usize, token_bytes: usize,
                         capacity_factor: f64) -> TopoCosts {
        topo.assert_valid();
        let m = uniform_a2a_bytes(
            topo.n_devices,
            uniform_bytes_per_pair(topo, tokens_per_device, token_bytes,
                                   capacity_factor));
        let links = topo.intra_links();
        let phases = a2a_decompose_per_node(&m, topo.n_devices,
                                            topo.devices_per_node,
                                            &links, topo.inter);
        let flat = a2a_time_per_node(&m, topo.n_devices, topo.devices_per_node,
                                     &links, topo.inter);
        let per_device = (0..topo.n_devices)
            .map(|d| base.scaled(topo.device_compute_scale(d), flat))
            .collect();
        TopoCosts {
            per_device,
            a2a_intra_k1: phases.intra,
            a2a_inter_k1: phases.inter,
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            devices_per_node: topo.devices_per_node,
        }
    }

    /// Build topology-aware costs from *actual routing decisions*: the
    /// dispatch byte matrix comes from `rt.a2a_bytes_placed(placement,
    /// token_bytes)` and the combine matrix is its transpose, so expert
    /// placement (block, affinity-packed, skewed) directly shapes the
    /// per-device intra-node and per-node inter-node phase times —
    /// including asymmetric dispatch vs. combine phases under skewed
    /// layouts. A placement that keeps every route node-local yields
    /// inter-node phases of exactly zero.
    ///
    /// Phases are normalized to k = 1 volume by dividing the routed phase
    /// times (which already include all `rt.k` route copies) by `rt.k`, so
    /// schedule builders that scale by `MoEKind::routed_k()` reproduce the
    /// full routed volume when the kind's k matches the table's.
    pub fn from_routing(base: &ComputeCosts, topo: &Topology,
                        rt: &RoutingTable, placement: &Placement,
                        token_bytes: usize) -> TopoCosts {
        topo.assert_valid();
        assert_eq!(placement.n_devices, topo.n_devices,
                   "placement must cover the topology's device fleet");
        let disp = rt.a2a_bytes_placed(placement, token_bytes);
        let comb = a2a_transpose(&disp, topo.n_devices);
        let links = topo.intra_links();
        let pd = a2a_decompose_per_node(&disp, topo.n_devices,
                                        topo.devices_per_node,
                                        &links, topo.inter);
        let pc = a2a_decompose_per_node(&comb, topo.n_devices,
                                        topo.devices_per_node,
                                        &links, topo.inter);
        let kf = rt.k.max(1) as f64;
        let scale = |v: Vec<f64>| -> Vec<f64> {
            v.into_iter().map(|x| x / kf).collect()
        };
        let flat = a2a_time_per_node(&disp, topo.n_devices,
                                     topo.devices_per_node,
                                     &links, topo.inter)
            .max(a2a_time_per_node(&comb, topo.n_devices,
                                   topo.devices_per_node,
                                   &links, topo.inter))
            / kf;
        let per_device = (0..topo.n_devices)
            .map(|d| base.scaled(topo.device_compute_scale(d), flat))
            .collect();
        TopoCosts {
            per_device,
            a2a_intra_k1: scale(pd.intra),
            a2a_inter_k1: scale(pd.inter),
            a2a_intra_combine_k1: scale(pc.intra),
            a2a_inter_combine_k1: scale(pc.inter),
            devices_per_node: topo.devices_per_node,
        }
    }
}

/// Pure compute-op durations on the baseline device (A30 scale = 1.0).
/// Produced by the calibration harness (`scmoe bench-calib`) from real CPU
/// measurements of the AOT operator artifacts, then scaled to GPU-class
/// throughput ratios; or taken from the built-in proxy preset.
#[derive(Debug, Clone)]
pub struct ComputeCosts {
    pub attn: f64,
    pub mlp: f64,
    pub se: f64,
    pub gate: f64,
    pub encode: f64,
    pub decode: f64,
    pub expert_k1: f64,
}

impl ComputeCosts {
    /// Divide every op duration by a device compute speed and attach a
    /// flat one-way All-to-All time — the one place op scaling happens,
    /// shared by the legacy and topology-aware cost constructors.
    pub fn scaled(&self, compute_scale: f64, a2a_k1: f64) -> BlockCosts {
        let s = compute_scale;
        BlockCosts {
            attn: self.attn / s,
            mlp: self.mlp / s,
            se: self.se / s,
            gate: self.gate / s,
            encode: self.encode / s,
            decode: self.decode / s,
            expert_k1: self.expert_k1 / s,
            a2a_k1,
        }
    }

    /// SwinV2-MoE-S block proxy (paper Fig. 1/8 shapes): ratios measured
    /// from the ops_tiny artifacts on CPU (see EXPERIMENTS.md §Calibration),
    /// absolute scale normalized so attn ≈ 1 ms on the A30 baseline.
    pub fn swin_proxy() -> ComputeCosts {
        ComputeCosts {
            attn: 1.00e-3,
            mlp: 0.75e-3,
            se: 0.75e-3,
            gate: 0.06e-3,
            encode: 0.05e-3,
            decode: 0.05e-3,
            expert_k1: 0.80e-3,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Scenario;

    #[test]
    fn comm_fraction_matches_paper_bands() {
        // Fig. 1: top-2 comm share ≈ 60% on PCIe, ≈ 15% on NVLink,
        // ≈ 50% across 2 nodes. The presets must land in those bands.
        let base = ComputeCosts::swin_proxy();
        let costs = |sc: Scenario| {
            let t = sc.topology();
            BlockCosts::from_topology(&base, &t, 4096, 384, 1.25)
        };
        let f_pcie = costs(Scenario::PcieA30x8).comm_fraction(2);
        let f_nv = costs(Scenario::NvlinkA800x8).comm_fraction(2);
        let f_2n = costs(Scenario::TwoNodeA800x16).comm_fraction(2);
        assert!((0.50..0.70).contains(&f_pcie), "pcie comm frac {f_pcie}");
        assert!((0.08..0.25).contains(&f_nv), "nvlink comm frac {f_nv}");
        assert!((0.35..0.60).contains(&f_2n), "2node comm frac {f_2n}");
    }

    #[test]
    fn expert_and_a2a_scale_with_k() {
        let c = BlockCosts {
            attn: 1.0, mlp: 1.0, se: 1.0, gate: 0.1, encode: 0.1,
            decode: 0.1, expert_k1: 0.5, a2a_k1: 0.3,
        };
        assert_eq!(c.expert(2), 1.0);
        assert_eq!(c.a2a(3), 0.3 * 3.0);
    }

    #[test]
    fn topo_from_block_is_exact_single_device_view() {
        let c = BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: 0.37,
        };
        let tc = TopoCosts::from_block(&c);
        assert_eq!(tc.n_devices(), 1);
        assert_eq!(tc.n_nodes(), 1);
        assert!(tc.a2a_inter_k1.is_empty());
        assert_eq!(tc.a2a_intra(0, 2), c.a2a(2)); // bit-exact, same expression
        assert_eq!(tc.per_device[0].attn, c.attn);
    }

    #[test]
    fn topo_from_topology_scales_hetero_devices() {
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::HeteroA800A30x8.topology();
        let tc = TopoCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert_eq!(tc.n_devices(), 8);
        assert_eq!(tc.n_nodes(), 2);
        assert_eq!(tc.a2a_inter_k1.len(), 2);
        // A30 node (devices 4..8) is 1.9x slower on compute ops
        let fast = tc.per_device[0].attn;
        let slow = tc.per_device[7].attn;
        assert!((slow / fast - 1.9).abs() < 1e-12, "ratio {}", slow / fast);
    }

    #[test]
    fn legacy_hetero_costs_use_the_straggler_scale() {
        // single-representative-device view of the mixed fleet must model
        // the A30 stragglers (scale 1.0), not the A800s
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::HeteroA800A30x8.topology();
        let c = BlockCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert_eq!(c.attn, base.attn);
        assert_eq!(c.expert_k1, base.expert_k1);
    }

    #[test]
    fn topo_single_node_has_no_inter_phase() {
        let base = ComputeCosts::swin_proxy();
        let topo = Scenario::NvlinkA800x8.topology();
        let tc = TopoCosts::from_topology(&base, &topo, 4096, 384, 1.25);
        assert!(tc.a2a_inter_k1.is_empty());
        assert_eq!(tc.a2a_intra_k1.len(), 8);
        // flat bound equals the per-device phase on a uniform single node
        assert!((tc.a2a_intra_k1[0] - tc.per_device[0].a2a_k1).abs() < 1e-15);
    }

    #[test]
    fn uniform_costs_leave_combine_symmetric() {
        let base = ComputeCosts::swin_proxy();
        for sc in Scenario::extended() {
            let tc = TopoCosts::from_topology(&base, &sc.topology(), 4096, 384, 1.25);
            assert!(tc.a2a_intra_combine_k1.is_empty());
            assert!(tc.a2a_inter_combine_k1.is_empty());
            // the fallback accessors mirror dispatch bit-exactly
            for d in 0..tc.n_devices() {
                assert_eq!(tc.a2a_intra_combine(d, 2), tc.a2a_intra(d, 2));
            }
            for n in 0..tc.a2a_inter_k1.len() {
                assert_eq!(tc.a2a_inter_combine(n, 2), tc.a2a_inter(n, 2));
            }
        }
    }

    #[test]
    fn routed_costs_fill_combine_phases() {
        use crate::moe::{Placement, RoutingTable};
        // 4 tokens on 4 devices, each routed to the "next" device's expert:
        // the byte matrix is a rotation (not symmetric), so dispatch and
        // combine phases genuinely differ per device, yet every phase pair
        // is derived from the same transposed volume.
        let idx = vec![1i32, 2, 3, 0];
        let w = vec![1.0f32; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let topo = Scenario::HeteroA800A30x8.topology();
        // shrink to a 4-device view of the hetero fleet for the test
        let topo = crate::cluster::Topology {
            n_devices: 4,
            devices_per_node: 2,
            device_scales: None,
            node_intra: None,
            ..topo
        };
        let p = Placement::new(4, 4);
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &p, 1024);
        tc.assert_valid();
        assert_eq!(tc.a2a_intra_combine_k1.len(), 4);
        assert_eq!(tc.a2a_inter_combine_k1.len(), 2);
        // rotation: device d sends to d+1; device 1 sends to node 1 (cross)
        // while device 2 receives from node 0 — dispatch and combine phase
        // sums must both account for exactly the cross volume
        let cross_d: f64 = tc.a2a_inter_k1.iter().sum();
        let cross_c: f64 = tc.a2a_inter_combine_k1.iter().sum();
        assert!(cross_d > 0.0 && cross_c > 0.0);
    }

    #[test]
    fn routed_costs_normalize_per_k() {
        use crate::moe::{Placement, RoutingTable};
        // k = 2: every token routes to experts 0 and 1 (devices 0 and 1)
        let idx = vec![0i32, 1, 0, 1];
        let w = vec![0.5f32; 4];
        let rt = RoutingTable::build(&idx, &w, 2, 2, 2, 4);
        let topo = crate::cluster::Topology {
            n_devices: 2,
            devices_per_node: 2,
            intra: crate::cluster::LinkModel::new(0.0, 1e9),
            inter: None,
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        };
        let tc = TopoCosts::from_routing(&ComputeCosts::swin_proxy(), &topo,
                                         &rt, &Placement::new(2, 2), 1000);
        // device 0 dispatches its token's remote copy (1000 B) once per k;
        // normalized per k then rescaled by k = 2 gives the full volume
        assert!((tc.a2a_intra(0, 2) - 1000.0 / 1e9).abs() < 1e-15);
    }
}
