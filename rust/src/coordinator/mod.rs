//! The paper's system contribution: operator scheduling for expert
//! parallelism with shortcut-decoupled communication.
//!
//! - `costs`: per-operator durations (calibrated or preset) + comm volumes;
//! - `schedule`: task-graph builders for every architecture × strategy in
//!   Fig. 6 (sequential, Tutel-style pipelining, shared-expert, ScMoE
//!   overlapping, ScMoE + pipelining);
//! - `adaptive`: Eq. 11 — the adaptive placement of expert computation
//!   among the four candidate locations in the shared-expert stream;
//! - `timeline`: ASCII rendering of DES spans (regenerates Fig. 6);
//! - `exec`: real threaded execution of the same schedules against PJRT
//!   artifacts with injected link delays (validates the DES).

pub mod adaptive;
pub mod costs;
pub mod exec;
pub mod schedule;
pub mod timeline;

pub use adaptive::choose_expert_slot;
pub use costs::{BlockCosts, MoEKind, Strategy};
pub use schedule::{build_pair_schedule, PairSchedule};
