//! The paper's system contribution: operator scheduling for expert
//! parallelism with shortcut-decoupled communication.
//!
//! - `spec`: the construction API — a declarative [`ScheduleSpec`] (MoE
//!   kind × strategy × slot policy × chunk pipelining) built against any
//!   [`CostModel`] back end via `spec.build(&costs)`;
//! - `costs`: per-operator durations (calibrated or preset) + comm
//!   volumes, at two granularities — the single-representative-device
//!   `BlockCosts` and the topology-aware `TopoCosts` (per-device compute,
//!   per-link All-to-All phases derived from topology + token counts, or
//!   from actual `moe::RoutingTable` traffic under a `moe::Placement` via
//!   `TopoCosts::from_routing`, including the per-device `ExpertLoad`
//!   that stretches hot devices' expert compute) — both implementing
//!   [`CostModel`];
//! - `schedule`: the spec-driven task-graph builders for every
//!   architecture × strategy in Fig. 6 (sequential, Tutel-style
//!   pipelining, shared-expert, ScMoE overlapping, ScMoE + pipelining);
//!   one builder family serves both back ends;
//! - `adaptive`: Eq. 11 — the adaptive placement of expert computation
//!   among the four candidate locations in the shared-expert stream,
//!   including the fleet-level argmin over topology-aware simulations;
//! - `replace`: live re-placement — `MigrationPlan` (expert→device
//!   deltas priced as H2D DES tasks), `ReplacePolicy` (never / every-k /
//!   break-even) and `run_replace_timeline` composing per-step schedules
//!   with overlapped migrations into N-step makespans; plus the chaos
//!   variants `failover_placement` and `run_chaos_timeline` (per-step
//!   perturbed topologies, dropout recovery via forced failover);
//! - `model`: whole-model composition — [`ModelSpec`] embeds L per-layer
//!   pair graphs × M microbatches onto S pipeline stages under a
//!   [`PipelineSchedule`] (layer-sequential / GPipe / 1F1B) with chained
//!   inter-layer dispatch sources, and `run_model_timeline` drives the
//!   multi-step stream with per-layer or ExFlow-style cross-layer
//!   ([`PlacementMode`]) live re-placement;
//! - `timeline`: ASCII rendering of DES spans (regenerates Fig. 6);
//! - `exec`: real threaded execution of the same schedules against PJRT
//!   artifacts with injected link delays (validates the DES).

pub mod adaptive;
pub mod costs;
pub mod exec;
pub mod model;
pub mod replace;
pub mod schedule;
pub mod spec;
pub mod timeline;

pub use adaptive::{choose_expert_slot, choose_expert_slot_model,
                   choose_expert_slot_topo};
pub use costs::{BlockCosts, ChunkSource, ChunkedA2a, MoEKind, Strategy, TopoCosts};
pub use model::{build_model_sim, chained_sources, model_layer_costs,
                run_model_timeline, ModelConfig, ModelOutcome, ModelSpec,
                ModelStepReport, PipelineSchedule, PlacementMode};
pub use replace::{ExpertMove, MigrationPlan, ReplaceConfig, ReplaceOutcome,
                  ReplacePolicy, StepReport, failover_placement,
                  run_chaos_timeline, run_replace_timeline};
pub use schedule::{build_pair_schedule, build_pair_schedule_auto,
                   ChunkPipelining, PairSchedule};
pub use spec::{BuiltInto, CostModel, PhaseDir, PhaseScope, ScheduleSpec,
               SlotPolicy};
