//! Adaptive operator scheduling (§3.2): choose where the expert computation
//! sits among the four candidate locations ①–④ in the shared-expert stream.
//!
//! The paper schedules "based on actual performance metrics" — we implement
//! exactly that: run the DES for each candidate slot and pick the argmin.
//! Eq. 11's closed form plus the Eq. 12/13 bounds are provided for analysis
//! and are property-tested against the DES (rust/tests/coordinator_props.rs).

use super::costs::{BlockCosts, MoEKind, Strategy, TopoCosts};
use super::spec::{CostModel, ScheduleSpec};

/// Pick the expert slot minimizing the simulated makespan over any cost
/// back end ([`ScheduleSpec::choose_slot`] on an ad-hoc spec).
/// Returns (slot, makespan).
pub fn choose_expert_slot_model(cm: &dyn CostModel, kind: MoEKind,
                                strategy: Strategy) -> (usize, f64) {
    ScheduleSpec::new(kind, strategy).choose_slot(cm)
}

/// Single-device slot choice (the paper's §3.2 search on the
/// representative-device model). Returns (slot, makespan).
pub fn choose_expert_slot(c: &BlockCosts, kind: MoEKind,
                          strategy: Strategy) -> (usize, f64) {
    choose_expert_slot_model(c, kind, strategy)
}

/// Topology-aware slot choice: simulate the whole fleet per candidate slot
/// and pick the argmin of the fleet makespan. Different topologies (link
/// hierarchies, heterogeneous compute) legitimately prefer different
/// slots — that is the scenario diversity the multi-device DES buys.
pub fn choose_expert_slot_topo(tc: &TopoCosts, kind: MoEKind,
                               strategy: Strategy) -> (usize, f64) {
    choose_expert_slot_model(tc, kind, strategy)
}

/// Eq. 11 closed-form estimate of the *overhead-relevant* objective for a
/// given slot: |Σ_pre COMP − T_disp| + |Σ_post COMP − T_comb|.
pub fn eq11_objective(c: &BlockCosts, kind: MoEKind, slot: usize) -> f64 {
    let k = kind.routed_k();
    let window = [c.mlp, c.attn, c.se];
    let pre: f64 = window[..slot.min(3)].iter().sum();
    let post: f64 = window[slot.min(3)..].iter().sum();
    (pre - c.a2a(k)).abs() + (post - c.a2a(k)).abs()
}

/// Eq. 11: minimal objective over the four slots.
pub fn eq11_min(c: &BlockCosts, kind: MoEKind) -> f64 {
    (0..4).map(|s| eq11_objective(c, kind, s))
          .fold(f64::INFINITY, f64::min)
}

/// Eq. 12 lower bound on the exposed (non-overlapped) time:
/// |Σ COMP − (T_disp + T_comb)|.
pub fn eq12_lower_bound(c: &BlockCosts, kind: MoEKind) -> f64 {
    let k = kind.routed_k();
    let comp_total = c.mlp + c.attn + c.se;
    (comp_total - 2.0 * c.a2a(k)).abs()
}

/// Eq. 13 upper bound: Σ COMP + (T_disp + T_comb).
pub fn eq13_upper_bound(c: &BlockCosts, kind: MoEKind) -> f64 {
    let k = kind.routed_k();
    c.mlp + c.attn + c.se + 2.0 * c.a2a(k)
}

/// Fraction of one-way-comm time hidden by the overlap schedule, for the
/// paper's "70% to 100%" overlap claims (§1).
pub fn overlap_fraction(c: &BlockCosts, kind: MoEKind, strategy: Strategy) -> f64 {
    let (slot, overlapped) = choose_expert_slot(c, kind, strategy);
    let _ = slot;
    let k = kind.routed_k();
    // serial reference: same ops, comm fully exposed
    let serial = super::schedule::backbone_time(c, kind)
        + c.gate + c.encode + 2.0 * c.a2a(k) + c.expert(k) + c.decode;
    let comm = 2.0 * c.a2a(k);
    if comm <= 0.0 {
        return 1.0;
    }
    let exposed = (overlapped - (serial - comm)).max(0.0);
    (1.0 - exposed / comm).clamp(0.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::schedule::build_pair_schedule;

    fn costs(a2a: f64) -> BlockCosts {
        BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: a2a,
            a2a_alpha_k1: 0.0,
        }
    }

    #[test]
    fn balanced_comm_prefers_middle_slot() {
        // T_disp = T_comb = 0.9 ≈ mlp(0.8): slot 1 or 2 balance pre/post.
        let c = costs(0.9);
        let (slot, _) = choose_expert_slot(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(slot == 1 || slot == 2, "slot {slot}");
    }

    #[test]
    fn zero_comm_any_slot_equal() {
        let c = costs(0.0);
        let times: Vec<f64> = (0..4)
            .map(|s| build_pair_schedule(&c, MoEKind::ScMoE { k: 1 },
                                         Strategy::Overlap, s).makespan())
            .collect();
        for t in &times {
            assert!((t - times[0]).abs() < 1e-12);
        }
    }

    #[test]
    fn full_overlap_when_comm_fits_window() {
        // paper: "can fully overlap communication if the communication
        // tasks can be accommodated within the overlapping window"
        let c = costs(0.4); // 2*0.4 = 0.8 < window 2.6
        let f = overlap_fraction(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(f > 0.999, "overlap fraction {f}");
    }

    #[test]
    fn heavy_comm_overlap_band() {
        // comm equal to the whole window still overlaps most of itself;
        // the paper's 70%-100% band is asserted on the calibrated PCIe
        // preset in rust/tests/schedule_integration.rs.
        let c = costs(1.3);
        let f = overlap_fraction(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(f >= 0.65, "overlap fraction {f}");
    }

    #[test]
    fn eq12_13_bound_eq11() {
        for a2a in [0.0, 0.2, 0.5, 0.9, 1.5, 3.0] {
            let c = costs(a2a);
            let kind = MoEKind::ScMoE { k: 1 };
            let m = eq11_min(&c, kind);
            assert!(m >= eq12_lower_bound(&c, kind) - 1e-12);
            assert!(m <= eq13_upper_bound(&c, kind) + 1e-12);
        }
    }
}
