//! Task-graph builders for one Block-MLP + Block-MoE pair under every
//! architecture × strategy combination in the paper (Fig. 6 timelines,
//! Fig. 8 bars). The graphs run on the `simtime` DES; the same structures
//! drive the real threaded executor (`exec`).
//!
//! Modeling follows the paper: computation operators share one exclusive
//! compute stream per device; All-to-All runs on a separate comm stream;
//! gate/encode scheduled at the earliest viable position and decode at the
//! latest (§3.2).
//!
//! Since the [`ScheduleSpec`] redesign there is exactly ONE builder
//! family, driven by the spec and generic over the [`CostModel`] back end:
//!
//! - built against a [`BlockCosts`](super::costs::BlockCosts), it emits
//!   the paper's single-representative-device graphs (one `Compute(0)` +
//!   one `Comm(0)` stream, no `Link` tasks);
//! - built against a [`TopoCosts`](super::costs::TopoCosts), every device
//!   runs its own backbone on `Compute(d)`, each All-to-All becomes
//!   per-device intra-node phase tasks on `Comm(d)` plus per-node
//!   inter-node phase tasks on the shared `Link(node)` resource, expert
//!   computation waits on the whole collective (barrier semantics), and
//!   hot devices' Expert spans stretch with their routed
//!   [`ExpertLoad`](crate::moe::ExpertLoad).
//!
//! With one modeled device both back ends emit the identical task graph,
//! so N = 1 reproduces the legacy makespans bit-exactly (property-tested
//! in `rust/tests/simtime_props.rs`; absolute spans pinned by the golden
//! corpus). The prologue / dispatch / combine / decode loops that the
//! three pre-redesign topo builders kept verbatim now live once in the
//! shared helpers below — insertion order is semantic (the DES breaks
//! readiness ties by task id) and is unchanged.

use crate::simtime::{lazy_label, Resource, Sim, Span, TaskId};

use super::costs::{BlockCosts, ChunkedA2a, MoEKind, Strategy};
use super::spec::{CostModel, PhaseDir, PhaseScope, ScheduleSpec};

/// How the chunked builders arrange a chunk's intra-node and inter-node
/// phase tasks. With a single chunk there is nothing to pipeline and both
/// models keep the seed's barrier semantics (every phase starts after
/// Encode), so chunks = 1 schedules are identical under either value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkPipelining {
    /// MoNTA-style pipelining (the default): chunk i's uplink task starts
    /// once that node's chunk-i intra tasks finish (the cross-node data
    /// must be gathered before it can leave the node), and chunk i+1's
    /// intra tasks only wait on their own `Comm(d)` stream — so chunk i's
    /// inter-node transfer genuinely overlaps chunk i+1's intra phase on
    /// separate resources. The combine direction mirrors the staging
    /// structurally: each node drains its outbound return uplink before
    /// the local scatter, so chunk i's scatter overlaps chunk i+1's
    /// uplink (remote-arrival gating stays at the Decode barrier, as in
    /// the seed's send-side cost model).
    Staged,
    /// Conservative baseline for A/B comparison: like `Staged`, but chunk
    /// i+1's intra tasks additionally wait on chunk i's uplink (and the
    /// combine uplink of chunk i+1 on chunk i's intra scatter), so the
    /// phases of consecutive chunks alternate with no cross-chunk
    /// overlap.
    PhaseChained,
}

/// A built schedule plus span bookkeeping for rendering and assertions.
pub struct PairSchedule {
    pub sim: Sim,
    pub kind: MoEKind,
    pub strategy: Strategy,
    /// Expert-computation slot chosen (0..=3) when Strategy is Overlap*.
    pub expert_slot: usize,
}

impl PairSchedule {
    /// Execute the DES and return one span (start/end seconds) per task.
    pub fn run(&self) -> Vec<Span> {
        self.sim.run()
    }

    /// End time (seconds) of the last task in the simulated schedule.
    pub fn makespan(&self) -> f64 {
        self.sim.makespan()
    }
}

/// Serial compute time of the pair's backbone (no MoE stream at all):
/// Attn(l) + MLP(l) + Attn(l+1) [+ SE(l+1)].
pub fn backbone_time(c: &BlockCosts, kind: MoEKind) -> f64 {
    let se = if kind.has_shared_expert() { c.se } else { 0.0 };
    c.attn + c.mlp + c.attn + se
}

/// Single-device convenience shim over [`ScheduleSpec::build`], kept for
/// the paper-table call sites that iterate (kind, strategy, slot) triples.
/// `expert_slot` only applies to Overlap strategies.
pub fn build_pair_schedule(
    c: &BlockCosts,
    kind: MoEKind,
    strategy: Strategy,
    expert_slot: usize,
) -> PairSchedule {
    ScheduleSpec::new(kind, strategy).with_slot(expert_slot).build(c)
}

/// [`build_pair_schedule`] with the adaptive expert slot (and the
/// shortcut-architecture assertion for overlap strategies).
pub fn build_pair_schedule_auto(c: &BlockCosts, kind: MoEKind,
                                strategy: Strategy) -> PairSchedule {
    ScheduleSpec::new(kind, strategy).adaptive().build(c)
}

/// Build the schedule a resolved spec describes. Crate-internal: the
/// public entry point is [`ScheduleSpec::build`], which validates the
/// cost model and resolves the slot policy first.
pub(crate) fn build_from_spec(spec: &ScheduleSpec, cm: &dyn CostModel,
                              slot: usize) -> PairSchedule {
    let mut sim = Sim::new();
    build_from_spec_into(spec, cm, slot, &mut sim);
    let (strategy, expert_slot) = built_meta(spec, slot);
    PairSchedule { sim, kind: spec.kind, strategy, expert_slot }
}

/// [`build_from_spec`] appending into a caller-owned [`Sim`] — the entry
/// point `ScheduleSpec::build_into` replays over a `SimArena`, both cold
/// (appending) and warm (re-pricing a cached skeleton). The builders'
/// task insertion order and dependency lists are identical either way.
pub(crate) fn build_from_spec_into(spec: &ScheduleSpec, cm: &dyn CostModel,
                                   slot: usize, sim: &mut Sim) {
    let k = spec.kind.routed_k();
    match spec.strategy {
        Strategy::Sequential => build_sequential(sim, cm, spec.kind, k),
        Strategy::Pipelined { chunks } => {
            build_pipelined(sim, cm, spec.kind, k, chunks, spec.pipelining)
        }
        Strategy::Overlap => {
            build_overlap(sim, cm, spec.kind, k, slot, 1, spec.pipelining)
        }
        Strategy::OverlapPipelined { chunks } => {
            build_overlap(sim, cm, spec.kind, k, slot, chunks, spec.pipelining)
        }
    }
}

/// The `(strategy, expert_slot)` a built [`PairSchedule`] reports for a
/// spec: `OverlapPipelined { chunks: 1 }` normalizes to `Overlap` and
/// non-overlap strategies pin slot 0 — exactly what the builders returned
/// before they wrote into caller-owned sims.
pub(crate) fn built_meta(spec: &ScheduleSpec, slot: usize) -> (Strategy, usize) {
    match spec.strategy {
        Strategy::Sequential => (Strategy::Sequential, 0),
        Strategy::Pipelined { chunks } => (Strategy::Pipelined { chunks }, 0),
        Strategy::Overlap => (Strategy::Overlap, slot),
        Strategy::OverlapPipelined { chunks } => {
            let strategy = if chunks == 1 {
                Strategy::Overlap
            } else {
                Strategy::OverlapPipelined { chunks }
            };
            (strategy, slot)
        }
    }
}

// ---------------------------------------------------------------------------
// Shared construction helpers.
//
// Construction rules (all builders):
//  - device d's operators run on `Compute(d)`; its A2A intra-node phases on
//    `Comm(d)`; node n's inter-node phases on the shared `Link(n)`;
//  - an All-to-All is a barrier collective: consumers depend on every
//    phase task (per-device intra + per-node inter);
//  - dispatch tasks (`A2A-D*`) answer `phase(Dispatch, ..)` queries and
//    combine tasks (`A2A-C*`) `phase(Combine, ..)`, whose symmetric
//    fallback keeps uniform-routing schedules bit-exact with the
//    pre-routed model;
//  - expert durations come from `CostModel::expert_time` (load-scaled on
//    routed back ends) and, with `chunks > 1`, from the per-chunk
//    `ChunkedA2a::expert` matrix (token-true under routed costs; an even
//    `1/chunks` split otherwise);
//  - with `chunks > 1` phase durations come from `chunk_phases` and the
//    uplink tasks are staged behind the node's intra tasks per
//    `ChunkPipelining`; with one chunk the builders keep the seed's
//    enc-barrier phase layout and full-phase durations bit-exactly;
//  - task insertion order is semantic (the DES breaks readiness ties by
//    task id) and matches the pre-redesign builders exactly.
// ---------------------------------------------------------------------------

/// Per-device backbone prologue shared by every builder. Non-shortcut
/// kinds run Attn(l) → MLP(l) → Attn(l+1) and hang Gate + Encode off
/// Attn(l+1); the shortcut (ScMoE) hangs them off the *preceding layer's*
/// Attn(l) (Pos-2 shortcut input), leaving MLP(l)/Attn(l+1)/SE(l+1) for
/// the overlap window. Returns (anchor, enc) task ids per device — the
/// anchor is the task SE / the overlap window chains from.
fn add_backbone_head(sim: &mut Sim, cm: &dyn CostModel,
                     shortcut: bool) -> (Vec<TaskId>, Vec<TaskId>) {
    let n = cm.n_devices();
    let mut anchors = Vec::with_capacity(n);
    let mut enc = Vec::with_capacity(n);
    for d in 0..n {
        let c = cm.device(d);
        let attn_l = sim.add("Attn(l)", Resource::Compute(d), c.attn, &[]);
        let anchor = if shortcut {
            attn_l
        } else {
            let mlp_l = sim.add("MLP(l)", Resource::Compute(d), c.mlp, &[attn_l]);
            sim.add("Attn(l+1)", Resource::Compute(d), c.attn, &[mlp_l])
        };
        let gate = sim.add("Gate", Resource::Compute(d), c.gate, &[anchor]);
        let e = sim.add("Encode", Resource::Compute(d), c.encode, &[gate]);
        anchors.push(anchor);
        enc.push(e);
    }
    (anchors, enc)
}

/// Dispatch-phase task label: unchunked collectives use the bare name,
/// chunk i of a pipelined collective gets the index suffix.
fn tag(base: &str, i: Option<usize>) -> String {
    match i {
        Some(i) => format!("{base}{i}"),
        None => base.to_string(),
    }
}

/// One collective's dispatch phase tasks (intra per device, then inter
/// per node). `i = None` is the unchunked collective (`"A2A-D"` labels,
/// full phase durations, every phase starting after Encode — the seed's
/// barrier layout); `i = Some(idx)` is chunk `idx` of a pipelined stream,
/// whose durations come from `ca` when `chunks > 1` and whose uplink is
/// staged behind the node's intra tasks (plus the previous chunk's uplink
/// under `PhaseChained` for the intra tasks).
/// Returns this collective's task ids (devices first, then links).
#[allow(clippy::too_many_arguments)]
fn add_dispatch_chunk(
    sim: &mut Sim,
    cm: &dyn CostModel,
    k: usize,
    i: Option<usize>,
    ca: Option<&ChunkedA2a>,
    enc: &[TaskId],
    prev_d: &mut [Option<TaskId>],
    prev_x: &mut [Option<TaskId>],
    pipelining: ChunkPipelining,
) -> Vec<TaskId> {
    let n = cm.n_devices();
    let n_links = cm.n_links();
    let ci = i.unwrap_or(0);
    let mut disp_i = Vec::with_capacity(n + n_links);
    for d in 0..n {
        // at most enc + prev chunk intra + prev chunk uplink
        let mut dbuf: [TaskId; 3] = [0; 3];
        dbuf[0] = enc[d];
        let mut dl = 1;
        if let Some(p) = prev_d[d] {
            dbuf[dl] = p;
            dl += 1;
        }
        if pipelining == ChunkPipelining::PhaseChained && n_links > 0 {
            if let Some(p) = prev_x[cm.node_of(d)] {
                dbuf[dl] = p;
                dl += 1;
            }
        }
        let dur = match ca {
            Some(ca) => ca.disp_intra[ci][d],
            None => cm.phase(PhaseDir::Dispatch, PhaseScope::Intra, d, k),
        };
        let t = sim.add(lazy_label(|| tag("A2A-D", i)), Resource::Comm(d),
                        dur, &dbuf[..dl]);
        prev_d[d] = Some(t);
        disp_i.push(t);
    }
    let mut nbuf: Vec<TaskId> = Vec::with_capacity(cm.devices_per_node() + 1);
    for node in 0..n_links {
        // staged (chunks > 1): the uplink sends what the node's intra
        // phase gathered, so it waits on this chunk's intra tasks; the
        // unchunked collective keeps the seed's enc-barrier semantics
        nbuf.clear();
        match ca {
            Some(_) => nbuf.extend(cm.devices_of(node).map(|d| disp_i[d])),
            None => nbuf.extend(cm.devices_of(node).map(|d| enc[d])),
        }
        if let Some(p) = prev_x[node] {
            nbuf.push(p);
        }
        let dur = match ca {
            Some(ca) => ca.disp_inter[ci][node],
            None => cm.phase(PhaseDir::Dispatch, PhaseScope::Inter, node, k),
        };
        let t = sim.add(lazy_label(|| tag("A2A-Dx", i)), Resource::Link(node),
                        dur, &nbuf);
        prev_x[node] = Some(t);
        disp_i.push(t);
    }
    disp_i
}

/// One collective's combine phase tasks, mirroring [`add_dispatch_chunk`]
/// in the return direction: with `chunks > 1` the uplink-return tasks come
/// first and each device's intra scatter waits on its own node's
/// *outbound* return task — the structural mirror of dispatch's
/// gather-then-send (the node drains its shared return fabric before the
/// local scatter), so chunk i's intra scatter overlaps chunk i+1's
/// uplink. Remote-*arrival* gating is unchanged from the seed: the
/// consumer (`Decode`) barriers on every combine task of every chunk,
/// so no result is consumed before all uplinks finish. `PhaseChained`
/// additionally chains each uplink behind the previous chunk's scatter.
/// `experts_i[d]` is device d's expert task for this collective; appends
/// all created tasks to `combines` and records the intra tasks in
/// `prev_c`.
#[allow(clippy::too_many_arguments)]
fn add_combine_chunk(
    sim: &mut Sim,
    cm: &dyn CostModel,
    k: usize,
    i: Option<usize>,
    ca: Option<&ChunkedA2a>,
    experts_i: &[TaskId],
    prev_c: &mut [Option<TaskId>],
    combines: &mut Vec<TaskId>,
    pipelining: ChunkPipelining,
) {
    let n = cm.n_devices();
    let n_links = cm.n_links();
    let ci = i.unwrap_or(0);
    match ca {
        Some(ca) => {
            let mut comb_x_i = Vec::with_capacity(n_links);
            let mut nbuf: Vec<TaskId> =
                Vec::with_capacity(2 * cm.devices_per_node());
            for node in 0..n_links {
                nbuf.clear();
                nbuf.extend(cm.devices_of(node).map(|d| experts_i[d]));
                if pipelining == ChunkPipelining::PhaseChained {
                    for d in cm.devices_of(node) {
                        if let Some(p) = prev_c[d] {
                            nbuf.push(p);
                        }
                    }
                }
                let t = sim.add(lazy_label(|| tag("A2A-Cx", i)),
                                Resource::Link(node),
                                ca.comb_inter[ci][node], &nbuf);
                comb_x_i.push(t);
                combines.push(t);
            }
            for d in 0..n {
                let mut dbuf: [TaskId; 2] = [0; 2];
                dbuf[0] = experts_i[d];
                let mut dl = 1;
                if n_links > 0 {
                    dbuf[dl] = comb_x_i[cm.node_of(d)];
                    dl += 1;
                }
                let t = sim.add(lazy_label(|| tag("A2A-C", i)),
                                Resource::Comm(d),
                                ca.comb_intra[ci][d], &dbuf[..dl]);
                prev_c[d] = Some(t);
                combines.push(t);
            }
        }
        None => {
            for d in 0..n {
                let t = sim.add(
                    lazy_label(|| tag("A2A-C", i)), Resource::Comm(d),
                    cm.phase(PhaseDir::Combine, PhaseScope::Intra, d, k),
                    &[experts_i[d]]);
                prev_c[d] = Some(t);
                combines.push(t);
            }
            let mut nbuf: Vec<TaskId> =
                Vec::with_capacity(cm.devices_per_node());
            for node in 0..n_links {
                nbuf.clear();
                nbuf.extend(cm.devices_of(node).map(|d| experts_i[d]));
                combines.push(sim.add(
                    lazy_label(|| tag("A2A-Cx", i)), Resource::Link(node),
                    cm.phase(PhaseDir::Combine, PhaseScope::Inter, node, k),
                    &nbuf));
            }
        }
    }
}

/// Per-device Decode at the latest position (§3.2), barriering on every
/// combine task. Non-shortcut shared-expert kinds insert the SE task here
/// (`anchors` = Attn(l+1)); the overlap builder instead passes its
/// per-device backbone tails via `last_backbone` (SE already sits inside
/// the window).
fn add_decode(sim: &mut Sim, cm: &dyn CostModel, kind: MoEKind,
              combines: &[TaskId], anchors: &[TaskId],
              last_backbone: Option<&[TaskId]>) {
    for d in 0..cm.n_devices() {
        let c = cm.device(d);
        // the SE task (when present) must be inserted before Decode —
        // insertion order is semantic
        let tail: Option<TaskId> = if let Some(tails) = last_backbone {
            Some(tails[d])
        } else if kind.has_shared_expert() {
            Some(sim.add("SE", Resource::Compute(d), c.se, &[anchors[d]]))
        } else {
            None
        };
        let tail_buf;
        let extra: &[TaskId] = match tail {
            Some(t) => {
                tail_buf = [t];
                &tail_buf
            }
            None => &[],
        };
        sim.add_cat("Decode", Resource::Compute(d), c.decode, combines, extra);
    }
}

/// Fully sequential baseline (Fig. 6, 1st timeline), over the whole
/// modeled fleet: one barrier collective each way, experts between.
fn build_sequential(sim: &mut Sim, cm: &dyn CostModel, kind: MoEKind,
                    k: usize) {
    let n = cm.n_devices();
    let (attn_m, enc) = add_backbone_head(sim, cm, false);
    let mut prev_d: Vec<Option<TaskId>> = vec![None; n];
    let mut prev_x: Vec<Option<TaskId>> = vec![None; cm.n_links()];
    let mut prev_c: Vec<Option<TaskId>> = vec![None; n];
    let disp = add_dispatch_chunk(sim, cm, k, None, None, &enc,
                                  &mut prev_d, &mut prev_x,
                                  ChunkPipelining::Staged);
    let experts: Vec<TaskId> = (0..n)
        .map(|d| sim.add("Expert", Resource::Compute(d),
                         cm.expert_time(d, k), &disp))
        .collect();
    let mut combines = Vec::new();
    add_combine_chunk(sim, cm, k, None, None, &experts, &mut prev_c,
                      &mut combines, ChunkPipelining::Staged);
    add_decode(sim, cm, kind, &combines, &attn_m, None);
}

/// Tutel-style pipelining (Fig. 6, 2nd timeline) over the fleet: every
/// chunk's expert computation waits on that chunk's full collective, each
/// chunk pays its own per-link α and bytes (`CostModel::chunk_phases` —
/// token-true under routed costs, as are the per-chunk expert durations),
/// and the uplink tasks are staged per [`ChunkPipelining`].
fn build_pipelined(sim: &mut Sim, cm: &dyn CostModel, kind: MoEKind, k: usize,
                   chunks: usize, pipelining: ChunkPipelining) {
    assert!(chunks >= 1);
    let n = cm.n_devices();
    let (attn_m, enc) = add_backbone_head(sim, cm, false);
    let fc = chunks as f64;
    let ca = if chunks > 1 { Some(cm.chunk_phases(k, chunks)) } else { None };
    let mut prev_d: Vec<Option<TaskId>> = vec![None; n];
    let mut prev_x: Vec<Option<TaskId>> = vec![None; cm.n_links()];
    let mut prev_c: Vec<Option<TaskId>> = vec![None; n];
    let mut combines: Vec<TaskId> = Vec::new();
    for i in 0..chunks {
        let disp_i = add_dispatch_chunk(sim, cm, k, Some(i), ca.as_ref(),
                                        &enc, &mut prev_d, &mut prev_x,
                                        pipelining);
        let mut experts_i = Vec::with_capacity(n);
        for d in 0..n {
            let dur = match &ca {
                Some(ca) => ca.expert[i][d],
                None => cm.expert_time(d, k) / fc,
            };
            experts_i.push(sim.add(lazy_label(|| format!("Expert{i}")),
                                   Resource::Compute(d), dur, &disp_i));
        }
        add_combine_chunk(sim, cm, k, Some(i), ca.as_ref(), &experts_i,
                          &mut prev_c, &mut combines, pipelining);
    }
    add_decode(sim, cm, kind, &combines, &attn_m, None);
}

/// The paper's overlapping strategy (Fig. 6, 4th/5th timelines) over the
/// fleet: every device hangs its MoE stream off the preceding layer's
/// intermediate (Pos-2 shortcut) and inserts its expert chunks at `slot`
/// in its own backbone window; slow or hot devices stretch the collective
/// for everyone. Chunked dispatch/combine phases follow the same
/// per-chunk α + staging model as [`build_pipelined`].
fn build_overlap(sim: &mut Sim, cm: &dyn CostModel, kind: MoEKind, k: usize,
                 slot: usize, chunks: usize, pipelining: ChunkPipelining) {
    assert!(slot <= 3, "expert slot must be one of the 4 locations");
    assert!(chunks >= 1);
    let n = cm.n_devices();
    let (attn_l_ids, enc) = add_backbone_head(sim, cm, true);
    let fc = chunks as f64;
    let ca = if chunks > 1 { Some(cm.chunk_phases(k, chunks)) } else { None };
    let mut disp_chunks: Vec<Vec<TaskId>> = Vec::with_capacity(chunks);
    let mut prev_d: Vec<Option<TaskId>> = vec![None; n];
    let mut prev_x: Vec<Option<TaskId>> = vec![None; cm.n_links()];
    for i in 0..chunks {
        disp_chunks.push(add_dispatch_chunk(sim, cm, k, Some(i),
                                            ca.as_ref(), &enc, &mut prev_d,
                                            &mut prev_x, pipelining));
    }
    // per-device backbone window with expert chunks inserted at `slot`
    let mut last_backbone: Vec<TaskId> = vec![0; n];
    let mut experts_by_dev: Vec<Vec<TaskId>> = Vec::with_capacity(n);
    for d in 0..n {
        let c = cm.device(d);
        let mut dev_experts = Vec::with_capacity(chunks);
        let place = |sim: &mut Sim, after: TaskId,
                     out: &mut Vec<TaskId>| -> TaskId {
            let mut tail = after;
            for (i, disp_i) in disp_chunks.iter().enumerate() {
                let dur = match &ca {
                    Some(ca) => ca.expert[i][d],
                    None => cm.expert_time(d, k) / fc,
                };
                let e = sim.add_cat(lazy_label(|| format!("Expert{i}")),
                                    Resource::Compute(d), dur, disp_i,
                                    &[tail]);
                out.push(e);
                tail = e;
            }
            tail
        };
        let mut tail = attn_l_ids[d];
        if slot == 0 {
            tail = place(sim, tail, &mut dev_experts);
        }
        let window: [(&str, f64); 3] = [
            ("MLP(l)", c.mlp),
            ("Attn(l+1)", c.attn),
            ("SE(l+1)", c.se),
        ];
        for (wi, (label, dur)) in window.iter().enumerate() {
            tail = sim.add(*label, Resource::Compute(d), *dur, &[tail]);
            if slot == wi + 1 {
                tail = place(sim, tail, &mut dev_experts);
            }
        }
        last_backbone[d] = tail;
        experts_by_dev.push(dev_experts);
    }
    let mut prev_c: Vec<Option<TaskId>> = vec![None; n];
    let mut combines: Vec<TaskId> = Vec::new();
    for i in 0..chunks {
        let experts_i: Vec<TaskId> =
            (0..n).map(|d| experts_by_dev[d][i]).collect();
        add_combine_chunk(sim, cm, k, Some(i), ca.as_ref(), &experts_i,
                          &mut prev_c, &mut combines, pipelining);
    }
    add_decode(sim, cm, kind, &combines, &[], Some(&last_backbone));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::costs::TopoCosts;
    use crate::moe::ExpertLoad;

    fn costs(a2a: f64) -> BlockCosts {
        BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: a2a,
            a2a_alpha_k1: a2a / 16.0,
        }
    }

    #[test]
    fn sequential_is_sum_of_chain() {
        let c = costs(0.5);
        let s = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let expect = c.attn + c.mlp + c.attn
            + c.gate + c.encode + c.a2a(2) + c.expert(2) + c.a2a(2) + c.decode;
        assert!((s.makespan() - expect).abs() < 1e-12);
    }

    #[test]
    fn pipelining_beats_sequential_with_comm() {
        let c = costs(0.5);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 4 }, 0);
        assert!(pipe.makespan() < seq.makespan());
    }

    #[test]
    fn pipeline_one_chunk_equals_sequential_topk() {
        let c = costs(0.3);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe1 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                        Strategy::Pipelined { chunks: 1 }, 0);
        assert!((pipe1.makespan() - seq.makespan()).abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_small_comm_completely() {
        let c = costs(0.1); // comm well under the window
        let kind = MoEKind::ScMoE { k: 1 };
        let s = build_pair_schedule_auto(&c, kind, Strategy::Overlap);
        // full overlap: makespan = backbone + gate-side overhead + expert + decode
        let serial_no_comm = backbone_time(&c, kind) + c.expert(1) + c.decode;
        assert!(s.makespan() <= serial_no_comm + c.gate + c.encode + 1e-9,
                "makespan {} vs {}", s.makespan(), serial_no_comm);
    }

    #[test]
    fn overlap_beats_pipelined_top2_when_comm_heavy() {
        let c = costs(0.8); // PCIe-like: comm ≈ 60% of MoE time
        let top2 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 2 }, 0);
        let sc = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(sc.makespan() < top2.makespan());
    }

    #[test]
    fn all_slots_produce_valid_schedules() {
        let c = costs(0.5);
        for slot in 0..4 {
            let s = build_pair_schedule(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap, slot);
            let spans = s.run();
            assert!(!spans.is_empty());
            // compute stream never overlaps itself
            let mut comp_spans: Vec<_> = spans.iter()
                .filter(|sp| matches!(sp.resource, Resource::Compute(_)))
                .collect();
            comp_spans.sort_by(|a, b| a.start.total_cmp(&b.start));
            for w in comp_spans.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12,
                        "compute overlap: {:?} then {:?}", w[0].label, w[1].label);
            }
        }
    }

    fn homogeneous_topo(c: &BlockCosts, n: usize, devices_per_node: usize,
                        inter_k1: f64) -> TopoCosts {
        let n_nodes = n / devices_per_node;
        TopoCosts {
            per_device: vec![c.clone(); n],
            a2a_intra_k1: vec![c.a2a_k1; n],
            a2a_inter_k1: if n_nodes > 1 { vec![inter_k1; n_nodes] } else { Vec::new() },
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: vec![c.a2a_alpha_k1; n],
            a2a_inter_alpha_k1: if n_nodes > 1 {
                vec![inter_k1 / 16.0; n_nodes]
            } else {
                Vec::new()
            },
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            expert_load: None,
            devices_per_node,
        }
    }

    fn spec_of(kind: MoEKind, strat: Strategy, slot: usize) -> ScheduleSpec {
        ScheduleSpec::new(kind, strat).with_slot(slot)
    }

    #[test]
    fn topo_one_device_matches_legacy_graphs() {
        let c = costs(0.45);
        let tc = TopoCosts::from_block(&c);
        for (kind, strat, slot) in [
            (MoEKind::Standard { k: 2 }, Strategy::Sequential, 0),
            (MoEKind::SharedExpert, Strategy::Sequential, 0),
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 3 }, 0),
            (MoEKind::ScMoE { k: 1 }, Strategy::Overlap, 2),
            (MoEKind::ScMoE { k: 2 }, Strategy::OverlapPipelined { chunks: 2 }, 1),
        ] {
            let legacy = build_pair_schedule(&c, kind, strat, slot);
            let topo = spec_of(kind, strat, slot).build(&tc);
            let (ls, ts) = (legacy.run(), topo.run());
            assert_eq!(ls.len(), ts.len(), "{kind:?}/{strat:?}");
            for (a, b) in ls.iter().zip(&ts) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.resource, b.resource);
                assert_eq!(a.start, b.start, "{}: start", a.label);
                assert_eq!(a.end, b.end, "{}: end", a.label);
            }
        }
    }

    #[test]
    fn topo_homogeneous_single_node_fleet_matches_legacy_makespan() {
        // N identical devices on one node run the same schedule in
        // lockstep: fleet makespan == representative-device makespan.
        let c = costs(0.5);
        let tc = homogeneous_topo(&c, 4, 4, 0.0);
        for (kind, strat) in [
            (MoEKind::Standard { k: 2 }, Strategy::Sequential),
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 2 }),
        ] {
            let legacy = build_pair_schedule(&c, kind, strat, 0).makespan();
            let topo = spec_of(kind, strat, 0).build(&tc).makespan();
            assert!((legacy - topo).abs() < 1e-12,
                    "{kind:?}/{strat:?}: legacy {legacy} topo {topo}");
        }
    }

    #[test]
    fn topo_straggler_device_stretches_the_collective() {
        let c = costs(0.3);
        let mut tc = homogeneous_topo(&c, 4, 4, 0.0);
        // device 3 computes 2x slower: everyone waits at the barrier
        let d3 = &mut tc.per_device[3];
        d3.attn *= 2.0;
        d3.mlp *= 2.0;
        d3.se *= 2.0;
        d3.expert_k1 *= 2.0;
        let spec = spec_of(MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let uniform = spec.build(&homogeneous_topo(&c, 4, 4, 0.0)).makespan();
        let straggler = spec.build(&tc).makespan();
        assert!(straggler > uniform + 1e-9,
                "straggler {straggler} vs uniform {uniform}");
    }

    #[test]
    fn topo_hot_device_load_stretches_the_collective() {
        // same fleet, but device 3 carries twice the balanced load: its
        // Expert span (and the fleet makespan) must stretch even though
        // its compute scale and every phase duration are unchanged
        let c = costs(0.3);
        let balanced = homogeneous_topo(&c, 4, 4, 0.0);
        let mut hot = homogeneous_topo(&c, 4, 4, 0.0);
        hot.expert_load = Some(ExpertLoad { per_device: vec![4, 4, 4, 8],
                                            total: 20 });
        let spec = spec_of(MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let t_bal = spec.build(&balanced).makespan();
        let t_hot = spec.build(&hot).makespan();
        assert!(t_hot > t_bal + 1e-9, "hot {t_hot} vs balanced {t_bal}");
        // and the even-load fleet is bit-exact with no load vector at all
        let mut even = homogeneous_topo(&c, 4, 4, 0.0);
        even.expert_load = Some(ExpertLoad { per_device: vec![5; 4], total: 20 });
        assert_eq!(spec.build(&even).makespan(), t_bal);
    }

    #[test]
    fn topo_inter_node_link_is_contended() {
        // one shared uplink per node: raising the inter phase raises the
        // makespan even when intra phases stay fixed
        let c = costs(0.2);
        let spec = spec_of(MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let cheap = spec.build(&homogeneous_topo(&c, 4, 2, 0.1)).makespan();
        let pricey = spec.build(&homogeneous_topo(&c, 4, 2, 1.5)).makespan();
        assert!(pricey > cheap + 1e-9, "pricey {pricey} vs cheap {cheap}");
        // and the link rows exist in the spans
        let spans = spec.build(&homogeneous_topo(&c, 4, 2, 0.5)).run();
        assert!(spans.iter().any(|s| matches!(s.resource, Resource::Link(_))));
    }
}
