//! Task-graph builders for one Block-MLP + Block-MoE pair under every
//! architecture × strategy combination in the paper (Fig. 6 timelines,
//! Fig. 8 bars). The graphs run on the `simtime` DES; the same structures
//! drive the real threaded executor (`exec`).
//!
//! Modeling follows the paper: computation operators share one exclusive
//! compute stream per device; All-to-All runs on a separate comm stream;
//! gate/encode scheduled at the earliest viable position and decode at the
//! latest (§3.2).
//!
//! Two families of builders:
//!
//! - [`build_pair_schedule`] — the paper's single-representative-device
//!   graphs over [`BlockCosts`];
//! - [`build_pair_schedule_topo`] — the same strategies generalized to an
//!   N-device fleet over [`TopoCosts`]: every device runs its own backbone
//!   on `Compute(d)`, each All-to-All becomes per-device intra-node phase
//!   tasks on `Comm(d)` plus per-node inter-node phase tasks on the shared
//!   `Link(node)` resource, and expert computation on each device waits on
//!   the whole collective (barrier semantics). With one modeled device the
//!   construction emits the identical task graph as the legacy builders,
//!   so N = 1 reproduces the legacy makespans bit-exactly.

use crate::simtime::{Resource, Sim, Span, TaskId};

use super::costs::{BlockCosts, ChunkedA2a, MoEKind, Strategy, TopoCosts};

const DEV: usize = 0;

/// How the chunked topology-aware builders arrange a chunk's intra-node
/// and inter-node phase tasks. With a single chunk there is nothing to
/// pipeline and both models keep the seed's barrier semantics (every
/// phase starts after Encode), so chunks = 1 schedules are identical
/// under either value.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChunkPipelining {
    /// MoNTA-style pipelining (the default): chunk i's uplink task starts
    /// once that node's chunk-i intra tasks finish (the cross-node data
    /// must be gathered before it can leave the node), and chunk i+1's
    /// intra tasks only wait on their own `Comm(d)` stream — so chunk i's
    /// inter-node transfer genuinely overlaps chunk i+1's intra phase on
    /// separate resources. The combine direction mirrors the staging
    /// structurally: each node drains its outbound return uplink before
    /// the local scatter, so chunk i's scatter overlaps chunk i+1's
    /// uplink (remote-arrival gating stays at the Decode barrier, as in
    /// the seed's send-side cost model).
    Staged,
    /// Conservative baseline for A/B comparison: like `Staged`, but chunk
    /// i+1's intra tasks additionally wait on chunk i's uplink (and the
    /// combine uplink of chunk i+1 on chunk i's intra scatter), so the
    /// phases of consecutive chunks alternate with no cross-chunk
    /// overlap.
    PhaseChained,
}

/// A built schedule plus span bookkeeping for rendering and assertions.
pub struct PairSchedule {
    pub sim: Sim,
    pub kind: MoEKind,
    pub strategy: Strategy,
    /// Expert-computation slot chosen (0..=3) when Strategy is Overlap*.
    pub expert_slot: usize,
}

impl PairSchedule {
    /// Execute the DES and return one span (start/end seconds) per task.
    pub fn run(&self) -> Vec<Span> {
        self.sim.run()
    }

    /// End time (seconds) of the last task in the simulated schedule.
    pub fn makespan(&self) -> f64 {
        self.sim.makespan()
    }
}

/// Serial compute time of the pair's backbone (no MoE stream at all):
/// Attn(l) + MLP(l) + Attn(l+1) [+ SE(l+1)].
pub fn backbone_time(c: &BlockCosts, kind: MoEKind) -> f64 {
    let se = if kind.has_shared_expert() { c.se } else { 0.0 };
    c.attn + c.mlp + c.attn + se
}

/// Build the schedule for a pair under (kind, strategy).
///
/// `expert_slot` only applies to Overlap strategies; pass
/// `choose_expert_slot` output (or use `build_pair_schedule_auto`).
pub fn build_pair_schedule(
    c: &BlockCosts,
    kind: MoEKind,
    strategy: Strategy,
    expert_slot: usize,
) -> PairSchedule {
    let k = kind.routed_k();
    match strategy {
        Strategy::Sequential => build_sequential(c, kind, k),
        Strategy::Pipelined { chunks } => build_pipelined(c, kind, k, chunks),
        Strategy::Overlap => build_overlap(c, kind, k, expert_slot, 1),
        Strategy::OverlapPipelined { chunks } => {
            build_overlap(c, kind, k, expert_slot, chunks)
        }
    }
}

/// Build with the best expert slot (and, for Overlap strategies on
/// non-shortcut architectures, fall back to the legal strategy).
pub fn build_pair_schedule_auto(c: &BlockCosts, kind: MoEKind,
                                strategy: Strategy) -> PairSchedule {
    match strategy {
        Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
            assert!(matches!(kind, MoEKind::ScMoE { .. }),
                    "overlap strategy requires the shortcut architecture");
            let slot = super::adaptive::choose_expert_slot(c, kind, strategy).0;
            build_pair_schedule(c, kind, strategy, slot)
        }
        _ => build_pair_schedule(c, kind, strategy, 0),
    }
}

/// Build the topology-aware schedule for a pair under (kind, strategy)
/// across every modeled device of `tc`, with MoNTA-style
/// [`ChunkPipelining::Staged`] intra/inter staging for chunked strategies.
pub fn build_pair_schedule_topo(
    tc: &TopoCosts,
    kind: MoEKind,
    strategy: Strategy,
    expert_slot: usize,
) -> PairSchedule {
    build_pair_schedule_topo_with(tc, kind, strategy, expert_slot,
                                  ChunkPipelining::Staged)
}

/// [`build_pair_schedule_topo`] with an explicit [`ChunkPipelining`]
/// model — `PhaseChained` serializes each chunk's intra phase against the
/// previous chunk's uplink, the baseline the staged pipeline is measured
/// against in `scmoe report topo`'s chunk sweep.
pub fn build_pair_schedule_topo_with(
    tc: &TopoCosts,
    kind: MoEKind,
    strategy: Strategy,
    expert_slot: usize,
    pipelining: ChunkPipelining,
) -> PairSchedule {
    tc.assert_valid();
    let k = kind.routed_k();
    match strategy {
        Strategy::Sequential => build_sequential_topo(tc, kind, k),
        Strategy::Pipelined { chunks } => {
            build_pipelined_topo(tc, kind, k, chunks, pipelining)
        }
        Strategy::Overlap => {
            build_overlap_topo(tc, kind, k, expert_slot, 1, pipelining)
        }
        Strategy::OverlapPipelined { chunks } => {
            build_overlap_topo(tc, kind, k, expert_slot, chunks, pipelining)
        }
    }
}

/// Topology-aware twin of [`build_pair_schedule_auto`]: picks the best
/// expert slot for overlap strategies by simulating the whole fleet.
pub fn build_pair_schedule_topo_auto(tc: &TopoCosts, kind: MoEKind,
                                     strategy: Strategy) -> PairSchedule {
    match strategy {
        Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
            assert!(matches!(kind, MoEKind::ScMoE { .. }),
                    "overlap strategy requires the shortcut architecture");
            let slot = super::adaptive::choose_expert_slot_topo(tc, kind, strategy).0;
            build_pair_schedule_topo(tc, kind, strategy, slot)
        }
        _ => build_pair_schedule_topo(tc, kind, strategy, 0),
    }
}

fn comp(sim: &mut Sim, label: &str, dur: f64, deps: &[TaskId]) -> TaskId {
    sim.add(label, Resource::Compute(DEV), dur, deps)
}

fn comm(sim: &mut Sim, label: &str, dur: f64, deps: &[TaskId]) -> TaskId {
    sim.add(label, Resource::Comm(DEV), dur, deps)
}

/// Standard top-k / shared-expert, fully sequential (Fig. 6, 1st timeline).
fn build_sequential(c: &BlockCosts, kind: MoEKind, k: usize) -> PairSchedule {
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    let mlp_l = comp(&mut sim, "MLP(l)", c.mlp, &[attn_l]);
    let attn_m = comp(&mut sim, "Attn(l+1)", c.attn, &[mlp_l]);
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_m]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);
    let disp = comm(&mut sim, "A2A-D", c.a2a(k), &[enc]);
    let expert = comp(&mut sim, "Expert", c.expert(k), &[disp]);
    let comb = comm(&mut sim, "A2A-C", c.a2a(k), &[expert]);
    let mut decode_deps = vec![comb];
    if kind.has_shared_expert() {
        // SE computed after attention; serial on the compute stream but can
        // overlap the MoE comm in principle — sequential strategy runs it
        // before the gate for the worst-case baseline.
        let se = comp(&mut sim, "SE", c.se, &[attn_m]);
        decode_deps.push(se);
    }
    let _dec = comp(&mut sim, "Decode", c.decode, &decode_deps);
    PairSchedule { sim, kind, strategy: Strategy::Sequential, expert_slot: 0 }
}

/// Tutel-style pipelining (Fig. 6, 2nd timeline): tokens split into
/// `chunks`; dispatch/expert/combine of different chunks overlap. Each
/// chunk message pays the link's full launch latency — only the byte term
/// divides (`BlockCosts::a2a_chunk`), so deep chunking is no longer free.
fn build_pipelined(c: &BlockCosts, kind: MoEKind, k: usize,
                   chunks: usize) -> PairSchedule {
    assert!(chunks >= 1);
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    let mlp_l = comp(&mut sim, "MLP(l)", c.mlp, &[attn_l]);
    let attn_m = comp(&mut sim, "Attn(l+1)", c.attn, &[mlp_l]);
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_m]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);
    let fc = chunks as f64;
    let mut combines = Vec::new();
    let mut prev_disp: Option<TaskId> = None;
    for i in 0..chunks {
        let dd = match prev_disp {
            Some(p) => vec![enc, p],
            None => vec![enc],
        };
        let disp = comm(&mut sim, &format!("A2A-D{i}"),
                        c.a2a_chunk(k, chunks), &dd);
        prev_disp = Some(disp);
        let expert = comp(&mut sim, &format!("Expert{i}"), c.expert(k) / fc, &[disp]);
        let comb = comm(&mut sim, &format!("A2A-C{i}"),
                        c.a2a_chunk(k, chunks), &[expert]);
        combines.push(comb);
    }
    let mut decode_deps = combines;
    if kind.has_shared_expert() {
        // shared-expert MoE overlaps SE with the MoE stream's comm
        let se = comp(&mut sim, "SE", c.se, &[attn_m]);
        decode_deps.push(se);
    }
    let _dec = comp(&mut sim, "Decode", c.decode, &decode_deps);
    PairSchedule { sim, kind, strategy: Strategy::Pipelined { chunks }, expert_slot: 0 }
}

/// The paper's overlapping strategy (Fig. 6, 4th/5th timelines): the MoE
/// stream hangs off the *preceding layer's* intermediate representation
/// (Pos-2 shortcut), so its comm overlaps MLP(l) + Attn(l+1) + SE(l+1).
/// Expert computation is inserted in one of 4 slots of the backbone
/// stream; with `chunks > 1` the dispatch/expert/combine are additionally
/// pipelined inside the window.
fn build_overlap(c: &BlockCosts, kind: MoEKind, k: usize, slot: usize,
                 chunks: usize) -> PairSchedule {
    assert!(slot <= 3, "expert slot must be one of the 4 locations");
    assert!(chunks >= 1);
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    // MoE stream: gate + encode at the earliest viable position — right
    // after the preceding layer's attention (Pos-2 shortcut input).
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_l]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);

    // Backbone window ops (COMP_1..COMP_3 of Eq. 11); the expert
    // computation occupies one of the 4 slots around them.
    // slot 0: before MLP(l); 1: after MLP(l); 2: after Attn(l+1);
    // slot 3: after SE(l+1).
    let fc = chunks as f64;
    let mut dispatches = Vec::new();
    let mut prev: Option<TaskId> = None;
    for i in 0..chunks {
        let deps = match prev {
            Some(p) => vec![enc, p],
            None => vec![enc],
        };
        let d = comm(&mut sim, &format!("A2A-D{i}"),
                     c.a2a_chunk(k, chunks), &deps);
        dispatches.push(d);
        prev = Some(d);
    }

    // backbone ops, inserting expert chunks at `slot`
    let mut experts: Vec<TaskId> = Vec::new();
    let mut last_backbone = attn_l;
    let window: [(&str, f64); 3] = [
        ("MLP(l)", c.mlp),
        ("Attn(l+1)", c.attn),
        ("SE(l+1)", c.se),
    ];
    let mut place_experts = |sim: &mut Sim, after: TaskId| -> TaskId {
        let mut tail = after;
        for (i, d) in dispatches.iter().enumerate() {
            let e = comp(sim, &format!("Expert{i}"),
                         c.expert(k) / fc, &[*d, tail]);
            experts.push(e);
            tail = e;
        }
        tail
    };

    if slot == 0 {
        last_backbone = place_experts(&mut sim, last_backbone);
    }
    for (i, (label, dur)) in window.iter().enumerate() {
        last_backbone = comp(&mut sim, label, *dur, &[last_backbone]);
        if slot == i + 1 {
            last_backbone = place_experts(&mut sim, last_backbone);
        }
    }

    // combines: chunk i's combine depends on its expert; comm stream FIFO
    let mut combines = Vec::new();
    for (i, e) in experts.iter().enumerate() {
        combines.push(comm(&mut sim, &format!("A2A-C{i}"),
                           c.a2a_chunk(k, chunks), &[*e]));
    }
    // decode at the latest position: after the backbone and all combines
    let mut deps = combines;
    deps.push(last_backbone);
    let _dec = comp(&mut sim, "Decode", c.decode, &deps);
    let strategy = if chunks == 1 {
        Strategy::Overlap
    } else {
        Strategy::OverlapPipelined { chunks }
    };
    PairSchedule { sim, kind, strategy, expert_slot: slot }
}

// ---------------------------------------------------------------------------
// Topology-aware builders: the same strategies over an N-device fleet.
//
// Construction rules shared by all three builders:
//  - device d's operators run on `Compute(d)`; its A2A intra-node phases on
//    `Comm(d)`; node n's inter-node phases on the shared `Link(n)`;
//  - an All-to-All is a barrier collective: consumers depend on every
//    phase task (per-device intra + per-node inter);
//  - dispatch tasks (`A2A-D*`) take durations from the dispatch phase
//    vectors; combine tasks (`A2A-C*`) from `TopoCosts::a2a_*_combine`,
//    which fall back to the dispatch phases when routing is symmetric —
//    routed placements thus expose asymmetric forward/return traffic
//    without forking the builders;
//  - with `chunks > 1` every chunk's durations come from
//    `TopoCosts::chunk_phases` (token-true under routed costs; α-true
//    analytic otherwise) and the uplink tasks are staged behind the
//    node's intra tasks per `ChunkPipelining`; with one chunk the
//    builders keep the seed's enc-barrier phase layout and full-phase
//    durations bit-exactly;
//  - task insertion order matches the legacy single-device builders, so a
//    one-device `TopoCosts` yields the identical task graph (same ids,
//    deps, durations) and therefore bit-exact spans.
// ---------------------------------------------------------------------------

/// Per-device sequential baseline over the fleet (cf. `build_sequential`).
fn build_sequential_topo(tc: &TopoCosts, kind: MoEKind, k: usize) -> PairSchedule {
    let n = tc.n_devices();
    let n_links = tc.a2a_inter_k1.len();
    let mut sim = Sim::new();
    let mut attn_m = Vec::with_capacity(n);
    let mut enc = Vec::with_capacity(n);
    for d in 0..n {
        let c = &tc.per_device[d];
        let attn_l = sim.add("Attn(l)", Resource::Compute(d), c.attn, &[]);
        let mlp_l = sim.add("MLP(l)", Resource::Compute(d), c.mlp, &[attn_l]);
        let a_m = sim.add("Attn(l+1)", Resource::Compute(d), c.attn, &[mlp_l]);
        let gate = sim.add("Gate", Resource::Compute(d), c.gate, &[a_m]);
        let e = sim.add("Encode", Resource::Compute(d), c.encode, &[gate]);
        attn_m.push(a_m);
        enc.push(e);
    }
    let mut disp = Vec::with_capacity(n + n_links);
    for d in 0..n {
        disp.push(sim.add("A2A-D", Resource::Comm(d), tc.a2a_intra(d, k), &[enc[d]]));
    }
    for node in 0..n_links {
        let deps: Vec<TaskId> = tc.devices_of(node).map(|d| enc[d]).collect();
        disp.push(sim.add("A2A-Dx", Resource::Link(node), tc.a2a_inter(node, k), &deps));
    }
    let mut experts = Vec::with_capacity(n);
    for d in 0..n {
        let c = &tc.per_device[d];
        experts.push(sim.add("Expert", Resource::Compute(d), c.expert(k), &disp));
    }
    let mut comb = Vec::with_capacity(n + n_links);
    for d in 0..n {
        comb.push(sim.add("A2A-C", Resource::Comm(d),
                          tc.a2a_intra_combine(d, k), &[experts[d]]));
    }
    for node in 0..n_links {
        let deps: Vec<TaskId> = tc.devices_of(node).map(|d| experts[d]).collect();
        comb.push(sim.add("A2A-Cx", Resource::Link(node),
                          tc.a2a_inter_combine(node, k), &deps));
    }
    for d in 0..n {
        let c = &tc.per_device[d];
        let mut deps = comb.clone();
        if kind.has_shared_expert() {
            let se = sim.add("SE", Resource::Compute(d), c.se, &[attn_m[d]]);
            deps.push(se);
        }
        sim.add("Decode", Resource::Compute(d), c.decode, &deps);
    }
    PairSchedule { sim, kind, strategy: Strategy::Sequential, expert_slot: 0 }
}

/// One chunk's dispatch phase tasks (intra per device, then inter per
/// node), shared by the chunked topo builders. With `chunks == 1`
/// (`ca == None`) this reproduces the seed's task graph exactly: full
/// phase durations and every phase starting after Encode. With
/// `chunks > 1` durations come from the per-chunk [`ChunkedA2a`] and the
/// uplink is staged behind the node's intra tasks (plus the previous
/// chunk's uplink under `PhaseChained` for the intra tasks).
/// Returns this chunk's task ids (devices first, then links).
#[allow(clippy::too_many_arguments)]
fn add_dispatch_chunk(
    sim: &mut Sim,
    tc: &TopoCosts,
    k: usize,
    i: usize,
    ca: Option<&ChunkedA2a>,
    enc: &[TaskId],
    prev_d: &mut [Option<TaskId>],
    prev_x: &mut [Option<TaskId>],
    pipelining: ChunkPipelining,
) -> Vec<TaskId> {
    let n = tc.n_devices();
    let n_links = tc.a2a_inter_k1.len();
    let mut disp_i = Vec::with_capacity(n + n_links);
    for d in 0..n {
        let mut deps = vec![enc[d]];
        if let Some(p) = prev_d[d] {
            deps.push(p);
        }
        if pipelining == ChunkPipelining::PhaseChained && n_links > 0 {
            if let Some(p) = prev_x[tc.node_of(d)] {
                deps.push(p);
            }
        }
        let dur = match ca {
            Some(ca) => ca.disp_intra[i][d],
            None => tc.a2a_intra(d, k),
        };
        let t = sim.add(format!("A2A-D{i}"), Resource::Comm(d), dur, &deps);
        prev_d[d] = Some(t);
        disp_i.push(t);
    }
    for node in 0..n_links {
        // staged (chunks > 1): the uplink sends what the node's intra
        // phase gathered, so it waits on this chunk's intra tasks; the
        // unchunked collective keeps the seed's enc-barrier semantics
        let mut deps: Vec<TaskId> = match ca {
            Some(_) => tc.devices_of(node).map(|d| disp_i[d]).collect(),
            None => tc.devices_of(node).map(|d| enc[d]).collect(),
        };
        if let Some(p) = prev_x[node] {
            deps.push(p);
        }
        let dur = match ca {
            Some(ca) => ca.disp_inter[i][node],
            None => tc.a2a_inter(node, k),
        };
        let t = sim.add(format!("A2A-Dx{i}"), Resource::Link(node), dur, &deps);
        prev_x[node] = Some(t);
        disp_i.push(t);
    }
    disp_i
}

/// One chunk's combine phase tasks, mirroring [`add_dispatch_chunk`] in
/// the return direction: with `chunks > 1` the uplink-return tasks come
/// first and each device's intra scatter waits on its own node's
/// *outbound* return task — the structural mirror of dispatch's
/// gather-then-send (the node drains its shared return fabric before the
/// local scatter), so chunk i's intra scatter overlaps chunk i+1's
/// uplink. Remote-*arrival* gating is unchanged from the seed: the
/// consumer (`Decode`) barriers on every combine task of every chunk,
/// so no result is consumed before all uplinks finish. `PhaseChained`
/// additionally chains each uplink behind the previous chunk's scatter.
/// `experts_i[d]` is device d's chunk-i expert task; appends all created
/// tasks to `combines` and records this chunk's intra tasks in `prev_c`.
#[allow(clippy::too_many_arguments)]
fn add_combine_chunk(
    sim: &mut Sim,
    tc: &TopoCosts,
    k: usize,
    i: usize,
    ca: Option<&ChunkedA2a>,
    experts_i: &[TaskId],
    prev_c: &mut [Option<TaskId>],
    combines: &mut Vec<TaskId>,
    pipelining: ChunkPipelining,
) {
    let n = tc.n_devices();
    let n_links = tc.a2a_inter_k1.len();
    match ca {
        Some(ca) => {
            let mut comb_x_i = Vec::with_capacity(n_links);
            for node in 0..n_links {
                let mut deps: Vec<TaskId> =
                    tc.devices_of(node).map(|d| experts_i[d]).collect();
                if pipelining == ChunkPipelining::PhaseChained {
                    for d in tc.devices_of(node) {
                        if let Some(p) = prev_c[d] {
                            deps.push(p);
                        }
                    }
                }
                let t = sim.add(format!("A2A-Cx{i}"), Resource::Link(node),
                                ca.comb_inter[i][node], &deps);
                comb_x_i.push(t);
                combines.push(t);
            }
            for d in 0..n {
                let mut deps = vec![experts_i[d]];
                if n_links > 0 {
                    deps.push(comb_x_i[tc.node_of(d)]);
                }
                let t = sim.add(format!("A2A-C{i}"), Resource::Comm(d),
                                ca.comb_intra[i][d], &deps);
                prev_c[d] = Some(t);
                combines.push(t);
            }
        }
        None => {
            for d in 0..n {
                let t = sim.add(format!("A2A-C{i}"), Resource::Comm(d),
                                tc.a2a_intra_combine(d, k), &[experts_i[d]]);
                prev_c[d] = Some(t);
                combines.push(t);
            }
            for node in 0..n_links {
                let deps: Vec<TaskId> =
                    tc.devices_of(node).map(|d| experts_i[d]).collect();
                combines.push(sim.add(format!("A2A-Cx{i}"),
                                      Resource::Link(node),
                                      tc.a2a_inter_combine(node, k), &deps));
            }
        }
    }
}

/// Tutel-style pipelining over the fleet (cf. `build_pipelined`): every
/// chunk's expert computation waits on that chunk's full collective, each
/// chunk pays its own per-link α and bytes (`TopoCosts::chunk_phases` —
/// token-true under routed costs), and the uplink tasks are staged behind
/// the intra phases per [`ChunkPipelining`].
fn build_pipelined_topo(tc: &TopoCosts, kind: MoEKind, k: usize,
                        chunks: usize,
                        pipelining: ChunkPipelining) -> PairSchedule {
    assert!(chunks >= 1);
    let n = tc.n_devices();
    let n_links = tc.a2a_inter_k1.len();
    let mut sim = Sim::new();
    let mut attn_m = Vec::with_capacity(n);
    let mut enc = Vec::with_capacity(n);
    for d in 0..n {
        let c = &tc.per_device[d];
        let attn_l = sim.add("Attn(l)", Resource::Compute(d), c.attn, &[]);
        let mlp_l = sim.add("MLP(l)", Resource::Compute(d), c.mlp, &[attn_l]);
        let a_m = sim.add("Attn(l+1)", Resource::Compute(d), c.attn, &[mlp_l]);
        let gate = sim.add("Gate", Resource::Compute(d), c.gate, &[a_m]);
        let e = sim.add("Encode", Resource::Compute(d), c.encode, &[gate]);
        attn_m.push(a_m);
        enc.push(e);
    }
    let fc = chunks as f64;
    let ca = if chunks > 1 { Some(tc.chunk_phases(k, chunks)) } else { None };
    let mut prev_d: Vec<Option<TaskId>> = vec![None; n];
    let mut prev_x: Vec<Option<TaskId>> = vec![None; n_links];
    let mut prev_c: Vec<Option<TaskId>> = vec![None; n];
    let mut combines: Vec<TaskId> = Vec::new();
    for i in 0..chunks {
        let disp_i = add_dispatch_chunk(&mut sim, tc, k, i, ca.as_ref(), &enc,
                                        &mut prev_d, &mut prev_x, pipelining);
        let mut experts_i = Vec::with_capacity(n);
        for d in 0..n {
            let c = &tc.per_device[d];
            experts_i.push(sim.add(format!("Expert{i}"), Resource::Compute(d),
                                   c.expert(k) / fc, &disp_i));
        }
        add_combine_chunk(&mut sim, tc, k, i, ca.as_ref(), &experts_i,
                          &mut prev_c, &mut combines, pipelining);
    }
    for d in 0..n {
        let c = &tc.per_device[d];
        let mut deps = combines.clone();
        if kind.has_shared_expert() {
            let se = sim.add("SE", Resource::Compute(d), c.se, &[attn_m[d]]);
            deps.push(se);
        }
        sim.add("Decode", Resource::Compute(d), c.decode, &deps);
    }
    PairSchedule { sim, kind, strategy: Strategy::Pipelined { chunks }, expert_slot: 0 }
}

/// The paper's overlapping strategy over the fleet (cf. `build_overlap`):
/// every device hangs its MoE stream off the preceding layer's
/// intermediate and inserts its expert chunks at `slot` in its own
/// backbone window; slow devices stretch the collective for everyone.
/// Chunked dispatch/combine phases follow the same per-chunk α + staging
/// model as [`build_pipelined_topo`].
fn build_overlap_topo(tc: &TopoCosts, kind: MoEKind, k: usize, slot: usize,
                      chunks: usize,
                      pipelining: ChunkPipelining) -> PairSchedule {
    assert!(slot <= 3, "expert slot must be one of the 4 locations");
    assert!(chunks >= 1);
    let n = tc.n_devices();
    let n_links = tc.a2a_inter_k1.len();
    let mut sim = Sim::new();
    let mut attn_l_ids = Vec::with_capacity(n);
    let mut enc = Vec::with_capacity(n);
    for d in 0..n {
        let c = &tc.per_device[d];
        let attn_l = sim.add("Attn(l)", Resource::Compute(d), c.attn, &[]);
        let gate = sim.add("Gate", Resource::Compute(d), c.gate, &[attn_l]);
        let e = sim.add("Encode", Resource::Compute(d), c.encode, &[gate]);
        attn_l_ids.push(attn_l);
        enc.push(e);
    }
    let fc = chunks as f64;
    let ca = if chunks > 1 { Some(tc.chunk_phases(k, chunks)) } else { None };
    let mut disp_chunks: Vec<Vec<TaskId>> = Vec::with_capacity(chunks);
    let mut prev_d: Vec<Option<TaskId>> = vec![None; n];
    let mut prev_x: Vec<Option<TaskId>> = vec![None; n_links];
    for i in 0..chunks {
        disp_chunks.push(add_dispatch_chunk(&mut sim, tc, k, i, ca.as_ref(),
                                            &enc, &mut prev_d, &mut prev_x,
                                            pipelining));
    }
    // per-device backbone window with expert chunks inserted at `slot`
    let mut last_backbone: Vec<TaskId> = vec![0; n];
    let mut experts_by_dev: Vec<Vec<TaskId>> = Vec::with_capacity(n);
    for d in 0..n {
        let c = &tc.per_device[d];
        let mut dev_experts = Vec::with_capacity(chunks);
        let place = |sim: &mut Sim, after: TaskId,
                     out: &mut Vec<TaskId>| -> TaskId {
            let mut tail = after;
            for (i, disp_i) in disp_chunks.iter().enumerate() {
                let mut deps = disp_i.clone();
                deps.push(tail);
                let e = sim.add(format!("Expert{i}"), Resource::Compute(d),
                                c.expert(k) / fc, &deps);
                out.push(e);
                tail = e;
            }
            tail
        };
        let mut tail = attn_l_ids[d];
        if slot == 0 {
            tail = place(&mut sim, tail, &mut dev_experts);
        }
        let window: [(&str, f64); 3] = [
            ("MLP(l)", c.mlp),
            ("Attn(l+1)", c.attn),
            ("SE(l+1)", c.se),
        ];
        for (wi, (label, dur)) in window.iter().enumerate() {
            tail = sim.add(*label, Resource::Compute(d), *dur, &[tail]);
            if slot == wi + 1 {
                tail = place(&mut sim, tail, &mut dev_experts);
            }
        }
        last_backbone[d] = tail;
        experts_by_dev.push(dev_experts);
    }
    let mut prev_c: Vec<Option<TaskId>> = vec![None; n];
    let mut combines: Vec<TaskId> = Vec::new();
    for i in 0..chunks {
        let experts_i: Vec<TaskId> =
            (0..n).map(|d| experts_by_dev[d][i]).collect();
        add_combine_chunk(&mut sim, tc, k, i, ca.as_ref(), &experts_i,
                          &mut prev_c, &mut combines, pipelining);
    }
    for d in 0..n {
        let c = &tc.per_device[d];
        let mut deps = combines.clone();
        deps.push(last_backbone[d]);
        sim.add("Decode", Resource::Compute(d), c.decode, &deps);
    }
    let strategy = if chunks == 1 {
        Strategy::Overlap
    } else {
        Strategy::OverlapPipelined { chunks }
    };
    PairSchedule { sim, kind, strategy, expert_slot: slot }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(a2a: f64) -> BlockCosts {
        BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: a2a,
            a2a_alpha_k1: a2a / 16.0,
        }
    }

    #[test]
    fn sequential_is_sum_of_chain() {
        let c = costs(0.5);
        let s = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let expect = c.attn + c.mlp + c.attn
            + c.gate + c.encode + c.a2a(2) + c.expert(2) + c.a2a(2) + c.decode;
        assert!((s.makespan() - expect).abs() < 1e-12);
    }

    #[test]
    fn pipelining_beats_sequential_with_comm() {
        let c = costs(0.5);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 4 }, 0);
        assert!(pipe.makespan() < seq.makespan());
    }

    #[test]
    fn pipeline_one_chunk_equals_sequential_topk() {
        let c = costs(0.3);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe1 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                        Strategy::Pipelined { chunks: 1 }, 0);
        assert!((pipe1.makespan() - seq.makespan()).abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_small_comm_completely() {
        let c = costs(0.1); // comm well under the window
        let kind = MoEKind::ScMoE { k: 1 };
        let s = build_pair_schedule_auto(&c, kind, Strategy::Overlap);
        // full overlap: makespan = backbone + gate-side overhead + expert + decode
        let serial_no_comm = backbone_time(&c, kind) + c.expert(1) + c.decode;
        assert!(s.makespan() <= serial_no_comm + c.gate + c.encode + 1e-9,
                "makespan {} vs {}", s.makespan(), serial_no_comm);
    }

    #[test]
    fn overlap_beats_pipelined_top2_when_comm_heavy() {
        let c = costs(0.8); // PCIe-like: comm ≈ 60% of MoE time
        let top2 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 2 }, 0);
        let sc = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(sc.makespan() < top2.makespan());
    }

    #[test]
    fn all_slots_produce_valid_schedules() {
        let c = costs(0.5);
        for slot in 0..4 {
            let s = build_pair_schedule(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap, slot);
            let spans = s.run();
            assert!(!spans.is_empty());
            // compute stream never overlaps itself
            let mut comp_spans: Vec<_> = spans.iter()
                .filter(|sp| matches!(sp.resource, Resource::Compute(_)))
                .collect();
            comp_spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in comp_spans.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12,
                        "compute overlap: {:?} then {:?}", w[0].label, w[1].label);
            }
        }
    }

    fn homogeneous_topo(c: &BlockCosts, n: usize, devices_per_node: usize,
                        inter_k1: f64) -> TopoCosts {
        let n_nodes = n / devices_per_node;
        TopoCosts {
            per_device: vec![c.clone(); n],
            a2a_intra_k1: vec![c.a2a_k1; n],
            a2a_inter_k1: if n_nodes > 1 { vec![inter_k1; n_nodes] } else { Vec::new() },
            a2a_intra_combine_k1: Vec::new(),
            a2a_inter_combine_k1: Vec::new(),
            a2a_intra_alpha_k1: vec![c.a2a_alpha_k1; n],
            a2a_inter_alpha_k1: if n_nodes > 1 {
                vec![inter_k1 / 16.0; n_nodes]
            } else {
                Vec::new()
            },
            a2a_intra_combine_alpha_k1: Vec::new(),
            a2a_inter_combine_alpha_k1: Vec::new(),
            chunk_source: None,
            devices_per_node,
        }
    }

    #[test]
    fn topo_one_device_matches_legacy_graphs() {
        let c = costs(0.45);
        let tc = TopoCosts::from_block(&c);
        for (kind, strat, slot) in [
            (MoEKind::Standard { k: 2 }, Strategy::Sequential, 0),
            (MoEKind::SharedExpert, Strategy::Sequential, 0),
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 3 }, 0),
            (MoEKind::ScMoE { k: 1 }, Strategy::Overlap, 2),
            (MoEKind::ScMoE { k: 2 }, Strategy::OverlapPipelined { chunks: 2 }, 1),
        ] {
            let legacy = build_pair_schedule(&c, kind, strat, slot);
            let topo = build_pair_schedule_topo(&tc, kind, strat, slot);
            let (ls, ts) = (legacy.run(), topo.run());
            assert_eq!(ls.len(), ts.len(), "{kind:?}/{strat:?}");
            for (a, b) in ls.iter().zip(&ts) {
                assert_eq!(a.label, b.label);
                assert_eq!(a.resource, b.resource);
                assert_eq!(a.start, b.start, "{}: start", a.label);
                assert_eq!(a.end, b.end, "{}: end", a.label);
            }
        }
    }

    #[test]
    fn topo_homogeneous_single_node_fleet_matches_legacy_makespan() {
        // N identical devices on one node run the same schedule in
        // lockstep: fleet makespan == representative-device makespan.
        let c = costs(0.5);
        let tc = homogeneous_topo(&c, 4, 4, 0.0);
        for (kind, strat) in [
            (MoEKind::Standard { k: 2 }, Strategy::Sequential),
            (MoEKind::Standard { k: 2 }, Strategy::Pipelined { chunks: 2 }),
        ] {
            let legacy = build_pair_schedule(&c, kind, strat, 0).makespan();
            let topo = build_pair_schedule_topo(&tc, kind, strat, 0).makespan();
            assert!((legacy - topo).abs() < 1e-12,
                    "{kind:?}/{strat:?}: legacy {legacy} topo {topo}");
        }
    }

    #[test]
    fn topo_straggler_device_stretches_the_collective() {
        let c = costs(0.3);
        let mut tc = homogeneous_topo(&c, 4, 4, 0.0);
        // device 3 computes 2x slower: everyone waits at the barrier
        let d3 = &mut tc.per_device[3];
        d3.attn *= 2.0;
        d3.mlp *= 2.0;
        d3.se *= 2.0;
        d3.expert_k1 *= 2.0;
        let uniform = build_pair_schedule_topo(
            &homogeneous_topo(&c, 4, 4, 0.0),
            MoEKind::Standard { k: 2 }, Strategy::Sequential, 0).makespan();
        let straggler = build_pair_schedule_topo(
            &tc, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0).makespan();
        assert!(straggler > uniform + 1e-9,
                "straggler {straggler} vs uniform {uniform}");
    }

    #[test]
    fn topo_inter_node_link_is_contended() {
        // one shared uplink per node: raising the inter phase raises the
        // makespan even when intra phases stay fixed
        let c = costs(0.2);
        let cheap = build_pair_schedule_topo(
            &homogeneous_topo(&c, 4, 2, 0.1),
            MoEKind::Standard { k: 2 }, Strategy::Sequential, 0).makespan();
        let pricey = build_pair_schedule_topo(
            &homogeneous_topo(&c, 4, 2, 1.5),
            MoEKind::Standard { k: 2 }, Strategy::Sequential, 0).makespan();
        assert!(pricey > cheap + 1e-9, "pricey {pricey} vs cheap {cheap}");
        // and the link rows exist in the spans
        let spans = build_pair_schedule_topo(
            &homogeneous_topo(&c, 4, 2, 0.5),
            MoEKind::Standard { k: 2 }, Strategy::Sequential, 0).run();
        assert!(spans.iter().any(|s| matches!(s.resource, Resource::Link(_))));
    }
}
