//! Task-graph builders for one Block-MLP + Block-MoE pair under every
//! architecture × strategy combination in the paper (Fig. 6 timelines,
//! Fig. 8 bars). The graphs run on the `simtime` DES; the same structures
//! drive the real threaded executor (`exec`).
//!
//! Modeling follows the paper: one representative device; computation
//! operators share a single exclusive compute stream; All-to-All runs on a
//! separate comm stream; gate/encode scheduled at the earliest viable
//! position and decode at the latest (§3.2).

use crate::simtime::{Resource, Sim, Span, TaskId};

use super::costs::{BlockCosts, MoEKind, Strategy};

const DEV: usize = 0;

/// A built schedule plus span bookkeeping for rendering and assertions.
pub struct PairSchedule {
    pub sim: Sim,
    pub kind: MoEKind,
    pub strategy: Strategy,
    /// Expert-computation slot chosen (0..=3) when Strategy is Overlap*.
    pub expert_slot: usize,
}

impl PairSchedule {
    pub fn run(&self) -> Vec<Span> {
        self.sim.run()
    }

    pub fn makespan(&self) -> f64 {
        self.sim.makespan()
    }
}

/// Serial compute time of the pair's backbone (no MoE stream at all):
/// Attn(l) + MLP(l) + Attn(l+1) [+ SE(l+1)].
pub fn backbone_time(c: &BlockCosts, kind: MoEKind) -> f64 {
    let se = if kind.has_shared_expert() { c.se } else { 0.0 };
    c.attn + c.mlp + c.attn + se
}

/// Build the schedule for a pair under (kind, strategy).
///
/// `expert_slot` only applies to Overlap strategies; pass
/// `choose_expert_slot` output (or use `build_pair_schedule_auto`).
pub fn build_pair_schedule(
    c: &BlockCosts,
    kind: MoEKind,
    strategy: Strategy,
    expert_slot: usize,
) -> PairSchedule {
    let k = kind.routed_k();
    match strategy {
        Strategy::Sequential => build_sequential(c, kind, k),
        Strategy::Pipelined { chunks } => build_pipelined(c, kind, k, chunks),
        Strategy::Overlap => build_overlap(c, kind, k, expert_slot, 1),
        Strategy::OverlapPipelined { chunks } => {
            build_overlap(c, kind, k, expert_slot, chunks)
        }
    }
}

/// Build with the best expert slot (and, for Overlap strategies on
/// non-shortcut architectures, fall back to the legal strategy).
pub fn build_pair_schedule_auto(c: &BlockCosts, kind: MoEKind,
                                strategy: Strategy) -> PairSchedule {
    match strategy {
        Strategy::Overlap | Strategy::OverlapPipelined { .. } => {
            assert!(matches!(kind, MoEKind::ScMoE { .. }),
                    "overlap strategy requires the shortcut architecture");
            let slot = super::adaptive::choose_expert_slot(c, kind, strategy).0;
            build_pair_schedule(c, kind, strategy, slot)
        }
        _ => build_pair_schedule(c, kind, strategy, 0),
    }
}

fn comp(sim: &mut Sim, label: &str, dur: f64, deps: &[TaskId]) -> TaskId {
    sim.add(label, Resource::Compute(DEV), dur, deps)
}

fn comm(sim: &mut Sim, label: &str, dur: f64, deps: &[TaskId]) -> TaskId {
    sim.add(label, Resource::Comm(DEV), dur, deps)
}

/// Standard top-k / shared-expert, fully sequential (Fig. 6, 1st timeline).
fn build_sequential(c: &BlockCosts, kind: MoEKind, k: usize) -> PairSchedule {
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    let mlp_l = comp(&mut sim, "MLP(l)", c.mlp, &[attn_l]);
    let attn_m = comp(&mut sim, "Attn(l+1)", c.attn, &[mlp_l]);
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_m]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);
    let disp = comm(&mut sim, "A2A-D", c.a2a(k), &[enc]);
    let expert = comp(&mut sim, "Expert", c.expert(k), &[disp]);
    let comb = comm(&mut sim, "A2A-C", c.a2a(k), &[expert]);
    let mut decode_deps = vec![comb];
    if kind.has_shared_expert() {
        // SE computed after attention; serial on the compute stream but can
        // overlap the MoE comm in principle — sequential strategy runs it
        // before the gate for the worst-case baseline.
        let se = comp(&mut sim, "SE", c.se, &[attn_m]);
        decode_deps.push(se);
    }
    let _dec = comp(&mut sim, "Decode", c.decode, &decode_deps);
    PairSchedule { sim, kind, strategy: Strategy::Sequential, expert_slot: 0 }
}

/// Tutel-style pipelining (Fig. 6, 2nd timeline): tokens split into
/// `chunks`; dispatch/expert/combine of different chunks overlap.
fn build_pipelined(c: &BlockCosts, kind: MoEKind, k: usize,
                   chunks: usize) -> PairSchedule {
    assert!(chunks >= 1);
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    let mlp_l = comp(&mut sim, "MLP(l)", c.mlp, &[attn_l]);
    let attn_m = comp(&mut sim, "Attn(l+1)", c.attn, &[mlp_l]);
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_m]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);
    let fc = chunks as f64;
    let mut combines = Vec::new();
    let mut prev_disp: Option<TaskId> = None;
    for i in 0..chunks {
        let dd = match prev_disp {
            Some(p) => vec![enc, p],
            None => vec![enc],
        };
        let disp = comm(&mut sim, &format!("A2A-D{i}"), c.a2a(k) / fc, &dd);
        prev_disp = Some(disp);
        let expert = comp(&mut sim, &format!("Expert{i}"), c.expert(k) / fc, &[disp]);
        let comb = comm(&mut sim, &format!("A2A-C{i}"), c.a2a(k) / fc, &[expert]);
        combines.push(comb);
    }
    let mut decode_deps = combines;
    if kind.has_shared_expert() {
        // shared-expert MoE overlaps SE with the MoE stream's comm
        let se = comp(&mut sim, "SE", c.se, &[attn_m]);
        decode_deps.push(se);
    }
    let _dec = comp(&mut sim, "Decode", c.decode, &decode_deps);
    PairSchedule { sim, kind, strategy: Strategy::Pipelined { chunks }, expert_slot: 0 }
}

/// The paper's overlapping strategy (Fig. 6, 4th/5th timelines): the MoE
/// stream hangs off the *preceding layer's* intermediate representation
/// (Pos-2 shortcut), so its comm overlaps MLP(l) + Attn(l+1) + SE(l+1).
/// Expert computation is inserted in one of 4 slots of the backbone
/// stream; with `chunks > 1` the dispatch/expert/combine are additionally
/// pipelined inside the window.
fn build_overlap(c: &BlockCosts, kind: MoEKind, k: usize, slot: usize,
                 chunks: usize) -> PairSchedule {
    assert!(slot <= 3, "expert slot must be one of the 4 locations");
    assert!(chunks >= 1);
    let mut sim = Sim::new();
    let attn_l = comp(&mut sim, "Attn(l)", c.attn, &[]);
    // MoE stream: gate + encode at the earliest viable position — right
    // after the preceding layer's attention (Pos-2 shortcut input).
    let gate = comp(&mut sim, "Gate", c.gate, &[attn_l]);
    let enc = comp(&mut sim, "Encode", c.encode, &[gate]);

    // Backbone window ops (COMP_1..COMP_3 of Eq. 11); the expert
    // computation occupies one of the 4 slots around them.
    // slot 0: before MLP(l); 1: after MLP(l); 2: after Attn(l+1);
    // slot 3: after SE(l+1).
    let fc = chunks as f64;
    let mut dispatches = Vec::new();
    let mut prev: Option<TaskId> = None;
    for i in 0..chunks {
        let deps = match prev {
            Some(p) => vec![enc, p],
            None => vec![enc],
        };
        let d = comm(&mut sim, &format!("A2A-D{i}"), c.a2a(k) / fc, &deps);
        dispatches.push(d);
        prev = Some(d);
    }

    // backbone ops, inserting expert chunks at `slot`
    let mut experts: Vec<TaskId> = Vec::new();
    let mut last_backbone = attn_l;
    let window: [(&str, f64); 3] = [
        ("MLP(l)", c.mlp),
        ("Attn(l+1)", c.attn),
        ("SE(l+1)", c.se),
    ];
    let mut place_experts = |sim: &mut Sim, after: TaskId| -> TaskId {
        let mut tail = after;
        for (i, d) in dispatches.iter().enumerate() {
            let e = comp(sim, &format!("Expert{i}"),
                         c.expert(k) / fc, &[*d, tail]);
            experts.push(e);
            tail = e;
        }
        tail
    };

    if slot == 0 {
        last_backbone = place_experts(&mut sim, last_backbone);
    }
    for (i, (label, dur)) in window.iter().enumerate() {
        last_backbone = comp(&mut sim, label, *dur, &[last_backbone]);
        if slot == i + 1 {
            last_backbone = place_experts(&mut sim, last_backbone);
        }
    }

    // combines: chunk i's combine depends on its expert; comm stream FIFO
    let mut combines = Vec::new();
    for (i, e) in experts.iter().enumerate() {
        combines.push(comm(&mut sim, &format!("A2A-C{i}"), c.a2a(k) / fc, &[*e]));
    }
    // decode at the latest position: after the backbone and all combines
    let mut deps = combines;
    deps.push(last_backbone);
    let _dec = comp(&mut sim, "Decode", c.decode, &deps);
    let strategy = if chunks == 1 {
        Strategy::Overlap
    } else {
        Strategy::OverlapPipelined { chunks }
    };
    PairSchedule { sim, kind, strategy, expert_slot: slot }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn costs(a2a: f64) -> BlockCosts {
        BlockCosts {
            attn: 1.0, mlp: 0.8, se: 0.8, gate: 0.05, encode: 0.05,
            decode: 0.05, expert_k1: 0.6, a2a_k1: a2a,
        }
    }

    #[test]
    fn sequential_is_sum_of_chain() {
        let c = costs(0.5);
        let s = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let expect = c.attn + c.mlp + c.attn
            + c.gate + c.encode + c.a2a(2) + c.expert(2) + c.a2a(2) + c.decode;
        assert!((s.makespan() - expect).abs() < 1e-12);
    }

    #[test]
    fn pipelining_beats_sequential_with_comm() {
        let c = costs(0.5);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 4 }, 0);
        assert!(pipe.makespan() < seq.makespan());
    }

    #[test]
    fn pipeline_one_chunk_equals_sequential_topk() {
        let c = costs(0.3);
        let seq = build_pair_schedule(&c, MoEKind::Standard { k: 2 }, Strategy::Sequential, 0);
        let pipe1 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                        Strategy::Pipelined { chunks: 1 }, 0);
        assert!((pipe1.makespan() - seq.makespan()).abs() < 1e-12);
    }

    #[test]
    fn overlap_hides_small_comm_completely() {
        let c = costs(0.1); // comm well under the window
        let kind = MoEKind::ScMoE { k: 1 };
        let s = build_pair_schedule_auto(&c, kind, Strategy::Overlap);
        // full overlap: makespan = backbone + gate-side overhead + expert + decode
        let serial_no_comm = backbone_time(&c, kind) + c.expert(1) + c.decode;
        assert!(s.makespan() <= serial_no_comm + c.gate + c.encode + 1e-9,
                "makespan {} vs {}", s.makespan(), serial_no_comm);
    }

    #[test]
    fn overlap_beats_pipelined_top2_when_comm_heavy() {
        let c = costs(0.8); // PCIe-like: comm ≈ 60% of MoE time
        let top2 = build_pair_schedule(&c, MoEKind::Standard { k: 2 },
                                       Strategy::Pipelined { chunks: 2 }, 0);
        let sc = build_pair_schedule_auto(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap);
        assert!(sc.makespan() < top2.makespan());
    }

    #[test]
    fn all_slots_produce_valid_schedules() {
        let c = costs(0.5);
        for slot in 0..4 {
            let s = build_pair_schedule(&c, MoEKind::ScMoE { k: 1 }, Strategy::Overlap, slot);
            let spans = s.run();
            assert!(!spans.is_empty());
            // compute stream never overlaps itself
            let mut comp_spans: Vec<_> = spans.iter()
                .filter(|sp| matches!(sp.resource, Resource::Compute(_)))
                .collect();
            comp_spans.sort_by(|a, b| a.start.partial_cmp(&b.start).unwrap());
            for w in comp_spans.windows(2) {
                assert!(w[1].start >= w[0].end - 1e-12,
                        "compute overlap: {:?} then {:?}", w[0].label, w[1].label);
            }
        }
    }
}
