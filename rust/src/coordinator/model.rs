//! Whole-model composition: L MoE layers × S pipeline stages × M
//! microbatches in one DES timeline.
//!
//! The single-pair core ([`ScheduleSpec::build`]) prices exactly one
//! Block-MLP + Block-MoE pair; this module composes per-layer,
//! per-microbatch pair graphs into an L-layer model timeline the way
//! Pipeline-MoE (arXiv:2304.11414) runs one: layers are divided over
//! `stages` pipeline stages (each stage owns its own device fleet —
//! disjoint compute/comm/link/transfer engines), the token batch splits
//! into `microbatches` contiguous token ranges, and a
//! [`PipelineSchedule`] decides which (layer, microbatch) graphs may
//! overlap. Layer-*l* A2A then genuinely overlaps layer-*l±1* expert
//! compute whenever the two graphs sit on different stages — the ScMoE
//! shortcut generalized across depth.
//!
//! Composition is by *graph embedding*: each pair graph's tasks are
//! appended to one big [`Sim`] with their resources remapped onto the
//! owning stage's engines, their in-graph dependencies offset, and
//! their dependency-free roots chained behind the join tasks of
//! whatever graphs the pipeline schedule says must come first. A
//! zero-duration [`Resource::Free`] join task per graph
//! (`Join-L{l}M{m}`) gives downstream graphs a single handle. With
//! L = S = M = 1 nothing is remapped and nothing is chained, so the
//! model timeline reduces bit-exactly to the single-pair schedule —
//! and [`run_model_timeline`] to
//! [`run_replace_timeline`](super::replace::run_replace_timeline),
//! field for field (pinned in `rust/tests/model_timeline.rs` and mirror
//! `consistency_checks8`).
//!
//! Across layers the data plane is chained in the ExFlow
//! (arXiv:2401.08383) execution model: a token's layer-*l* activations
//! live on whichever device ran its layer-*l−1* primary expert, so
//! layer *l*'s dispatch matrix is priced from those *chained sources*
//! ([`TopoCosts::from_routing_with_sources`]) instead of the even
//! home split. That is what makes placement a *cross-layer* problem:
//! [`run_model_timeline`] learns one
//! [`AffinityEstimator`](crate::moe::AffinityEstimator) per layer plus
//! one inter-layer [`TransitionEstimator`](crate::moe::TransitionEstimator)
//! per adjacent pair, and [`PlacementMode::CrossLayer`] packs each
//! layer against the previous layer's (candidate) placement via
//! [`co_placed`](crate::moe::co_placed). Migrations span layers: each
//! layer's [`MigrationPlan`] lands on its own stage's transfer engines
//! (offset D2H/H2D resources), all overlapping the same step.

use crate::cluster::{LinkModel, Topology};
use crate::moe::{co_placed, AffinityEstimator, Placement, RoutingTable,
                 TransitionEstimator};
use crate::simtime::{Resource, Sim, SimArena, TaskId};

use super::costs::{ComputeCosts, TopoCosts};
use super::replace::{MigrationPlan, ReplacePolicy};
use super::spec::ScheduleSpec;

/// Which (layer, microbatch) pair graphs may overlap.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PipelineSchedule {
    /// No pipelining: layer l+1 starts only after *every* microbatch of
    /// layer l joined (the depth-sequential baseline — M contiguous
    /// chunks of one barrier-synchronized model).
    LayerSequential,
    /// GPipe-style: microbatch m enters layer l as soon as *its own*
    /// layer-l−1 graph joined, so different microbatches occupy
    /// different stages concurrently (fill/drain bubbles at the ends).
    GPipe,
    /// 1F1B-style steady state: GPipe's dependencies plus a bounded
    /// in-flight window — microbatch m may enter the first stage only
    /// once microbatch m−S drained from the last, capping concurrent
    /// microbatches at the stage count S (the 1F1B memory bound).
    OneFOneB,
}

impl PipelineSchedule {
    /// Display label for study tables.
    pub fn label(&self) -> &'static str {
        match self {
            PipelineSchedule::LayerSequential => "layerseq",
            PipelineSchedule::GPipe => "gpipe",
            PipelineSchedule::OneFOneB => "1f1b",
        }
    }
}

/// How [`run_model_timeline`] derives candidate placements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementMode {
    /// Independent per-layer affinity packing: each layer's estimator
    /// feeds `affinity_packed_measured` on its own.
    PerLayer,
    /// ExFlow-style cross-layer co-placement: layer 0 packs per-layer,
    /// every later layer packs via [`co_placed`] against the previous
    /// layer's candidate and the measured inter-layer transitions.
    CrossLayer,
}

impl PlacementMode {
    /// Display label for study tables.
    pub fn label(&self) -> &'static str {
        match self {
            PlacementMode::PerLayer => "per-layer",
            PlacementMode::CrossLayer => "cross-layer",
        }
    }
}

/// The whole-model geometry: one [`ScheduleSpec`] per layer, a stage
/// count dividing the layers, a microbatch count splitting the tokens,
/// and the pipeline schedule composing the graphs.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    /// Per-layer pair-schedule specs, outermost-first. Layer l's graph
    /// is built from `layers[l]` against that layer's routed costs.
    pub layers: Vec<ScheduleSpec>,
    /// Pipeline stages; must divide `layers.len()`. Stage σ owns layers
    /// `[σ·L/S, (σ+1)·L/S)` and its own device fleet (every engine
    /// index offset by σ × fleet size).
    pub stages: usize,
    /// Contiguous token ranges the batch splits into
    /// ([`RoutingTable::chunk`]); 1 = the whole batch at once.
    pub microbatches: usize,
    /// Which (layer, microbatch) graphs may overlap.
    pub schedule: PipelineSchedule,
}

impl ModelSpec {
    /// Geometry sanity: at least one layer/stage/microbatch, stages
    /// dividing layers evenly.
    pub fn validate(&self) {
        assert!(!self.layers.is_empty(), "a model needs at least one layer");
        assert!(self.stages >= 1 && self.microbatches >= 1);
        assert!(self.layers.len() % self.stages == 0,
                "layers ({}) must divide into {} pipeline stages",
                self.layers.len(), self.stages);
    }

    /// Layers per pipeline stage.
    pub fn layers_per_stage(&self) -> usize {
        self.layers.len() / self.stages
    }

    /// Stage owning a layer.
    pub fn stage_of(&self, layer: usize) -> usize {
        layer / self.layers_per_stage()
    }
}

/// Remap a pair-graph resource onto its stage's engines: device-indexed
/// engines shift by `stage × devices_per_stage`, node-indexed links by
/// `stage × nodes_per_stage`, `Free` stays free.
fn remap_resource(res: Resource, stage: usize, devices_per_stage: usize,
                  nodes_per_stage: usize) -> Resource {
    let d = stage * devices_per_stage;
    let n = stage * nodes_per_stage;
    match res {
        Resource::Compute(i) => Resource::Compute(i + d),
        Resource::Comm(i) => Resource::Comm(i + d),
        Resource::H2D(i) => Resource::H2D(i + d),
        Resource::D2H(i) => Resource::D2H(i + d),
        Resource::Link(i) => Resource::Link(i + n),
        Resource::Free => Resource::Free,
    }
}

/// Compose per-(layer, microbatch) pair graphs into one model Sim.
///
/// `costs[l][m]` prices layer l's schedule over microbatch m; each
/// graph is built with `spec.layers[l]`, embedded with its resources
/// remapped onto stage `spec.stage_of(l)`'s engines, its dependency-free
/// roots chained behind the joins the [`PipelineSchedule`] requires,
/// and capped with a zero-duration `Join-L{l}M{m}` task depending on
/// every task of the graph. Returns the Sim plus the join id per
/// (layer, microbatch).
///
/// Insertion order is semantic (the DES breaks readiness ties by task
/// id) and schedule-dependent by necessity: [`PipelineSchedule::LayerSequential`]
/// inserts layer-major (all microbatches of layer l before layer l+1),
/// the pipelined schedules microbatch-major — under 1F1B, microbatch
/// m's *first* layer depends on microbatch m−S's *last*, which only
/// exists by insertion time in microbatch-major order (the DES rejects
/// forward dependencies).
pub fn build_model_sim(spec: &ModelSpec, costs: &[Vec<TopoCosts>],
                       devices_per_stage: usize,
                       nodes_per_stage: usize) -> (Sim, Vec<Vec<TaskId>>) {
    spec.validate();
    let n_layers = spec.layers.len();
    let m = spec.microbatches;
    assert_eq!(costs.len(), n_layers, "one cost row per layer");
    for row in costs {
        assert_eq!(row.len(), m, "one cost model per (layer, microbatch)");
    }
    let mut sim = Sim::new();
    let mut joins: Vec<Vec<TaskId>> = vec![vec![0; m]; n_layers];
    // layer-pair skeletons repeat across microbatches (and across layers
    // sharing a spec), so the inner builds warm-start from the arena
    let mut arena = SimArena::new();
    let mut embed = |sim: &mut Sim, joins: &mut Vec<Vec<TaskId>>,
                     arena: &mut SimArena, l: usize, mb: usize| {
        let mut roots: Vec<TaskId> = match spec.schedule {
            PipelineSchedule::LayerSequential => {
                if l > 0 { joins[l - 1].clone() } else { Vec::new() }
            }
            PipelineSchedule::GPipe | PipelineSchedule::OneFOneB => {
                if l > 0 { vec![joins[l - 1][mb]] } else { Vec::new() }
            }
        };
        if spec.schedule == PipelineSchedule::OneFOneB
            && l == 0
            && mb >= spec.stages
        {
            roots.push(joins[n_layers - 1][mb - spec.stages]);
        }
        let stage = spec.stage_of(l);
        spec.layers[l].build_into(&costs[l][mb], arena);
        let off = sim.len();
        let count = arena.sim().len();
        for t in arena.sim().tasks() {
            let deps: Vec<TaskId> = if t.deps.is_empty() {
                roots.clone()
            } else {
                t.deps.iter().map(|&d| d + off).collect()
            };
            sim.add(t.label.clone(),
                    remap_resource(t.resource, stage, devices_per_stage,
                                   nodes_per_stage),
                    t.duration, &deps);
        }
        let all: Vec<TaskId> = (off..off + count).collect();
        joins[l][mb] =
            sim.add(format!("Join-L{l}M{mb}"), Resource::Free, 0.0, &all);
    };
    match spec.schedule {
        PipelineSchedule::LayerSequential => {
            for l in 0..n_layers {
                for mb in 0..m {
                    embed(&mut sim, &mut joins, &mut arena, l, mb);
                }
            }
        }
        PipelineSchedule::GPipe | PipelineSchedule::OneFOneB => {
            for mb in 0..m {
                for l in 0..n_layers {
                    embed(&mut sim, &mut joins, &mut arena, l, mb);
                }
            }
        }
    }
    (sim, joins)
}

/// Where each token's activations sit when a layer dispatches, given
/// the *previous* layer's routing and placement: the device owning the
/// token's previous primary expert, or (for tokens whose primary route
/// dropped) the token's home device under the even index-order split.
pub fn chained_sources(prev: &RoutingTable,
                       prev_placement: &Placement) -> Vec<usize> {
    let n_devices = prev_placement.n_devices;
    let tokens_per_device = prev.n_tokens.div_ceil(n_devices);
    prev.primary_experts()
        .iter()
        .enumerate()
        .map(|(t, p)| match p {
            Some(e) => prev_placement.device_of(*e),
            None => (t / tokens_per_device).min(n_devices - 1),
        })
        .collect()
}

/// Per-(layer, microbatch) routed costs for one model step: layer 0
/// prices from home sources, every later layer from the chained
/// sources its predecessor's placement implies; with `microbatches > 1`
/// each layer's table splits into contiguous token ranges
/// ([`RoutingTable::chunk`] — parts keep parent token ids, so one
/// source vector per layer serves every part).
pub fn model_layer_costs(base: &ComputeCosts, topo: &Topology,
                         token_bytes: usize,
                         layer_tables: &[RoutingTable],
                         placements: &[Placement],
                         microbatches: usize) -> Vec<Vec<TopoCosts>> {
    assert_eq!(layer_tables.len(), placements.len(),
               "one placement per layer");
    let mut out = Vec::with_capacity(layer_tables.len());
    for (l, rt) in layer_tables.iter().enumerate() {
        let sources: Option<Vec<usize>> = if l == 0 {
            None
        } else {
            Some(chained_sources(&layer_tables[l - 1], &placements[l - 1]))
        };
        let cost_of = |part: &RoutingTable| {
            TopoCosts::from_routing_with_sources(base, topo, part,
                                                 &placements[l], token_bytes,
                                                 sources.as_deref())
        };
        let row = if microbatches == 1 {
            vec![cost_of(rt)]
        } else {
            rt.chunk(microbatches).iter().map(cost_of).collect()
        };
        out.push(row);
    }
    out
}

/// Everything a multi-step model timeline needs beyond the routing
/// streams: the model geometry, the migration policy and transfer
/// links, and how candidate placements are derived.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    /// Model geometry + per-layer schedule specs.
    pub spec: ModelSpec,
    /// Migration decision rule (asked once per step for the whole
    /// model's plan set).
    pub policy: ReplacePolicy,
    /// Parameter bytes per migrated expert.
    pub bytes_per_expert: usize,
    /// Host-to-device transfer link (per-stage engines).
    pub h2d: LinkModel,
    /// Optional device-to-host link pricing each move's source-side
    /// read-out (see [`super::replace::ReplaceConfig::d2h_link`]).
    pub d2h: Option<LinkModel>,
    /// Estimator decay for both the per-layer affinity estimators and
    /// the inter-layer transition estimators.
    pub decay: f64,
    /// Per-layer vs cross-layer candidate derivation.
    pub mode: PlacementMode,
}

/// One step of a [`ModelOutcome`].
#[derive(Debug, Clone)]
pub struct ModelStepReport {
    /// 0-based step index.
    pub step: usize,
    /// DES makespan of the step's L-layer pipeline, including migration
    /// transfer spans if a migration fired here.
    pub makespan: f64,
    /// Makespan of the pipeline alone (no migration tasks).
    pub base_makespan: f64,
    /// Whether a migration fired during this step (the new placements
    /// take effect from the next step).
    pub migrated: bool,
    /// Bytes moved across all layers' plans (0 when `!migrated`).
    pub migration_bytes: usize,
    /// Slowest layer plan's transfer time (0 when `!migrated`); the
    /// step pays only `max(0, this − base_makespan)`.
    pub migration_time: f64,
}

/// Result of [`run_model_timeline`].
#[derive(Debug, Clone)]
pub struct ModelOutcome {
    /// One report per step, in order.
    pub steps: Vec<ModelStepReport>,
    /// Sum of the per-step makespans (strict step barriers).
    pub total: f64,
    /// Number of steps that fired a migration.
    pub migrations: usize,
    /// Per-layer placements in force after the final step.
    pub final_placements: Vec<Placement>,
}

/// Drive an N-step stream of per-layer routing tables through L-layer
/// pipeline timelines with live (per-layer or cross-layer) re-placement.
///
/// `tables[step][layer]` routes one step; `initial[layer]` seeds the
/// placements. Per step: (1) price every (layer, microbatch) under the
/// placements in force — chained sources included — and build the
/// pipeline Sim; (2) feed every layer's table to its affinity
/// estimator and every adjacent pair to its transition estimator; (3)
/// unless the policy is `Never` or this is the last step, derive
/// candidate placements per [`PlacementMode`], diff per layer, and ask
/// the policy once with the slowest layer plan's transfer time as the
/// migration cost (layers migrate concurrently on their own stages'
/// engines) and — for break-even — the full-model rebuild under the
/// candidates as the saving; (4) on migration, overlap each layer's
/// transfer tasks into *this* step's Sim on its stage's engines.
pub fn run_model_timeline(base: &ComputeCosts, topo: &Topology,
                          token_bytes: usize,
                          tables: &[Vec<RoutingTable>],
                          initial: &[Placement],
                          cfg: &ModelConfig) -> ModelOutcome {
    cfg.spec.validate();
    assert!(!tables.is_empty(), "a timeline needs at least one step");
    let n_layers = cfg.spec.layers.len();
    assert_eq!(initial.len(), n_layers, "one initial placement per layer");
    for row in tables {
        assert_eq!(row.len(), n_layers, "one table per layer per step");
    }
    let n_nodes = topo.n_devices / topo.devices_per_node;
    let mut ests: Vec<AffinityEstimator> = initial
        .iter()
        .map(|p| AffinityEstimator::ewma(p.n_experts, n_nodes, cfg.decay))
        .collect();
    let mut trans: Vec<TransitionEstimator> = (0..n_layers.saturating_sub(1))
        .map(|l| TransitionEstimator::ewma(initial[l].n_experts, cfg.decay))
        .collect();
    let mut placements: Vec<Placement> = initial.to_vec();
    let mut steps = Vec::with_capacity(tables.len());
    let mut total = 0.0f64;
    let mut migrations = 0usize;
    let n_steps = tables.len();
    let candidates_of = |ests: &[AffinityEstimator],
                        trans: &[TransitionEstimator]| -> Vec<Placement> {
        match cfg.mode {
            PlacementMode::PerLayer => ests
                .iter()
                .map(|e| e.packed(topo.n_devices, topo.devices_per_node))
                .collect(),
            PlacementMode::CrossLayer => {
                let mut out = Vec::with_capacity(n_layers);
                out.push(ests[0].packed(topo.n_devices,
                                        topo.devices_per_node));
                for l in 1..n_layers {
                    let prev = out[l - 1].clone();
                    out.push(co_placed(ests[l].matrix(), &trans[l - 1],
                                       &prev, topo.n_devices,
                                       topo.devices_per_node));
                }
                out
            }
        }
    };
    for (s, layer_tables) in tables.iter().enumerate() {
        let costs = model_layer_costs(base, topo, token_bytes, layer_tables,
                                      &placements, cfg.spec.microbatches);
        let (mut sim, _joins) = build_model_sim(&cfg.spec, &costs,
                                                topo.n_devices, n_nodes);
        let base_makespan = sim.makespan();
        for (l, rt) in layer_tables.iter().enumerate() {
            ests[l].observe(rt, topo.n_devices, topo.devices_per_node);
        }
        for l in 0..n_layers.saturating_sub(1) {
            trans[l].observe(&layer_tables[l], &layer_tables[l + 1]);
        }
        let remaining = n_steps - s - 1;
        let mut migrated = false;
        let mut migration_bytes = 0usize;
        let mut migration_time = 0.0f64;
        if remaining > 0 && cfg.policy != ReplacePolicy::Never {
            let candidates = candidates_of(&ests, &trans);
            let plans: Vec<MigrationPlan> = (0..n_layers)
                .map(|l| MigrationPlan::between(&placements[l],
                                                &candidates[l],
                                                cfg.bytes_per_expert))
                .collect();
            if plans.iter().any(|p| !p.is_empty()) {
                // layers migrate concurrently on their own stages'
                // engines, so the model-level transfer time is the
                // slowest layer plan's
                let mig = plans
                    .iter()
                    .map(|p| p.transfer_time(&cfg.h2d, cfg.d2h.as_ref()))
                    .fold(0.0f64, f64::max);
                let overhead = (mig - base_makespan).max(0.0);
                let saving = match cfg.policy {
                    ReplacePolicy::BreakEven => {
                        let cand_costs = model_layer_costs(
                            base, topo, token_bytes, layer_tables,
                            &candidates, cfg.spec.microbatches);
                        let (cand_sim, _) = build_model_sim(
                            &cfg.spec, &cand_costs, topo.n_devices, n_nodes);
                        base_makespan - cand_sim.makespan()
                    }
                    _ => 0.0,
                };
                if cfg.policy.should_migrate(s, remaining, saving, overhead) {
                    for (l, plan) in plans.iter().enumerate() {
                        if !plan.is_empty() {
                            plan.add_transfer_tasks(
                                &mut sim, &cfg.h2d, cfg.d2h.as_ref(),
                                cfg.spec.stage_of(l) * topo.n_devices);
                        }
                    }
                    migrated = true;
                    migration_bytes =
                        plans.iter().map(|p| p.total_bytes()).sum();
                    migration_time = mig;
                    placements = candidates;
                    migrations += 1;
                }
            }
        }
        let makespan = if migrated { sim.makespan() } else { base_makespan };
        total += makespan;
        steps.push(ModelStepReport {
            step: s,
            makespan,
            base_makespan,
            migrated,
            migration_bytes,
            migration_time,
        });
    }
    ModelOutcome { steps, total, migrations, final_placements: placements }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LinkModel;
    use crate::coordinator::costs::{MoEKind, Strategy};

    fn dyadic_topo() -> Topology {
        Topology {
            n_devices: 4,
            devices_per_node: 2,
            intra: LinkModel::new(0.0625, 1024.0),
            inter: Some(LinkModel::new(0.125, 512.0)),
            compute_scale: 1.0,
            device_scales: None,
            node_intra: None,
        }
    }

    fn dyadic_base() -> ComputeCosts {
        ComputeCosts {
            attn: 1.0,
            mlp: 0.75,
            se: 0.75,
            gate: 0.0625,
            encode: 0.0625,
            decode: 0.0625,
            expert_k1: 0.5,
        }
    }

    fn corpus_table() -> RoutingTable {
        let idx: Vec<i32> =
            vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let w = vec![1.0f32; 16];
        RoutingTable::build(&idx, &w, 16, 1, 4, 16)
    }

    fn seq_spec() -> ScheduleSpec {
        ScheduleSpec::new(MoEKind::ScMoE { k: 1 }, Strategy::Sequential)
    }

    fn model_spec(layers: usize, stages: usize, microbatches: usize,
                  schedule: PipelineSchedule) -> ModelSpec {
        ModelSpec {
            layers: vec![seq_spec(); layers],
            stages,
            microbatches,
            schedule,
        }
    }

    #[test]
    fn trivial_model_reduces_to_the_pair_schedule() {
        let rt = corpus_table();
        let p = Placement::new(4, 4);
        let costs = model_layer_costs(&dyadic_base(), &dyadic_topo(), 64,
                                      &[rt.clone()], &[p.clone()], 1);
        let spec = model_spec(1, 1, 1, PipelineSchedule::LayerSequential);
        let (sim, joins) = build_model_sim(&spec, &costs, 4, 2);
        let pair = seq_spec().build(&costs[0][0]);
        assert_eq!(sim.len(), pair.sim.len() + 1, "one extra Join task");
        assert_eq!(sim.makespan(), pair.makespan());
        assert_eq!(joins, vec![vec![pair.sim.len()]]);
        // spans coincide task for task
        let (ms, ps) = (sim.run(), pair.run());
        for (a, b) in ms.iter().zip(&ps) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.resource, b.resource);
            assert_eq!(a.start, b.start);
            assert_eq!(a.end, b.end);
        }
    }

    #[test]
    fn gpipe_equals_layer_sequential_at_one_microbatch() {
        let rt = corpus_table();
        let p = Placement::new(4, 4);
        let tables = vec![rt.clone(), rt.clone()];
        let ps = vec![p.clone(), p.clone()];
        let costs = model_layer_costs(&dyadic_base(), &dyadic_topo(), 64,
                                      &tables, &ps, 1);
        let seq = build_model_sim(
            &model_spec(2, 1, 1, PipelineSchedule::LayerSequential),
            &costs, 4, 2).0;
        let gp = build_model_sim(
            &model_spec(2, 1, 1, PipelineSchedule::GPipe), &costs, 4, 2).0;
        assert_eq!(seq.makespan(), gp.makespan());
    }

    #[test]
    fn pipelining_beats_layer_sequential_across_stages() {
        let rt = corpus_table();
        let p = Placement::new(4, 4);
        let tables = vec![rt.clone(), rt.clone()];
        let ps = vec![p.clone(), p.clone()];
        let costs = model_layer_costs(&dyadic_base(), &dyadic_topo(), 64,
                                      &tables, &ps, 4);
        let mk = |schedule| {
            build_model_sim(&model_spec(2, 2, 4, schedule), &costs, 4, 2)
                .0
                .makespan()
        };
        let seq = mk(PipelineSchedule::LayerSequential);
        let gp = mk(PipelineSchedule::GPipe);
        let fb = mk(PipelineSchedule::OneFOneB);
        assert!(gp < seq, "gpipe {gp} vs layerseq {seq}");
        // 1F1B trades throughput for a bounded in-flight window: on this
        // fleet the cap costs makespan relative to unconstrained GPipe
        assert!(fb >= gp, "1f1b {fb} vs gpipe {gp}");
    }

    #[test]
    fn one_f_one_b_caps_the_in_flight_window() {
        // S = 1: microbatch m's layer 0 must wait for microbatch m-1's
        // last layer, so 1F1B degenerates to layer-sequential per
        // microbatch while GPipe overlaps — 1F1B must be strictly
        // slower than GPipe here and exactly equal to M sequential
        // model passes
        let rt = corpus_table();
        let p = Placement::new(4, 4);
        let tables = vec![rt.clone(), rt.clone()];
        let ps = vec![p.clone(), p.clone()];
        let costs = model_layer_costs(&dyadic_base(), &dyadic_topo(), 64,
                                      &tables, &ps, 2);
        let gp = build_model_sim(
            &model_spec(2, 1, 2, PipelineSchedule::GPipe), &costs, 4, 2)
            .0
            .makespan();
        let fb = build_model_sim(
            &model_spec(2, 1, 2, PipelineSchedule::OneFOneB), &costs, 4, 2)
            .0
            .makespan();
        assert!(fb > gp, "1f1b {fb} must exceed gpipe {gp} at S = 1");
    }

    #[test]
    fn stage_resources_are_disjoint() {
        let rt = corpus_table();
        let p = Placement::new(4, 4);
        let tables = vec![rt.clone(), rt.clone()];
        let ps = vec![p.clone(), p.clone()];
        let costs = model_layer_costs(&dyadic_base(), &dyadic_topo(), 64,
                                      &tables, &ps, 1);
        let (sim, _) = build_model_sim(
            &model_spec(2, 2, 1, PipelineSchedule::GPipe), &costs, 4, 2);
        let mut saw_stage1 = false;
        for sp in sim.run() {
            match sp.resource {
                Resource::Compute(d) | Resource::Comm(d) => {
                    if d >= 4 {
                        saw_stage1 = true;
                        assert!(d < 8);
                    }
                }
                Resource::Link(n) => assert!(n < 4),
                _ => {}
            }
        }
        assert!(saw_stage1, "stage 1's engines must appear");
    }

    #[test]
    fn model_timeline_reduces_to_replace_timeline() {
        use crate::coordinator::replace::{run_replace_timeline,
                                          ReplaceConfig};
        let tables: Vec<RoutingTable> = (0..3).map(|_| corpus_table()).collect();
        let model_tables: Vec<Vec<RoutingTable>> =
            tables.iter().map(|t| vec![t.clone()]).collect();
        let initial = Placement::new(4, 4);
        for policy in [ReplacePolicy::Never, ReplacePolicy::EveryK { k: 2 },
                       ReplacePolicy::BreakEven] {
            let rcfg = ReplaceConfig {
                spec: seq_spec(),
                policy,
                bytes_per_expert: 4096,
                h2d: LinkModel::new(0.125, 1024.0),
                d2h_link: None,
                decay: 1.0,
            };
            let mcfg = ModelConfig {
                spec: model_spec(1, 1, 1, PipelineSchedule::LayerSequential),
                policy,
                bytes_per_expert: 4096,
                h2d: LinkModel::new(0.125, 1024.0),
                d2h: None,
                decay: 1.0,
                mode: PlacementMode::CrossLayer,
            };
            let r = run_replace_timeline(&dyadic_base(), &dyadic_topo(), 64,
                                         &tables, &initial, &rcfg);
            let m = run_model_timeline(&dyadic_base(), &dyadic_topo(), 64,
                                       &model_tables, &[initial.clone()],
                                       &mcfg);
            assert_eq!(r.total, m.total, "{policy:?}");
            assert_eq!(r.migrations, m.migrations);
            for (a, b) in r.steps.iter().zip(&m.steps) {
                assert_eq!(a.makespan, b.makespan);
                assert_eq!(a.base_makespan, b.base_makespan);
                assert_eq!(a.migrated, b.migrated);
                assert_eq!(a.migration_bytes, b.migration_bytes);
                assert_eq!(a.migration_time, b.migration_time);
            }
            for e in 0..4 {
                assert_eq!(r.final_placement.device_of(e),
                           m.final_placements[0].device_of(e));
            }
        }
    }

    #[test]
    #[should_panic(expected = "pipeline stages")]
    fn ragged_stage_split_is_rejected() {
        model_spec(3, 2, 1, PipelineSchedule::GPipe).validate();
    }
}
