//! Phase-aware routing-stream generation for the serving simulator.
//!
//! Serving batches mix two token populations with different routing
//! statistics: *prefill* tokens (full prompts, routed while the planted
//! expert affinity still dominates) and *decode* tokens (single generated
//! tokens, whose routing drifts harder from the learned structure).
//! [`phase_affine_routing`] generates one [`RoutingTable`] for such a
//! mixed batch: the first `prefill_tokens` positions use `prefill_noise`,
//! the remaining `decode_tokens` use `decode_noise`, and both share the
//! node-affine backbone of
//! [`drifting_node_affine_routing`](crate::report::efficiency::drifting_node_affine_routing)
//! — which is the `prefill_noise == decode_noise`, evenly-divisible
//! special case of this generator, bit-exactly (same splitmix64 draw
//! order: one `next_f64` per token, plus one `below` on whichever branch
//! the noise comparison picks).

use crate::util::rng::Rng;

use super::router::RoutingTable;

/// Seeded mixed-phase node-affine routing (k = 1).
///
/// Token sources follow the `RoutingTable::a2a_bytes_placed` convention:
/// the `prefill_tokens + decode_tokens` batch positions are split evenly
/// over devices in index order, so a token's source node is a function of
/// its position. With probability `noise` (per token, phase-dependent)
/// the token routes to a uniformly random expert; otherwise it picks from
/// its source node's affinity group `{e : e % n_nodes == aff_node}` with
/// `aff_node = (node + regime) % n_nodes`. Capacity is sized so nothing
/// drops. Deterministic per seed.
#[allow(clippy::too_many_arguments)]
pub fn phase_affine_routing(n_devices: usize, devices_per_node: usize,
                            n_experts: usize, prefill_tokens: usize,
                            decode_tokens: usize, regime: usize,
                            prefill_noise: f64, decode_noise: f64,
                            seed: u64) -> RoutingTable {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    assert!(n_experts % n_nodes == 0, "experts must divide into nodes");
    let group = n_experts / n_nodes;
    let n_tokens = prefill_tokens + decode_tokens;
    assert!(n_tokens > 0, "a batch needs at least one token");
    let tokens_per_device = n_tokens.div_ceil(n_devices);
    let mut rng = Rng::new(seed);
    let mut indices = Vec::with_capacity(n_tokens);
    let weights = vec![1.0f32; n_tokens];
    for t in 0..n_tokens {
        let node = (t / tokens_per_device).min(n_devices - 1) / devices_per_node;
        let aff_node = (node + regime) % n_nodes;
        let noise = if t < prefill_tokens { prefill_noise } else { decode_noise };
        let e = if rng.next_f64() < noise {
            rng.below(n_experts)
        } else {
            aff_node + n_nodes * rng.below(group)
        };
        indices.push(e as i32);
    }
    RoutingTable::build(&indices, &weights, n_tokens, 1, n_experts, n_tokens)
}

/// Seeded ExFlow-style (arXiv:2401.08383) inter-layer correlated routing
/// (k = 1): the next layer's table as a function of the previous one.
///
/// Each token follows a deterministic expert *transition*: with
/// probability `1 - noise` it routes to `(e_prev + stride) % n_experts`
/// where `e_prev` is its previous-layer primary expert — the stable
/// cross-layer correlation ExFlow measures — and with probability
/// `noise` (or when its previous primary dropped) it scatters to a
/// uniformly random expert. Per-token draw order (one `next_f64`, then
/// one `below` on the scatter branches) matches the other generators.
/// Capacity is sized so nothing drops. Deterministic per seed.
pub fn correlated_layer_routing(prev: &RoutingTable, n_experts: usize,
                                stride: usize, noise: f64,
                                seed: u64) -> RoutingTable {
    assert_eq!(prev.n_experts, n_experts,
               "layers share one expert-count geometry");
    let n_tokens = prev.n_tokens;
    assert!(n_tokens > 0, "a batch needs at least one token");
    let primary = prev.primary_experts();
    let mut rng = Rng::new(seed);
    let mut indices = Vec::with_capacity(n_tokens);
    let weights = vec![1.0f32; n_tokens];
    for t in 0..n_tokens {
        let e = if rng.next_f64() < noise {
            rng.below(n_experts)
        } else {
            match primary[t] {
                Some(p) => (p + stride) % n_experts,
                None => rng.below(n_experts),
            }
        };
        indices.push(e as i32);
    }
    RoutingTable::build(&indices, &weights, n_tokens, 1, n_experts, n_tokens)
}

/// Seeded C2R-style (arXiv:2504.01337) collaboration-constrained
/// node-affine routing (k = 1).
///
/// The chaos mitigation measured by `scmoe report chaos`: tokens that
/// deviate from their node's affinity group (probability `noise` per
/// token) are confined to the first `collab` experts *of that group*
/// instead of scattering uniformly over all experts, so every token's
/// expert satisfies `e % n_nodes == aff_node` and worst-case All-to-All
/// fanout stays bounded no matter how hard routing drifts — at a
/// clean-path cost, since the collaboration set concentrates load.
/// Same per-token draw order as
/// [`drifting_node_affine_routing`](crate::report::efficiency::drifting_node_affine_routing)
/// (one `next_f64`, then one `below` on whichever branch the noise
/// comparison picks), to which it reduces bit-exactly at `noise = 0`.
#[allow(clippy::too_many_arguments)]
pub fn c2r_routing(n_devices: usize, devices_per_node: usize,
                   n_experts: usize, tokens_per_device: usize,
                   regime: usize, noise: f64, collab: usize,
                   seed: u64) -> RoutingTable {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    assert!(n_experts % n_nodes == 0, "experts must divide into nodes");
    let group = n_experts / n_nodes;
    assert!((1..=group).contains(&collab),
            "collaboration width must fit inside one affinity group");
    let n_tokens = n_devices * tokens_per_device;
    let mut rng = Rng::new(seed);
    let mut indices = Vec::with_capacity(n_tokens);
    let weights = vec![1.0f32; n_tokens];
    for t in 0..n_tokens {
        let node = (t / tokens_per_device) / devices_per_node;
        let aff_node = (node + regime) % n_nodes;
        let e = if rng.next_f64() < noise {
            aff_node + n_nodes * rng.below(collab)
        } else {
            aff_node + n_nodes * rng.below(group)
        };
        indices.push(e as i32);
    }
    RoutingTable::build(&indices, &weights, n_tokens, 1, n_experts, n_tokens)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_noise_prefill_is_exactly_node_affine() {
        let rt = phase_affine_routing(4, 2, 4, 16, 0, 0, 0.0, 0.0, 3);
        for r in &rt.routes {
            let node = (r.token / 4) / 2;
            assert_eq!(r.expert % 2, node, "token {} expert {}", r.token, r.expert);
        }
    }

    #[test]
    fn phases_use_distinct_noise_levels() {
        // prefill exact, decode fully random: every affinity violation
        // must come from the decode suffix
        let rt = phase_affine_routing(4, 2, 8, 32, 32, 0, 0.0, 1.0, 9);
        let violations: Vec<usize> = rt
            .routes
            .iter()
            .filter(|r| {
                let node = (r.token / 16).min(3) / 2;
                r.expert % 2 != node
            })
            .map(|r| r.token)
            .collect();
        assert!(!violations.is_empty(), "noise 1.0 must violate affinity");
        assert!(violations.iter().all(|&t| t >= 32),
                "prefill tokens (noise 0) may never violate: {violations:?}");
    }

    #[test]
    fn regime_rotates_the_affinity_target() {
        let rt = phase_affine_routing(4, 2, 4, 16, 0, 1, 0.0, 0.0, 3);
        for r in &rt.routes {
            let node = (r.token / 4) / 2;
            assert_eq!(r.expert % 2, (node + 1) % 2);
        }
    }

    #[test]
    fn c2r_fanout_is_bounded_at_any_noise() {
        // even at 60% deviation probability, every token stays inside
        // its node's affinity group — that is the whole point of the
        // collaboration constraint
        let rt = c2r_routing(4, 2, 8, 16, 1, 0.6, 2, 5);
        for r in &rt.routes {
            let node = (r.token / 16) / 2;
            assert_eq!(r.expert % 2, (node + 1) % 2,
                       "token {} escaped its group", r.token);
        }
        assert_eq!(rt.dropped, 0);
    }

    #[test]
    fn zero_noise_correlation_is_the_pure_stride() {
        let prev = phase_affine_routing(4, 2, 8, 32, 0, 0, 0.0, 0.0, 3);
        let next = correlated_layer_routing(&prev, 8, 3, 0.0, 7);
        let pp = prev.primary_experts();
        let np = next.primary_experts();
        for t in 0..prev.n_tokens {
            assert_eq!(np[t], Some((pp[t].unwrap() + 3) % 8));
        }
    }

    #[test]
    fn correlated_routing_deviates_at_full_noise() {
        let prev = phase_affine_routing(4, 2, 8, 32, 0, 0, 0.0, 0.0, 3);
        let next = correlated_layer_routing(&prev, 8, 1, 1.0, 7);
        let pp = prev.primary_experts();
        let np = next.primary_experts();
        let off_stride = (0..prev.n_tokens)
            .filter(|&t| np[t] != Some((pp[t].unwrap() + 1) % 8))
            .count();
        assert!(off_stride > 0, "full noise must break the stride");
        assert_eq!(next.dropped, 0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = phase_affine_routing(4, 2, 8, 12, 7, 0, 0.25, 0.75, 42);
        let b = phase_affine_routing(4, 2, 8, 12, 7, 0, 0.25, 0.75, 42);
        let idx = |rt: &RoutingTable| -> Vec<usize> {
            rt.routes.iter().map(|r| r.expert).collect()
        };
        assert_eq!(idx(&a), idx(&b));
        assert_eq!(a.n_tokens, 19);
        assert_eq!(a.dropped, 0);
    }
}
