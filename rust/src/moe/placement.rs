//! Expert placement across devices.
//!
//! The paper allocates one expert per GPU; we support arbitrary
//! expert-to-device maps so the scheduling simulator can study how layout
//! shapes All-to-All traffic:
//!
//! - [`Placement::new`] — the contiguous block layout (`experts_per_device`
//!   consecutive experts per device), the default everywhere;
//! - [`Placement::affinity_packed`] — ExFlow-style (arXiv:2401.08383)
//!   greedy packing that co-locates each expert with the node sourcing
//!   most of its tokens, shrinking inter-node A2A volume;
//! - [`Placement::affinity_packed_measured`] — the same greedy packer
//!   over a *measured* affinity matrix (an
//!   [`AffinityEstimator`](super::AffinityEstimator)'s discounted route
//!   counts over a multi-step stream), for live re-placement;
//! - [`Placement::imbalance_skewed`] — a deliberately skewed layout that
//!   concentrates experts on a device prefix, for studying hot-device
//!   link contention;
//! - [`Placement::custom`] — any explicit expert→device map.
//!
//! A placement combines with a [`RoutingTable`](super::RoutingTable) via
//! `RoutingTable::a2a_bytes_placed` to produce the per-device-pair byte
//! matrix that `coordinator::TopoCosts::from_routing` turns into per-link
//! phase times.

use super::router::RoutingTable;

/// Maps each expert id to the device owning its parameters.
#[derive(Debug, Clone)]
pub struct Placement {
    /// Total number of experts in the layer.
    pub n_experts: usize,
    /// Number of expert-parallel devices.
    pub n_devices: usize,
    /// `map[expert] == device` owning that expert.
    map: Vec<usize>,
}

impl Placement {
    /// Contiguous block layout: device `d` owns experts
    /// `[d * per, (d + 1) * per)` with `per = n_experts / n_devices`.
    /// Panics unless `n_experts` divides evenly.
    pub fn new(n_experts: usize, n_devices: usize) -> Placement {
        assert!(n_experts % n_devices == 0,
                "experts ({n_experts}) must be divisible by devices ({n_devices})");
        let per = n_experts / n_devices;
        let map = (0..n_experts).map(|e| e / per).collect();
        Placement { n_experts, n_devices, map }
    }

    /// Arbitrary expert→device map (`map[expert] == device`). Unlike the
    /// block layout, per-device expert counts may be uneven — that is the
    /// point of skewed layouts.
    pub fn custom(n_experts: usize, n_devices: usize, map: Vec<usize>) -> Placement {
        assert_eq!(map.len(), n_experts, "one device per expert");
        assert!(n_devices > 0);
        assert!(map.iter().all(|&d| d < n_devices),
                "placement maps an expert to a device outside the fleet");
        Placement { n_experts, n_devices, map }
    }

    /// ExFlow-style affinity packing from a *single oracle table*:
    /// count each expert's routed copies per source node, then pack with
    /// [`Self::affinity_packed_measured`]. Token sources follow the same
    /// convention as `RoutingTable::a2a_bytes_placed`: tokens are split
    /// evenly over devices in index order.
    ///
    /// For placements learned over a multi-step routing stream, feed a
    /// [`super::AffinityEstimator`]'s measured matrix to
    /// [`Self::affinity_packed_measured`] instead (this one-shot wrapper
    /// is the `steps == 1` counting special case, bit-exactly).
    pub fn affinity_packed(rt: &RoutingTable, n_devices: usize,
                           devices_per_node: usize) -> Placement {
        assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
        let n_nodes = n_devices / devices_per_node;
        let tokens_per_device = rt.n_tokens.div_ceil(n_devices);
        // affinity[e * n_nodes + node] = routed copies from that node
        let mut aff = vec![0.0f64; rt.n_experts * n_nodes];
        for r in &rt.routes {
            let src = (r.token / tokens_per_device).min(n_devices - 1);
            aff[r.expert * n_nodes + src / devices_per_node] += 1.0;
        }
        Placement::affinity_packed_measured(&aff, rt.n_experts, n_devices,
                                            devices_per_node)
    }

    /// ExFlow-style affinity packing from a *measured* affinity matrix
    /// (row-major `[n_experts, n_nodes]`, e.g. an
    /// [`super::AffinityEstimator`]'s discounted route counts): assign
    /// each expert to the node sourcing most of its measured traffic
    /// (greedy, highest-demand experts first — ties break toward the
    /// lower expert id — node capacity balanced at `n_experts / n_nodes`
    /// experts per node), then round-robin experts over the node's
    /// devices. When every expert's measured traffic comes from a single
    /// node and group sizes match the capacity, the resulting layout
    /// makes all A2A traffic node-local and the inter-node phase times
    /// drop to zero.
    pub fn affinity_packed_measured(aff: &[f64], n_experts: usize,
                                    n_devices: usize,
                                    devices_per_node: usize) -> Placement {
        assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
        let n_nodes = n_devices / devices_per_node;
        assert_eq!(aff.len(), n_experts * n_nodes,
                   "affinity matrix must be [n_experts, n_nodes]");
        assert!(n_experts % n_nodes == 0,
                "experts ({n_experts}) must divide into {n_nodes} nodes");
        // place the highest-demand experts first (ties: lower expert id)
        let total: Vec<f64> = (0..n_experts)
            .map(|e| aff[e * n_nodes..(e + 1) * n_nodes].iter().sum())
            .collect();
        let mut order: Vec<usize> = (0..n_experts).collect();
        order.sort_by(|&a, &b| {
            total[b].total_cmp(&total[a]).then(a.cmp(&b))
        });
        let cap = n_experts / n_nodes;
        let mut node_load = vec![0usize; n_nodes];
        let mut map = vec![0usize; n_experts];
        for &e in &order {
            let mut best: Option<usize> = None;
            let mut best_aff = 0.0f64;
            for node in 0..n_nodes {
                if node_load[node] >= cap {
                    continue;
                }
                if best.is_none() || aff[e * n_nodes + node] > best_aff {
                    best = Some(node);
                    best_aff = aff[e * n_nodes + node];
                }
            }
            let node = best.expect("capacities sum to n_experts");
            map[e] = node * devices_per_node + node_load[node] % devices_per_node;
            node_load[node] += 1;
        }
        Placement::custom(n_experts, n_devices, map)
    }

    /// Imbalance-skewed layout: pack `pack` experts per device onto the
    /// first `n_experts / pack` devices, leaving the rest empty. `pack = 1`
    /// with `n_experts == n_devices` is the block layout; larger `pack`
    /// concentrates combine traffic on the loaded device prefix.
    pub fn imbalance_skewed(n_experts: usize, n_devices: usize,
                            pack: usize) -> Placement {
        assert!(pack >= 1 && n_experts % pack == 0,
                "pack ({pack}) must divide the expert count ({n_experts})");
        let used = n_experts / pack;
        assert!((1..=n_devices).contains(&used),
                "skewed layout needs {used} devices, fleet has {n_devices}");
        let map = (0..n_experts).map(|e| e / pack).collect();
        Placement::custom(n_experts, n_devices, map)
    }

    /// Mean experts per device of the balanced layout (total / devices).
    /// Meaningful for block placements (where it is exact); skewed layouts
    /// intentionally deviate from it per device.
    pub fn experts_per_device(&self) -> usize {
        self.n_experts / self.n_devices
    }

    /// Device owning an expert.
    pub fn device_of(&self, expert: usize) -> usize {
        assert!(expert < self.n_experts);
        self.map[expert]
    }

    /// Experts owned by a device, in ascending expert order. Contiguous
    /// for the block layout, arbitrary for custom/skewed layouts.
    pub fn experts_of(&self, device: usize) -> Vec<usize> {
        assert!(device < self.n_devices);
        (0..self.n_experts).filter(|&e| self.map[e] == device).collect()
    }
}

/// Per-device routed *compute* load: how many kept token copies each
/// device's experts process under a given routing × placement. This is
/// the quantity that stretches a hot device's Expert span in the
/// scheduling simulator (`coordinator::TopoCosts` carries one): the
/// pre-load model charged every device the balanced capacity batch, so
/// comm-balanced-but-compute-overloaded layouts scored as fast as truly
/// balanced ones.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpertLoad {
    /// Kept token copies processed by each device (`Σ load[e]` over the
    /// experts placed on it).
    pub per_device: Vec<usize>,
    /// Sum of `per_device` — equals `RoutingTable::kept()` when derived
    /// from a routing table.
    pub total: usize,
}

impl ExpertLoad {
    /// Derive the per-device load from `RoutingTable::load` (kept token
    /// copies per expert) and the expert→device map.
    pub fn from_routing(rt: &RoutingTable, placement: &Placement) -> ExpertLoad {
        assert_eq!(placement.n_experts, rt.n_experts,
                   "placement expert count must match the routing table");
        let mut per_device = vec![0usize; placement.n_devices];
        for (e, &l) in rt.load.iter().enumerate() {
            per_device[placement.device_of(e)] += l;
        }
        let total = per_device.iter().sum();
        ExpertLoad { per_device, total }
    }

    /// Device `d`'s load relative to the balanced mean (`load_d / mean`).
    /// Exactly 1.0 for balanced loads — integer arithmetic cancels before
    /// any rounding — so balanced routing reduces bit-exactly to the
    /// unscaled expert-compute model. 0.0 when no route was kept at all.
    pub fn scale(&self, d: usize) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        self.per_device[d] as f64 * self.per_device.len() as f64
            / self.total as f64
    }

    /// Max device load over the mean (1.0 = perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        if self.total == 0 {
            return 1.0;
        }
        let mean = self.total as f64 / self.per_device.len() as f64;
        *self.per_device.iter().max().unwrap() as f64 / mean
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_layout() {
        let p = Placement::new(8, 4);
        assert_eq!(p.experts_per_device(), 2);
        assert_eq!(p.device_of(0), 0);
        assert_eq!(p.device_of(7), 3);
        assert_eq!(p.experts_of(1), vec![2, 3]);
    }

    #[test]
    fn one_expert_per_device() {
        let p = Placement::new(8, 8);
        for e in 0..8 {
            assert_eq!(p.device_of(e), e);
        }
    }

    #[test]
    #[should_panic]
    fn indivisible_panics() {
        Placement::new(7, 2);
    }

    #[test]
    #[should_panic(expected = "outside the fleet")]
    fn custom_rejects_out_of_range_device() {
        Placement::custom(2, 2, vec![0, 5]);
    }

    #[test]
    fn skewed_packs_device_prefix() {
        let p = Placement::imbalance_skewed(8, 8, 2);
        assert_eq!(p.experts_of(0), vec![0, 1]);
        assert_eq!(p.experts_of(3), vec![6, 7]);
        assert!(p.experts_of(4).is_empty());
        // pack = 1 on a square layout is the block layout
        let q = Placement::imbalance_skewed(4, 4, 1);
        for e in 0..4 {
            assert_eq!(q.device_of(e), e);
        }
    }

    #[test]
    fn affinity_packing_localizes_node_partitioned_traffic() {
        // 4 devices, 2 per node, 4 experts. Node 0's tokens route only to
        // experts {0, 2}; node 1's only to {1, 3}. Affinity packing must
        // place {0, 2} on node 0 and {1, 3} on node 1.
        let indices: Vec<i32> = vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let weights = vec![1.0f32; 16];
        let rt = RoutingTable::build(&indices, &weights, 16, 1, 4, 16);
        let p = Placement::affinity_packed(&rt, 4, 2);
        assert_eq!(p.device_of(0) / 2, 0, "expert 0 belongs on node 0");
        assert_eq!(p.device_of(2) / 2, 0, "expert 2 belongs on node 0");
        assert_eq!(p.device_of(1) / 2, 1, "expert 1 belongs on node 1");
        assert_eq!(p.device_of(3) / 2, 1, "expert 3 belongs on node 1");
        // deterministic greedy: highest-demand expert first, ties by id
        assert_eq!(
            (0..4).map(|e| p.device_of(e)).collect::<Vec<_>>(),
            vec![0, 3, 1, 2]
        );
    }

    #[test]
    fn measured_packing_follows_fractional_affinity() {
        // EWMA-style non-integer matrix (4 experts x 2 nodes): experts 0
        // and 2 lean toward node 1, experts 1 and 3 toward node 0. The
        // greedy packer places by demand order (e0, e1, e3, e2) under the
        // 2-experts-per-node capacity.
        let aff = vec![
            1.5, 2.25, // expert 0 -> node 1
            3.0, 0.5, // expert 1 -> node 0
            0.25, 1.0, // expert 2 -> node 1
            2.0, 0.0, // expert 3 -> node 0
        ];
        let p = Placement::affinity_packed_measured(&aff, 4, 4, 2);
        assert_eq!(
            (0..4).map(|e| p.device_of(e)).collect::<Vec<_>>(),
            vec![2, 0, 3, 1]
        );
    }

    #[test]
    fn expert_load_counts_kept_copies_per_device() {
        // the dyadic routed corpus table: per-expert loads 4/3/4/5
        let indices: Vec<i32> =
            vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let weights = vec![1.0f32; 16];
        let rt = RoutingTable::build(&indices, &weights, 16, 1, 4, 16);
        let load = ExpertLoad::from_routing(&rt, &Placement::new(4, 4));
        assert_eq!(load.per_device, vec![4, 3, 4, 5]);
        assert_eq!(load.total, 16);
        assert_eq!(load.scale(0), 1.0);
        assert_eq!(load.scale(1), 0.75);
        assert_eq!(load.scale(3), 1.25);
        assert!((load.imbalance() - 1.25).abs() < 1e-12);
        // skewed pack-2 layout concentrates everything on devices 0/1
        let skew =
            ExpertLoad::from_routing(&rt, &Placement::imbalance_skewed(4, 4, 2));
        assert_eq!(skew.per_device, vec![7, 9, 0, 0]);
        assert_eq!(skew.scale(2), 0.0);
    }

    #[test]
    fn balanced_expert_load_scale_is_exactly_one() {
        let indices: Vec<i32> = (0..16).map(|t| (t % 4) as i32).collect();
        let weights = vec![1.0f32; 16];
        let rt = RoutingTable::build(&indices, &weights, 16, 1, 4, 16);
        let load = ExpertLoad::from_routing(&rt, &Placement::new(4, 4));
        for d in 0..4 {
            assert_eq!(load.scale(d), 1.0); // bit-exact, not a tolerance
        }
        assert_eq!(load.imbalance(), 1.0);
    }
}
