//! Expert placement across devices (the paper allocates one expert per GPU;
//! we support `experts_per_device >= 1` for the multi-node Fig. 8c setup).

#[derive(Debug, Clone)]
pub struct Placement {
    pub n_experts: usize,
    pub n_devices: usize,
}

impl Placement {
    pub fn new(n_experts: usize, n_devices: usize) -> Placement {
        assert!(n_experts % n_devices == 0,
                "experts ({n_experts}) must be divisible by devices ({n_devices})");
        Placement { n_experts, n_devices }
    }

    pub fn experts_per_device(&self) -> usize {
        self.n_experts / self.n_devices
    }

    /// Device owning an expert (contiguous block layout).
    pub fn device_of(&self, expert: usize) -> usize {
        assert!(expert < self.n_experts);
        expert / self.experts_per_device()
    }

    /// Experts owned by a device.
    pub fn experts_of(&self, device: usize) -> std::ops::Range<usize> {
        assert!(device < self.n_devices);
        let per = self.experts_per_device();
        device * per..(device + 1) * per
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_layout() {
        let p = Placement::new(8, 4);
        assert_eq!(p.experts_per_device(), 2);
        assert_eq!(p.device_of(0), 0);
        assert_eq!(p.device_of(7), 3);
        assert_eq!(p.experts_of(1), 2..4);
    }

    #[test]
    fn one_expert_per_device() {
        let p = Placement::new(8, 8);
        for e in 0..8 {
            assert_eq!(p.device_of(e), e);
        }
    }

    #[test]
    #[should_panic]
    fn indivisible_panics() {
        Placement::new(7, 2);
    }
}
