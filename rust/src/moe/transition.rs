//! Measured inter-layer expert transitions and cross-layer co-placement.
//!
//! ExFlow (arXiv:2401.08383) observes that a token's expert choice at
//! layer *l* predicts its choice at layer *l+1*: routing decisions are
//! correlated across depth, so the device that ran a token's layer-*l*
//! expert is the *source* of its layer-*l+1* dispatch. A per-layer
//! affinity packer (one [`AffinityEstimator`](super::AffinityEstimator)
//! per layer) only sees where tokens *live* at batch start; it cannot
//! see that expert `f` at layer *l+1* receives most of its tokens from
//! expert `e` at layer *l*, wherever `e` happens to be placed.
//!
//! [`TransitionEstimator`] is the missing accumulator: a discounted
//! `[n_experts, n_experts]` prev→next primary-route count matrix over a
//! stream of adjacent-layer [`RoutingTable`] pairs, with the same
//! `count = decay * count + observed` update rule (and the same
//! counting/EWMA modes) as the per-layer estimator. [`co_placed`] then
//! packs layer *l+1* given layer *l*'s placement: each expert's
//! home-node affinity row is augmented with the transition counts
//! flowing from every previous-layer expert resident on that node, and
//! the combined matrix feeds the same greedy
//! [`Placement::affinity_packed_measured`] packer. With zero transition
//! counts the combined matrix *is* the affinity matrix, so cross-layer
//! packing reduces bit-exactly to independent per-layer packing (pinned
//! in `rust/tests/model_timeline.rs` and mirror `consistency_checks8`).

use super::placement::Placement;
use super::router::RoutingTable;

/// Discounted prev-layer→next-layer primary-expert transition counts —
/// the inter-layer analogue of
/// [`AffinityEstimator`](super::AffinityEstimator).
#[derive(Debug, Clone)]
pub struct TransitionEstimator {
    /// Experts per layer (both layers of every observed pair).
    pub n_experts: usize,
    /// Per-step discount on the accumulated counts (1.0 = counting).
    pub decay: f64,
    /// Row-major `[prev_expert, next_expert]` discounted counts.
    counts: Vec<f64>,
    /// Number of table pairs observed so far.
    pub steps: usize,
}

impl TransitionEstimator {
    /// Pure counting accumulator (`decay = 1.0`).
    pub fn counting(n_experts: usize) -> TransitionEstimator {
        TransitionEstimator::ewma(n_experts, 1.0)
    }

    /// Exponentially discounted accumulator; requires `0 < decay <= 1`.
    pub fn ewma(n_experts: usize, decay: f64) -> TransitionEstimator {
        assert!(n_experts > 0);
        assert!(decay > 0.0 && decay <= 1.0, "decay must be in (0, 1]");
        TransitionEstimator {
            n_experts,
            decay,
            counts: vec![0.0; n_experts * n_experts],
            steps: 0,
        }
    }

    /// Fold one adjacent-layer pair of routing tables over the same
    /// token batch: every token whose primary (k-slot-0, kept) route
    /// exists in *both* layers contributes one `(prev_expert,
    /// next_expert)` observation. Dropped primaries contribute nothing
    /// — a token that never reached a layer-*l* expert carries no
    /// layer-*l* residence to transition from.
    pub fn observe(&mut self, prev: &RoutingTable, next: &RoutingTable) {
        assert_eq!(prev.n_experts, self.n_experts,
                   "prev table must cover the estimator's experts");
        assert_eq!(next.n_experts, self.n_experts,
                   "next table must cover the estimator's experts");
        assert_eq!(prev.n_tokens, next.n_tokens,
                   "adjacent layers route the same token batch");
        let pe = prev.primary_experts();
        let ne = next.primary_experts();
        let mut obs = vec![0usize; self.n_experts * self.n_experts];
        for t in 0..prev.n_tokens {
            if let (Some(e), Some(f)) = (pe[t], ne[t]) {
                obs[e * self.n_experts + f] += 1;
            }
        }
        for (c, &o) in self.counts.iter_mut().zip(&obs) {
            *c = self.decay * *c + o as f64;
        }
        self.steps += 1;
    }

    /// Measured (discounted) transition count from previous-layer expert
    /// `e` into next-layer expert `f`.
    pub fn count(&self, e: usize, f: usize) -> f64 {
        assert!(e < self.n_experts && f < self.n_experts);
        self.counts[e * self.n_experts + f]
    }

    /// The full row-major `[n_experts, n_experts]` measured matrix.
    pub fn matrix(&self) -> &[f64] {
        &self.counts
    }
}

/// ExFlow-style cross-layer co-placement: pack a layer's experts given
/// the *previous* layer's placement. Each next-layer expert `f`'s
/// affinity row (`aff`, row-major `[n_experts, n_nodes]` — typically
/// this layer's [`AffinityEstimator`](super::AffinityEstimator) matrix)
/// is augmented with the measured transition counts arriving from every
/// previous-layer expert `e` resident on node `prev.device_of(e) /
/// devices_per_node`, then the combined matrix feeds the same greedy
/// capacity-balanced packer as per-layer packing. Zero transition
/// counts reduce bit-exactly to
/// [`Placement::affinity_packed_measured`] on `aff` alone.
pub fn co_placed(aff: &[f64], trans: &TransitionEstimator, prev: &Placement,
                 n_devices: usize, devices_per_node: usize) -> Placement {
    assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
    let n_nodes = n_devices / devices_per_node;
    let n_experts = trans.n_experts;
    assert_eq!(aff.len(), n_experts * n_nodes,
               "affinity matrix must be [n_experts, n_nodes]");
    assert_eq!(prev.n_experts, n_experts,
               "previous placement must cover the same experts");
    let mut combined = aff.to_vec();
    for e in 0..n_experts {
        let node = prev.device_of(e) / devices_per_node;
        for f in 0..n_experts {
            combined[f * n_nodes + node] += trans.count(e, f);
        }
    }
    Placement::affinity_packed_measured(&combined, n_experts, n_devices,
                                        devices_per_node)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table(idx: &[i32], n_experts: usize) -> RoutingTable {
        let w = vec![1.0f32; idx.len()];
        RoutingTable::build(idx, &w, idx.len(), 1, n_experts, idx.len())
    }

    #[test]
    fn counting_accumulates_primary_transitions() {
        // tokens 0..3 route e0→e1, e0→e1, e1→e0, e1→e1
        let prev = table(&[0, 0, 1, 1], 2);
        let next = table(&[1, 1, 0, 1], 2);
        let mut tr = TransitionEstimator::counting(2);
        tr.observe(&prev, &next);
        tr.observe(&prev, &next);
        assert_eq!(tr.steps, 2);
        assert_eq!(tr.count(0, 1), 4.0);
        assert_eq!(tr.count(1, 0), 2.0);
        assert_eq!(tr.count(1, 1), 2.0);
        assert_eq!(tr.count(0, 0), 0.0);
    }

    #[test]
    fn dropped_primaries_contribute_nothing() {
        // capacity 1 drops token 1's primary in the prev layer
        let w = vec![1.0f32; 2];
        let prev = RoutingTable::build(&[0, 0], &w, 2, 1, 2, 1);
        let next = table(&[1, 1], 2);
        let mut tr = TransitionEstimator::counting(2);
        tr.observe(&prev, &next);
        assert_eq!(tr.count(0, 1), 1.0);
        assert_eq!(tr.matrix().iter().sum::<f64>(), 1.0);
    }

    #[test]
    fn ewma_discounts_old_pairs() {
        let prev = table(&[0, 0], 2);
        let a = table(&[0, 0], 2);
        let b = table(&[1, 1], 2);
        let mut tr = TransitionEstimator::ewma(2, 0.5);
        tr.observe(&prev, &a);
        for _ in 0..3 {
            tr.observe(&prev, &b);
        }
        assert!(tr.count(0, 1) > tr.count(0, 0),
                "EWMA failed to forget: {} vs {}",
                tr.count(0, 1), tr.count(0, 0));
    }

    #[test]
    fn zero_transitions_reduce_to_per_layer_packing() {
        let aff = vec![
            1.5, 2.25,
            3.0, 0.5,
            0.25, 1.0,
            2.0, 0.0,
        ];
        let tr = TransitionEstimator::counting(4);
        let prev = Placement::new(4, 4);
        let cross = co_placed(&aff, &tr, &prev, 4, 2);
        let per = Placement::affinity_packed_measured(&aff, 4, 4, 2);
        for e in 0..4 {
            assert_eq!(cross.device_of(e), per.device_of(e));
        }
    }

    #[test]
    fn co_placement_follows_the_feeding_node() {
        // no home affinity at all; experts 0/1 of the previous layer sit
        // on node 0 and feed next-layer experts 0/1; experts 2/3 sit on
        // node 1 and feed 2/3 — co-placement must keep each pair local
        // to its feeding node
        let aff = vec![0.0; 8];
        let prev = Placement::new(4, 4); // devices 0,1 = node 0
        let pl = table(&[0, 0, 1, 1, 2, 2, 3, 3], 4);
        let nl = table(&[0, 0, 1, 1, 2, 2, 3, 3], 4);
        let mut tr = TransitionEstimator::counting(4);
        tr.observe(&pl, &nl);
        let p = co_placed(&aff, &tr, &prev, 4, 2);
        assert_eq!(p.device_of(0) / 2, 0);
        assert_eq!(p.device_of(1) / 2, 0);
        assert_eq!(p.device_of(2) / 2, 1);
        assert_eq!(p.device_of(3) / 2, 1);
    }

    #[test]
    #[should_panic(expected = "same token batch")]
    fn observe_rejects_mismatched_batches() {
        let prev = table(&[0, 0], 2);
        let next = table(&[1, 1, 1], 2);
        TransitionEstimator::counting(2).observe(&prev, &next);
    }
}
