//! Measured expert affinity over a multi-step routing stream.
//!
//! ExFlow (arXiv:2401.08383) observes that token→expert affinity is
//! stable from one training iteration to the next, so expert placement
//! should be *learned from measured routing traces* instead of derived
//! from a single oracle table. [`AffinityEstimator`] is that
//! accumulator: it folds a stream of [`RoutingTable`]s into a
//! per-(expert, source-node) route-count matrix, and
//! [`AffinityEstimator::packed`] turns the measured matrix into the
//! ExFlow-style placement via
//! [`Placement::affinity_packed_measured`](super::Placement::affinity_packed_measured).
//!
//! Two accumulation modes share one update rule
//! `count = decay * count + observed`:
//!
//! - [`AffinityEstimator::counting`] (`decay = 1.0`) — plain counting,
//!   the right choice under a stable routing regime (every observation
//!   weighs equally, noise averages away);
//! - [`AffinityEstimator::ewma`] (`decay < 1.0`) — exponentially
//!   discounted counting, which forgets old regimes geometrically and
//!   re-learns a post-shift affinity structure within a few steps.
//!
//! The estimator feeds `coordinator::replace::run_replace_timeline`,
//! where the measured packing becomes a live re-placement priced as H2D
//! migration tasks (see docs/ARCHITECTURE.md §"Measured affinity and
//! live re-placement").

use super::placement::Placement;
use super::router::RoutingTable;

/// Discounted (expert, source-node) route counts over a stream of
/// routing tables — the measured replacement for the single-table
/// oracle that `Placement::affinity_packed` consumes.
#[derive(Debug, Clone)]
pub struct AffinityEstimator {
    /// Experts covered by every observed table.
    pub n_experts: usize,
    /// Nodes tokens are sourced from (fleet nodes).
    pub n_nodes: usize,
    /// Per-step discount on the accumulated counts (1.0 = counting).
    pub decay: f64,
    /// Row-major `[n_experts, n_nodes]` discounted route counts.
    counts: Vec<f64>,
    /// Number of tables observed so far.
    pub steps: usize,
}

impl AffinityEstimator {
    /// Pure counting accumulator (`decay = 1.0`): every observed step
    /// weighs equally forever.
    pub fn counting(n_experts: usize, n_nodes: usize) -> AffinityEstimator {
        AffinityEstimator::ewma(n_experts, n_nodes, 1.0)
    }

    /// Exponentially discounted accumulator: before each observation the
    /// stored counts are multiplied by `decay`, so a step observed `s`
    /// steps ago weighs `decay^s`. Requires `0 < decay <= 1`.
    pub fn ewma(n_experts: usize, n_nodes: usize, decay: f64) -> AffinityEstimator {
        assert!(n_experts > 0 && n_nodes > 0);
        assert!(decay > 0.0 && decay <= 1.0, "decay must be in (0, 1]");
        AffinityEstimator {
            n_experts,
            n_nodes,
            decay,
            counts: vec![0.0; n_experts * n_nodes],
            steps: 0,
        }
    }

    /// Fold one step's routing table into the measured matrix. Token
    /// sources follow the same convention as
    /// `RoutingTable::a2a_bytes_placed`: tokens split evenly over
    /// `n_devices` in index order, nodes are contiguous device blocks of
    /// `devices_per_node`. Only *kept* routes count (dropped routes move
    /// no tokens, so they attract no affinity either).
    pub fn observe(&mut self, rt: &RoutingTable, n_devices: usize,
                   devices_per_node: usize) {
        assert_eq!(rt.n_experts, self.n_experts,
                   "observed table must cover the estimator's experts");
        assert!(devices_per_node > 0 && n_devices % devices_per_node == 0);
        assert_eq!(n_devices / devices_per_node, self.n_nodes,
                   "observed fleet must match the estimator's node count");
        let tokens_per_device = rt.n_tokens.div_ceil(n_devices);
        let mut obs = vec![0usize; self.n_experts * self.n_nodes];
        for r in &rt.routes {
            let src = (r.token / tokens_per_device).min(n_devices - 1);
            obs[r.expert * self.n_nodes + src / devices_per_node] += 1;
        }
        for (c, &o) in self.counts.iter_mut().zip(&obs) {
            *c = self.decay * *c + o as f64;
        }
        self.steps += 1;
    }

    /// Measured (discounted) route count from `node` into `expert`.
    pub fn affinity(&self, expert: usize, node: usize) -> f64 {
        assert!(expert < self.n_experts && node < self.n_nodes);
        self.counts[expert * self.n_nodes + node]
    }

    /// The full row-major `[n_experts, n_nodes]` measured matrix — the
    /// input [`Placement::affinity_packed_measured`] consumes.
    pub fn matrix(&self) -> &[f64] {
        &self.counts
    }

    /// ExFlow-style placement packed from the measured matrix (greedy,
    /// capacity-balanced per node — see
    /// [`Placement::affinity_packed_measured`]).
    pub fn packed(&self, n_devices: usize, devices_per_node: usize) -> Placement {
        Placement::affinity_packed_measured(&self.counts, self.n_experts,
                                            n_devices, devices_per_node)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus_table() -> RoutingTable {
        // the dyadic routed corpus table: node 0's tokens route to
        // experts {0, 2}, node 1's to {1, 3}
        let indices: Vec<i32> =
            vec![0, 2, 0, 2, 2, 0, 0, 2, 1, 3, 3, 1, 3, 1, 3, 3];
        let weights = vec![1.0f32; 16];
        RoutingTable::build(&indices, &weights, 16, 1, 4, 16)
    }

    #[test]
    fn counting_matches_one_shot_affinity() {
        let rt = corpus_table();
        let mut est = AffinityEstimator::counting(4, 2);
        for _ in 0..3 {
            est.observe(&rt, 4, 2);
        }
        assert_eq!(est.steps, 3);
        // counts are a 3x scaling of the one-shot matrix, so the greedy
        // packing is identical to Placement::affinity_packed
        let reference = Placement::affinity_packed(&rt, 4, 2);
        let measured = est.packed(4, 2);
        for e in 0..4 {
            assert_eq!(measured.device_of(e), reference.device_of(e));
        }
        assert_eq!(est.affinity(0, 0), 12.0);
        assert_eq!(est.affinity(0, 1), 0.0);
    }

    #[test]
    fn ewma_forgets_an_old_regime() {
        // regime A: all tokens to expert 0 come from node 0; regime B
        // flips the sourcing. After a few post-shift steps the EWMA
        // matrix must favor the new regime.
        let a: Vec<i32> = vec![0, 0, 0, 0, 1, 1, 1, 1];
        let b: Vec<i32> = vec![1, 1, 1, 1, 0, 0, 0, 0];
        let w = vec![1.0f32; 8];
        let rt_a = RoutingTable::build(&a, &w, 8, 1, 2, 8);
        let rt_b = RoutingTable::build(&b, &w, 8, 1, 2, 8);
        let mut est = AffinityEstimator::ewma(2, 2, 0.5);
        for _ in 0..8 {
            est.observe(&rt_a, 4, 2);
        }
        assert!(est.affinity(0, 0) > est.affinity(0, 1));
        for _ in 0..3 {
            est.observe(&rt_b, 4, 2);
        }
        assert!(est.affinity(0, 1) > est.affinity(0, 0),
                "EWMA failed to forget regime A: {} vs {}",
                est.affinity(0, 0), est.affinity(0, 1));
    }

    #[test]
    #[should_panic(expected = "node count")]
    fn observe_rejects_mismatched_fleet() {
        let rt = corpus_table();
        AffinityEstimator::counting(4, 2).observe(&rt, 8, 2);
    }
}
