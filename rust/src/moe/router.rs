//! Gate-output routing: maps each token's top-k expert choices to
//! (expert, capacity-slot) assignments with FCFS overflow dropping —
//! byte-for-byte the policy of `ref.dispatch_combine_masks` on the Python
//! side (pinned there by python/tests/test_dispatch_combine.py).

use super::placement::Placement;

/// One token's routing decision for one of its k expert choices.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Route {
    pub token: usize,
    pub k_slot: usize,
    pub expert: usize,
    /// Position within the expert's capacity buffer.
    pub slot: usize,
    /// Combine weight (gate score).
    pub weight: f32,
}

/// Routing table for one MoE layer invocation.
#[derive(Debug, Clone)]
pub struct RoutingTable {
    pub n_tokens: usize,
    pub n_experts: usize,
    pub capacity: usize,
    pub k: usize,
    pub routes: Vec<Route>,
    /// Tokens-per-expert histogram (before drop: demand; after drop: load).
    pub demand: Vec<usize>,
    pub load: Vec<usize>,
    pub dropped: usize,
}

impl RoutingTable {
    /// Build the table from gate outputs.
    ///
    /// `indices`: row-major [n_tokens, k] expert ids;
    /// `weights`: row-major [n_tokens, k] combine weights.
    /// Slot assignment is first-come-first-served over the flattened
    /// (token, k) order; routes beyond `capacity` are dropped.
    pub fn build(
        indices: &[i32],
        weights: &[f32],
        n_tokens: usize,
        k: usize,
        n_experts: usize,
        capacity: usize,
    ) -> RoutingTable {
        assert_eq!(indices.len(), n_tokens * k, "indices length");
        assert_eq!(weights.len(), n_tokens * k, "weights length");
        let mut routes = Vec::with_capacity(n_tokens * k);
        let mut next_slot = vec![0usize; n_experts];
        let mut demand = vec![0usize; n_experts];
        let mut dropped = 0usize;
        for t in 0..n_tokens {
            for kk in 0..k {
                let e = indices[t * k + kk];
                assert!(
                    (0..n_experts as i32).contains(&e),
                    "expert index {e} out of range (E={n_experts})"
                );
                let e = e as usize;
                demand[e] += 1;
                if next_slot[e] < capacity {
                    routes.push(Route {
                        token: t,
                        k_slot: kk,
                        expert: e,
                        slot: next_slot[e],
                        weight: weights[t * k + kk],
                    });
                    next_slot[e] += 1;
                } else {
                    dropped += 1;
                }
            }
        }
        RoutingTable {
            n_tokens,
            n_experts,
            capacity,
            k,
            routes,
            demand,
            load: next_slot,
            dropped,
        }
    }

    /// Bytes each source device must send to each destination device under
    /// the contiguous block layout (`experts_per_device` consecutive
    /// experts per device, tokens split evenly across devices).
    /// Returns a row-major `[n_devices, n_devices]` matrix of dispatch
    /// bytes. Shorthand for [`Self::a2a_bytes_placed`] with
    /// [`Placement::new`].
    pub fn a2a_bytes(
        &self,
        n_devices: usize,
        token_bytes: usize,
    ) -> Vec<usize> {
        assert!(self.n_experts % n_devices == 0, "experts must divide devices");
        self.a2a_bytes_placed(&Placement::new(self.n_experts, n_devices),
                              token_bytes)
    }

    /// Bytes each source device must send to each destination device under
    /// an arbitrary expert [`Placement`] (tokens split evenly across
    /// devices in index order; each kept route moves one `token_bytes`
    /// payload to the device owning its expert).
    ///
    /// Returns the row-major `[n_devices, n_devices]` *dispatch* matrix;
    /// the combine direction is its transpose
    /// (`cluster::a2a_transpose`). Dropped routes move no bytes.
    pub fn a2a_bytes_placed(
        &self,
        placement: &Placement,
        token_bytes: usize,
    ) -> Vec<usize> {
        assert_eq!(placement.n_experts, self.n_experts,
                   "placement expert count must match the routing table");
        let n_devices = placement.n_devices;
        let tokens_per_device = self.n_tokens.div_ceil(n_devices);
        let mut mat = vec![0usize; n_devices * n_devices];
        for r in &self.routes {
            let src = (r.token / tokens_per_device).min(n_devices - 1);
            let dst = placement.device_of(r.expert);
            mat[src * n_devices + dst] += token_bytes;
        }
        mat
    }

    /// [`Self::a2a_bytes_placed`] with an explicit per-token source
    /// device instead of the even index-order split: `sources[t]` is the
    /// device holding token `t`'s activations when this layer's dispatch
    /// fires. The model composition layer uses this to chain layers in
    /// the ExFlow execution model — a token's layer-*l* activations sit
    /// on whatever device ran its layer-*l−1* expert, so the layer-*l*
    /// dispatch matrix depends on the *previous* layer's placement.
    /// `sources` is indexed by absolute token id (chunked parts keep
    /// their parent's token ids, so one vector serves every part).
    /// The combine direction remains the transpose.
    pub fn a2a_bytes_from_sources(
        &self,
        sources: &[usize],
        placement: &Placement,
        token_bytes: usize,
    ) -> Vec<usize> {
        assert_eq!(placement.n_experts, self.n_experts,
                   "placement expert count must match the routing table");
        assert_eq!(sources.len(), self.n_tokens,
                   "one source device per token");
        let n_devices = placement.n_devices;
        let mut mat = vec![0usize; n_devices * n_devices];
        for r in &self.routes {
            let src = sources[r.token];
            assert!(src < n_devices, "source device outside the fleet");
            let dst = placement.device_of(r.expert);
            mat[src * n_devices + dst] += token_bytes;
        }
        mat
    }

    /// Each token's first kept k-slot-0 expert, `None` for tokens whose
    /// primary route dropped. This is the "where did the token go" map
    /// the inter-layer transition estimator and the chained-source
    /// computation consume (secondary top-k copies return to the token's
    /// holder at combine, so the primary expert decides residence).
    pub fn primary_experts(&self) -> Vec<Option<usize>> {
        let mut primary = vec![None; self.n_tokens];
        for r in &self.routes {
            if r.k_slot == 0 && primary[r.token].is_none() {
                primary[r.token] = Some(r.expert);
            }
        }
        primary
    }

    /// Split into `chunks` contiguous token ranges (Tutel-style pipeline
    /// chunking): part `i` covers tokens `[i·⌈n/chunks⌉, (i+1)·⌈n/chunks⌉)`
    /// and keeps exactly the parent routes whose token falls in that range.
    ///
    /// Each part retains the parent's `n_tokens`/`k`/`capacity` (and the
    /// parent's token ids and capacity slots), so `a2a_bytes_placed` maps
    /// tokens to source devices identically and the parts' byte matrices
    /// sum to the parent's matrix entry-for-entry — skewed routing skews
    /// *per-chunk* traffic instead of being averaged away. `demand`/`load`
    /// are the part's kept-route histograms and `dropped` the part's share
    /// of the parent's capacity drops (attributed by token range).
    pub fn chunk(&self, chunks: usize) -> Vec<RoutingTable> {
        assert!(chunks >= 1);
        let size = self.n_tokens.div_ceil(chunks);
        let mut parts = Vec::with_capacity(chunks);
        for i in 0..chunks {
            let lo = (i * size).min(self.n_tokens);
            let hi = ((i + 1) * size).min(self.n_tokens);
            let routes: Vec<Route> = self.routes.iter()
                .filter(|r| (lo..hi).contains(&r.token))
                .cloned()
                .collect();
            let mut load = vec![0usize; self.n_experts];
            for r in &routes {
                load[r.expert] += 1;
            }
            let dropped = (hi - lo) * self.k - routes.len();
            parts.push(RoutingTable {
                n_tokens: self.n_tokens,
                n_experts: self.n_experts,
                capacity: self.capacity,
                k: self.k,
                routes,
                demand: load.clone(),
                load,
                dropped,
            });
        }
        parts
    }

    /// Per-expert load imbalance: max load / mean load (1.0 = perfect).
    pub fn imbalance(&self) -> f64 {
        let total: usize = self.load.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / self.n_experts as f64;
        let max = *self.load.iter().max().unwrap() as f64;
        max / mean
    }

    /// Number of routes kept after capacity dropping.
    pub fn kept(&self) -> usize {
        self.routes.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_capacity() {
        // 4 tokens all to expert 0, capacity 2 -> tokens 0,1 kept.
        let idx = vec![0, 0, 0, 0];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 2, 2);
        assert_eq!(rt.kept(), 2);
        assert_eq!(rt.dropped, 2);
        assert_eq!(rt.routes[0].token, 0);
        assert_eq!(rt.routes[0].slot, 0);
        assert_eq!(rt.routes[1].token, 1);
        assert_eq!(rt.routes[1].slot, 1);
        assert_eq!(rt.demand[0], 4);
        assert_eq!(rt.load[0], 2);
    }

    #[test]
    fn topk_routes_both() {
        let idx = vec![0, 1, 1, 0];
        let w = vec![0.6, 0.4, 0.7, 0.3];
        let rt = RoutingTable::build(&idx, &w, 2, 2, 2, 4);
        assert_eq!(rt.kept(), 4);
        assert_eq!(rt.load, vec![2, 2]);
    }

    #[test]
    fn a2a_bytes_matrix() {
        // 4 tokens on 2 devices (2 each), 4 experts on 2 devices.
        // token0->e0, token1->e2, token2->e1, token3->e3
        let idx = vec![0, 2, 1, 3];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let m = rt.a2a_bytes(2, 10);
        // src0: t0->e0(dev0), t1->e2(dev1); src1: t2->e1(dev0), t3->e3(dev1)
        assert_eq!(m, vec![10, 10, 10, 10]);
    }

    #[test]
    fn a2a_bytes_placed_block_matches_legacy() {
        let idx = vec![0, 2, 1, 3, 2, 2];
        let w = vec![1.0; 6];
        let rt = RoutingTable::build(&idx, &w, 6, 1, 4, 4);
        let legacy = rt.a2a_bytes(2, 10);
        let placed = rt.a2a_bytes_placed(&Placement::new(4, 2), 10);
        assert_eq!(legacy, placed);
    }

    #[test]
    fn a2a_bytes_placed_follows_the_map() {
        // all experts on device 1: every source sends everything there
        let idx = vec![0, 1, 2, 3];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let p = Placement::custom(4, 2, vec![1, 1, 1, 1]);
        let m = rt.a2a_bytes_placed(&p, 10);
        assert_eq!(m, vec![0, 20, 0, 20]);
    }

    #[test]
    fn home_sources_reduce_to_the_even_split() {
        let idx = vec![0, 2, 1, 3, 2, 2];
        let w = vec![1.0; 6];
        let rt = RoutingTable::build(&idx, &w, 6, 1, 4, 4);
        let p = Placement::new(4, 2);
        let tpd = rt.n_tokens.div_ceil(2);
        let home: Vec<usize> =
            (0..rt.n_tokens).map(|t| (t / tpd).min(1)).collect();
        assert_eq!(rt.a2a_bytes_from_sources(&home, &p, 10),
                   rt.a2a_bytes_placed(&p, 10));
    }

    #[test]
    fn explicit_sources_redirect_the_rows() {
        // every token held by device 1: all dispatch leaves row 1
        let idx = vec![0, 1, 2, 3];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 4, 4);
        let m = rt.a2a_bytes_from_sources(&[1; 4], &Placement::new(4, 2), 10);
        assert_eq!(m, vec![0, 0, 20, 20]);
    }

    #[test]
    fn primary_experts_track_kept_slot_zero_routes() {
        // capacity 1: token 0 fills both experts, token 1 drops entirely
        let idx = vec![0, 1, 0, 1];
        let w = vec![0.6, 0.4, 0.7, 0.3];
        let rt = RoutingTable::build(&idx, &w, 2, 2, 2, 1);
        assert_eq!(rt.primary_experts(), vec![Some(0), None]);
    }

    #[test]
    fn chunk_partitions_routes_and_matrices() {
        // 6 tokens, skewed: the first half routes remotely, the rest stays
        let idx = vec![2, 3, 2, 0, 1, 0];
        let w = vec![1.0; 6];
        let rt = RoutingTable::build(&idx, &w, 6, 1, 4, 4);
        for chunks in [1usize, 2, 3, 4] {
            let parts = rt.chunk(chunks);
            assert_eq!(parts.len(), chunks);
            let kept: usize = parts.iter().map(|p| p.kept()).sum();
            assert_eq!(kept, rt.kept(), "routes partition");
            let full = rt.a2a_bytes_placed(&Placement::new(4, 2), 8);
            let mut sum = vec![0usize; full.len()];
            for p in &parts {
                for (s, b) in sum.iter_mut()
                    .zip(p.a2a_bytes_placed(&Placement::new(4, 2), 8))
                {
                    *s += b;
                }
            }
            assert_eq!(sum, full, "chunk matrices sum to the parent's");
        }
        // contiguous split: chunk 0 of 2 holds tokens 0..3 only
        let parts = rt.chunk(2);
        assert!(parts[0].routes.iter().all(|r| r.token < 3));
        assert!(parts[1].routes.iter().all(|r| r.token >= 3));
    }

    #[test]
    fn chunk_attributes_drops_by_token_range() {
        // capacity 1 on expert 0: tokens 1 and 2 drop (FCFS)
        let idx = vec![0, 0, 0, 1];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 2, 1);
        assert_eq!(rt.dropped, 2);
        let parts = rt.chunk(2);
        assert_eq!(parts[0].dropped, 1, "token 1's drop lands in chunk 0");
        assert_eq!(parts[1].dropped, 1, "token 2's drop lands in chunk 1");
        assert_eq!(parts.iter().map(|p| p.dropped).sum::<usize>(), rt.dropped);
    }

    #[test]
    fn imbalance_metric() {
        let idx = vec![0, 0, 0, 1];
        let w = vec![1.0; 4];
        let rt = RoutingTable::build(&idx, &w, 4, 1, 2, 8);
        assert!((rt.imbalance() - 1.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn out_of_range_expert_panics() {
        RoutingTable::build(&[5], &[1.0], 1, 1, 4, 1);
    }
}
