//! Encode / decode: the data-movement half of expert parallelism.
//!
//! `encode` gathers routed tokens into per-expert capacity buffers
//! ([E, C, D] contiguous, zero-padded) before the All-to-All dispatch;
//! `decode` scatters expert outputs back to token order with combine
//! weights after the All-to-All combine. These run on the coordinator's
//! hot path, so they are allocation-conscious: callers can reuse buffers
//! via the `_into` variants.

use super::router::RoutingTable;

/// Gather tokens into per-expert capacity buffers.
///
/// `tokens`: row-major [n_tokens, d]; returns [E, C, d] with dropped /
/// unused slots zeroed.
pub fn encode(table: &RoutingTable, tokens: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; table.n_experts * table.capacity * d];
    encode_into(table, tokens, d, &mut out);
    out
}

/// In-place [`encode`]: fills a caller-owned `[E * C * d]` buffer (zeroing
/// unused slots) instead of allocating.
pub fn encode_into(table: &RoutingTable, tokens: &[f32], d: usize, out: &mut [f32]) {
    assert_eq!(tokens.len(), table.n_tokens * d, "token buffer size");
    assert_eq!(out.len(), table.n_experts * table.capacity * d, "encode buffer size");
    // §Perf note: a slot-bitmap variant that skipped the blanket fill was
    // tried and REVERTED — the sequential memset + copy beats scattered
    // range-fills on this core (see EXPERIMENTS.md §Perf iteration log).
    out.fill(0.0);
    for r in &table.routes {
        let src = &tokens[r.token * d..(r.token + 1) * d];
        let base = (r.expert * table.capacity + r.slot) * d;
        out[base..base + d].copy_from_slice(src);
    }
}

/// Scatter expert outputs back to token order, weighted by combine weights.
///
/// `expert_out`: [E, C, d]; returns [n_tokens, d]. Tokens whose routes were
/// all dropped produce zeros (the residual connection preserves them).
pub fn decode(table: &RoutingTable, expert_out: &[f32], d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; table.n_tokens * d];
    decode_into(table, expert_out, d, &mut out);
    out
}

/// In-place [`decode`]: accumulates into a caller-owned `[n_tokens * d]`
/// buffer instead of allocating.
pub fn decode_into(table: &RoutingTable, expert_out: &[f32], d: usize, out: &mut [f32]) {
    assert_eq!(expert_out.len(), table.n_experts * table.capacity * d, "expert buffer size");
    assert_eq!(out.len(), table.n_tokens * d, "decode buffer size");
    // §Perf: first write per token stores w*s directly (skips the blanket
    // zero-fill); only tokens with zero surviving routes get memset.
    let mut seen = vec![false; table.n_tokens];
    for r in &table.routes {
        let base = (r.expert * table.capacity + r.slot) * d;
        let src = &expert_out[base..base + d];
        let dst = &mut out[r.token * d..(r.token + 1) * d];
        let w = r.weight;
        if seen[r.token] {
            for (o, s) in dst.iter_mut().zip(src) {
                *o += w * s;
            }
        } else {
            seen[r.token] = true;
            for (o, s) in dst.iter_mut().zip(src) {
                *o = w * s;
            }
        }
    }
    for (t, s) in seen.iter().enumerate() {
        if !s {
            out[t * d..(t + 1) * d].fill(0.0);
        }
    }
}

/// Split an [E, C, d] buffer into per-device shards (contiguous expert
/// ranges) — what each worker receives after All-to-All dispatch.
pub fn shard_by_device<'a>(
    buf: &'a [f32],
    n_experts: usize,
    n_devices: usize,
    capacity: usize,
    d: usize,
) -> Vec<&'a [f32]> {
    assert_eq!(buf.len(), n_experts * capacity * d);
    assert!(n_experts % n_devices == 0);
    let per = n_experts / n_devices;
    (0..n_devices)
        .map(|dev| {
            let start = dev * per * capacity * d;
            &buf[start..start + per * capacity * d]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::router::RoutingTable;

    fn table_2tok() -> RoutingTable {
        // token0 -> expert1 (w 0.5), token1 -> expert0 (w 2.0)
        RoutingTable::build(&[1, 0], &[0.5, 2.0], 2, 1, 2, 2)
    }

    #[test]
    fn encode_places_tokens() {
        let t = table_2tok();
        let tokens = vec![1.0, 2.0, /* tok0 */ 3.0, 4.0 /* tok1 */];
        let enc = encode(&t, &tokens, 2);
        // layout [E=2, C=2, d=2]: expert0 slot0 = token1; expert1 slot0 = token0
        assert_eq!(&enc[0..2], &[3.0, 4.0]);
        assert_eq!(&enc[2..4], &[0.0, 0.0]);
        assert_eq!(&enc[4..6], &[1.0, 2.0]);
    }

    #[test]
    fn decode_weights_and_restores_order() {
        let t = table_2tok();
        let mut expert_out = vec![0.0; 2 * 2 * 2];
        expert_out[0..2].copy_from_slice(&[10.0, 20.0]); // expert0 slot0 -> token1
        expert_out[4..6].copy_from_slice(&[1.0, 1.0]);   // expert1 slot0 -> token0
        let dec = decode(&t, &expert_out, 2);
        assert_eq!(&dec[0..2], &[0.5, 0.5]);   // token0: w=0.5
        assert_eq!(&dec[2..4], &[20.0, 40.0]); // token1: w=2.0
    }

    #[test]
    fn roundtrip_is_weighted_identity() {
        // identity experts: decode(encode(x)) == w * x when capacity ample
        let idx = vec![0, 1, 2, 3];
        let w = vec![1.0; 4];
        let t = RoutingTable::build(&idx, &w, 4, 1, 4, 2);
        let tokens: Vec<f32> = (0..8).map(|i| i as f32).collect();
        let enc = encode(&t, &tokens, 2);
        let dec = decode(&t, &enc, 2);
        assert_eq!(dec, tokens);
    }

    #[test]
    fn dropped_tokens_zeroed() {
        let t = RoutingTable::build(&[0, 0], &[1.0, 1.0], 2, 1, 1, 1);
        let tokens = vec![1.0, 1.0, 2.0, 2.0];
        let enc = encode(&t, &tokens, 2);
        let dec = decode(&t, &enc, 2);
        assert_eq!(&dec[0..2], &[1.0, 1.0]);
        assert_eq!(&dec[2..4], &[0.0, 0.0]); // dropped
    }

    #[test]
    fn shards_cover_buffer() {
        let buf = vec![0.0f32; 8 * 4 * 3];
        let shards = shard_by_device(&buf, 8, 4, 4, 3);
        assert_eq!(shards.len(), 4);
        assert!(shards.iter().all(|s| s.len() == 2 * 4 * 3));
    }
}
