//! MoE coordinator data plane: gate-output routing, capacity management,
//! encode/decode layout transforms, expert placement.
//!
//! This is the Rust half of the GShard-style dispatch whose reference
//! semantics live in python/compile/kernels/ref.py (`dispatch_combine_masks`).
//!
//! It also feeds the scheduling simulator: `RoutingTable::a2a_bytes_placed`
//! turns real routing decisions plus a [`Placement`] into the per-device-
//! pair byte matrix that `coordinator::TopoCosts::from_routing` converts
//! into per-link All-to-All phase times, and [`AffinityEstimator`]
//! accumulates measured (expert, source-node) affinity over a multi-step
//! stream of routing tables so placements can be *learned* (ExFlow-style)
//! and re-learned live instead of derived from a single oracle table.
//! For whole-model timelines, [`TransitionEstimator`] additionally
//! accumulates *inter-layer* expert transitions and [`co_placed`] packs
//! each layer against the previous layer's placement (cross-layer
//! co-placement), while `RoutingTable::a2a_bytes_from_sources` prices a
//! layer's dispatch from wherever the previous layer left each token.

pub mod dispatch;
pub mod estimator;
pub mod placement;
pub mod router;
pub mod traffic;
pub mod transition;

pub use dispatch::{decode, decode_into, encode, encode_into};
pub use estimator::AffinityEstimator;
pub use placement::{ExpertLoad, Placement};
pub use router::{Route, RoutingTable};
pub use traffic::{c2r_routing, correlated_layer_routing, phase_affine_routing};
pub use transition::{co_placed, TransitionEstimator};
