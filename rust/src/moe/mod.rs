//! MoE coordinator data plane: gate-output routing, capacity management,
//! encode/decode layout transforms, expert placement.
//!
//! This is the Rust half of the GShard-style dispatch whose reference
//! semantics live in python/compile/kernels/ref.py (`dispatch_combine_masks`).

pub mod dispatch;
pub mod placement;
pub mod router;

pub use dispatch::{decode, decode_into, encode, encode_into};
pub use placement::Placement;
pub use router::{Route, RoutingTable};
