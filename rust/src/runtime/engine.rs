//! PJRT engine: loads AOT HLO-text artifacts, compiles them once on the CPU
//! client, and executes them with shape-checked host tensors.
//!
//! Interchange is HLO *text* (see python/compile/aot.py): jax >= 0.5 emits
//! protos with 64-bit ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids. Outputs always arrive as a single tuple buffer (the 0.5.1
//! PJRT wrapper does not untuple), so `run` downloads + decomposes.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use super::tensor::HostTensor;

/// Shared PJRT CPU client. Creating a TfrtCpuClient is expensive (~100ms)
/// and the process only ever needs one.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile one artifact (HLO text -> executable).
    pub fn load_artifact(&self, spec: &ArtifactSpec) -> Result<Executable> {
        let path = &spec.file;
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let t0 = Instant::now();
        let exe = self.client.compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            spec: spec.clone(),
            exe,
            compile_secs: t0.elapsed().as_secs_f64(),
        })
    }

    /// Load every artifact in a manifest (compiled lazily via `ArtifactSet`).
    pub fn open(self: &Arc<Self>, dir: &Path) -> Result<ArtifactSet> {
        let manifest = Manifest::load(dir)?;
        Ok(ArtifactSet {
            engine: Arc::clone(self),
            manifest,
            cache: Mutex::new(BTreeMap::new()),
        })
    }
}

/// One compiled artifact with its tensor interface.
pub struct Executable {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    pub compile_secs: f64,
}

impl Executable {
    /// Execute with shape/dtype validation; returns one HostTensor per
    /// declared output.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.check_inputs(inputs)?;
        let lits: Vec<xla::Literal> = inputs.iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        self.run_literals(&lits)
    }

    /// Execute with pre-built literals (hot loop: the training driver keeps
    /// parameter literals resident and avoids re-encoding them per step).
    pub fn run_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self, inputs: &[L]) -> Result<Vec<HostTensor>> {
        let out = self.exe.execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = out[0][0].to_literal_sync().context("downloading result")?;
        let parts = tuple.to_tuple().context("decomposing result tuple")?;
        if parts.len() != self.spec.outputs.len() {
            bail!("artifact {} returned {} outputs, manifest says {}",
                  self.spec.name, parts.len(), self.spec.outputs.len());
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Execute and return raw literals without host conversion (used when
    /// outputs feed straight back into the next call).
    pub fn run_raw<L: std::borrow::Borrow<xla::Literal>>(
        &self, inputs: &[L]) -> Result<Vec<xla::Literal>> {
        let out = self.exe.execute::<L>(inputs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        let tuple = out[0][0].to_literal_sync().context("downloading result")?;
        tuple.to_tuple().context("decomposing result tuple")
    }

    fn check_inputs(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("artifact {} takes {} inputs, got {}",
                  self.spec.name, self.spec.inputs.len(), inputs.len());
        }
        for (t, s) in inputs.iter().zip(&self.spec.inputs) {
            if !t.matches(s) {
                bail!("artifact {} input {:?}: expected shape {:?} dtype {:?}, \
                       got shape {:?} dtype {:?}",
                      self.spec.name, s.name, s.shape, s.dtype, t.shape, t.dtype());
            }
        }
        Ok(())
    }
}

/// A manifest directory with lazily-compiled executables.
pub struct ArtifactSet {
    engine: Arc<Engine>,
    pub manifest: Manifest,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl ArtifactSet {
    pub fn get(&self, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(Arc::clone(e));
        }
        let spec = self.manifest.artifact(name)?.clone();
        let exe = Arc::new(self.engine.load_artifact(&spec)?);
        self.cache.lock().unwrap().insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    pub fn names(&self) -> Vec<String> {
        self.manifest.artifacts.keys().cloned().collect()
    }
}
