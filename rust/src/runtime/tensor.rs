//! Host-side tensor representation + conversion to/from `xla::Literal`.
//!
//! The runtime moves flat buffers across the PJRT boundary; this type keeps
//! shape/dtype metadata attached so the coordinator's data plane (routing,
//! encode/decode) can operate on plain slices.

use anyhow::{bail, Context, Result};

use super::manifest::{DType, TensorSpec};

#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U32(Vec<u32>),
}

#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> HostTensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor { shape, data: TensorData::I32(data) }
    }

    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(shape: &[usize]) -> HostTensor {
        HostTensor::f32(shape.to_vec(), vec![0.0; shape.iter().product()])
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * 4
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
            TensorData::U32(_) => DType::U32,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut [f32]> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn matches(&self, spec: &TensorSpec) -> bool {
        self.shape == spec.shape && self.dtype() == spec.dtype
    }

    /// Upload to an XLA literal (host->host copy on the CPU plugin).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<usize> = self.shape.clone();
        let lit = match &self.data {
            TensorData::F32(v) => {
                let mut l = xla::Literal::create_from_shape(xla::PrimitiveType::F32, &dims);
                l.copy_raw_from(v).context("copy f32")?;
                l
            }
            TensorData::I32(v) => {
                let mut l = xla::Literal::create_from_shape(xla::PrimitiveType::S32, &dims);
                l.copy_raw_from(v).context("copy i32")?;
                l
            }
            TensorData::U32(v) => {
                let mut l = xla::Literal::create_from_shape(xla::PrimitiveType::U32, &dims);
                l.copy_raw_from(v).context("copy u32")?;
                l
            }
        };
        Ok(lit)
    }

    /// Download from an XLA literal.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let data = match shape.element_type() {
            xla::ElementType::F32 => TensorData::F32(lit.to_vec::<f32>()?),
            xla::ElementType::S32 => TensorData::I32(lit.to_vec::<i32>()?),
            xla::ElementType::U32 => TensorData::U32(lit.to_vec::<u32>()?),
            other => bail!("unsupported element type {other:?}"),
        };
        Ok(HostTensor { shape: dims, data })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_accounting() {
        let t = HostTensor::f32(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.elements(), 6);
        assert_eq!(t.byte_len(), 24);
    }

    #[test]
    #[should_panic]
    fn mismatched_shape_panics() {
        HostTensor::f32(vec![2, 2], vec![0.0; 3]);
    }
}
