//! L3 runtime: PJRT client wrapper (load + execute AOT artifacts).
//!
//! `Engine` owns the PJRT CPU client; `ArtifactSet` maps a manifest
//! directory to lazily-compiled `Executable`s; `HostTensor` is the host
//! representation crossing the boundary.

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{ArtifactSet, Engine, Executable};
pub use manifest::{ArtifactSpec, DType, Manifest, ModelConfig, TensorSpec};
pub use tensor::{HostTensor, TensorData};
