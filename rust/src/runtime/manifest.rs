//! `manifest.json` loader — the contract between the Python AOT pipeline
//! and the Rust runtime. One manifest per artifact directory describes the
//! model config, the flattened parameter order, and every artifact's
//! input/output tensor interface.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unknown dtype {other:?}"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let name = j.field("name").map_err(|e| anyhow!(e))?
            .as_str().context("name not a string")?.to_string();
        let shape = j.field("shape").map_err(|e| anyhow!(e))?
            .as_arr().context("shape not an array")?
            .iter().map(|v| v.as_usize().context("bad dim")).collect::<Result<_>>()?;
        let dtype = DType::parse(
            j.field("dtype").map_err(|e| anyhow!(e))?
                .as_str().context("dtype not a string")?)?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model hyperparameters mirrored from python/compile/config.py.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub arch: String,
    pub task: String,
    pub vocab_size: usize,
    pub n_classes: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_blocks: usize,
    pub n_experts: usize,
    pub seq_len: usize,
    pub capacity_factor: f64,
    pub batch_size: usize,
}

impl ModelConfig {
    fn from_json(j: &Json) -> Result<ModelConfig> {
        let gs = |k: &str| -> Result<String> {
            Ok(j.field(k).map_err(|e| anyhow!(e))?
                .as_str().with_context(|| format!("{k} not a string"))?.to_string())
        };
        let gu = |k: &str| -> Result<usize> {
            j.field(k).map_err(|e| anyhow!(e))?
                .as_usize().with_context(|| format!("{k} not a number"))
        };
        Ok(ModelConfig {
            name: gs("name")?,
            arch: gs("arch")?,
            task: gs("task")?,
            vocab_size: gu("vocab_size")?,
            n_classes: gu("n_classes")?,
            d_model: gu("d_model")?,
            n_heads: gu("n_heads")?,
            d_ff: gu("d_ff")?,
            n_blocks: gu("n_blocks")?,
            n_experts: gu("n_experts")?,
            seq_len: gu("seq_len")?,
            capacity_factor: j.field("capacity_factor").map_err(|e| anyhow!(e))?
                .as_f64().context("capacity_factor")?,
            batch_size: gu("batch_size")?,
        })
    }

    pub fn tokens_per_batch(&self) -> usize {
        self.batch_size * self.seq_len
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub kind: String,
    pub config: ModelConfig,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// quality manifests: flattened (name, shape) parameter order
    pub param_specs: Vec<(String, Vec<usize>)>,
    pub param_count: usize,
    pub n_moe_blocks: usize,
    pub capacity: usize,
    /// ops manifests
    pub tokens: usize,
    pub capacities: BTreeMap<usize, usize>,
    pub token_bytes: usize,
    pub expert_param_bytes: usize,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;

        let kind = j.field("kind").map_err(|e| anyhow!(e))?
            .as_str().context("kind")?.to_string();
        let config = ModelConfig::from_json(j.field("config").map_err(|e| anyhow!(e))?)?;

        let mut artifacts = BTreeMap::new();
        for (name, a) in j.field("artifacts").map_err(|e| anyhow!(e))?
            .as_obj().context("artifacts not an object")? {
            let file = dir.join(a.field("file").map_err(|e| anyhow!(e))?
                .as_str().context("file")?);
            let inputs = a.field("inputs").map_err(|e| anyhow!(e))?
                .as_arr().context("inputs")?
                .iter().map(TensorSpec::from_json).collect::<Result<_>>()?;
            let outputs = a.field("outputs").map_err(|e| anyhow!(e))?
                .as_arr().context("outputs")?
                .iter().map(TensorSpec::from_json).collect::<Result<_>>()?;
            artifacts.insert(name.clone(), ArtifactSpec {
                name: name.clone(), file, inputs, outputs,
            });
        }

        let mut param_specs = Vec::new();
        if let Some(ps) = j.get("param_specs").and_then(|v| v.as_arr()) {
            for entry in ps {
                let pair = entry.as_arr().context("param spec not a pair")?;
                let name = pair[0].as_str().context("param name")?.to_string();
                let shape = pair[1].as_arr().context("param shape")?
                    .iter().map(|v| v.as_usize().context("dim")).collect::<Result<_>>()?;
                param_specs.push((name, shape));
            }
        }

        let mut capacities = BTreeMap::new();
        if let Some(caps) = j.get("capacities").and_then(|v| v.as_obj()) {
            for (k, v) in caps {
                capacities.insert(
                    k.parse::<usize>().context("capacity key")?,
                    v.as_usize().context("capacity value")?,
                );
            }
        }

        let gu0 = |k: &str| j.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
        Ok(Manifest {
            dir: dir.to_path_buf(),
            kind,
            config,
            artifacts,
            param_specs,
            param_count: gu0("param_count"),
            n_moe_blocks: gu0("n_moe_blocks"),
            capacity: gu0("capacity"),
            tokens: gu0("tokens"),
            capacities,
            token_bytes: gu0("token_bytes"),
            expert_param_bytes: gu0("expert_param_bytes"),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name)
            .with_context(|| format!("artifact {name:?} not in manifest {}", self.dir.display()))
    }
}
