//! Overlap-efficiency metrics over DES spans: per-resource utilization,
//! the hidden-communication fraction (the paper's "up to 100% overlap"
//! claim as a measured per-schedule number), and per-stage pipeline
//! bubble fractions for whole-model timelines.
//!
//! A communication span is *hidden* while compute is simultaneously busy
//! on the hardware it occupies: a `Comm(d)` stream against device `d`'s
//! compute stream, a shared `Link(n)` uplink against any compute stream
//! of node `n`'s devices. Hidden + exposed always equals total comm time.

use std::collections::BTreeMap;

use crate::simtime::{makespan, Resource, Span};

/// Busy time and utilization of one exclusive resource.
#[derive(Debug, Clone, Copy)]
pub struct ResourceUtil {
    pub resource: Resource,
    /// Summed span durations on this resource (seconds).
    pub busy: f64,
    /// `busy / makespan`, in [0, 1].
    pub utilization: f64,
}

/// Per-resource busy/utilization, in `Resource` order. `Free` spans are
/// skipped (unlimited concurrency has no utilization).
pub fn utilization(spans: &[Span]) -> Vec<ResourceUtil> {
    let ms = makespan(spans);
    let mut busy: BTreeMap<Resource, f64> = BTreeMap::new();
    for s in spans {
        if !matches!(s.resource, Resource::Free) {
            *busy.entry(s.resource).or_insert(0.0) += s.end - s.start;
        }
    }
    busy.into_iter()
        .map(|(resource, b)| ResourceUtil {
            resource,
            busy: b,
            utilization: if ms > 0.0 { b / ms } else { 0.0 },
        })
        .collect()
}

/// Total communication time and the part of it hidden behind compute.
#[derive(Debug, Clone, Copy, Default)]
pub struct CommOverlap {
    /// Summed durations of every `Comm`/`Link` span (seconds).
    pub total: f64,
    /// Part of `total` during which compute was busy on the same device
    /// (comm stream) or on some device of the same node (uplink).
    pub hidden: f64,
}

impl CommOverlap {
    /// Comm time left in the open: `total - hidden`.
    pub fn exposed(&self) -> f64 {
        self.total - self.hidden
    }

    /// The headline metric: `hidden / total` (0 when there is no comm).
    pub fn hidden_fraction(&self) -> f64 {
        if self.total > 0.0 {
            self.hidden / self.total
        } else {
            0.0
        }
    }
}

/// Measure comm/compute overlap. `devices_per_node` maps a `Link(n)`
/// uplink to its node's compute streams (devices `n*dpn .. (n+1)*dpn`);
/// model-composed timelines keep this mapping because stages remap
/// devices and links by the same stride.
pub fn comm_overlap(spans: &[Span], devices_per_node: usize) -> CommOverlap {
    assert!(devices_per_node > 0, "devices_per_node must be positive");
    let mut compute: BTreeMap<usize, Vec<(f64, f64)>> = BTreeMap::new();
    for s in spans {
        if let Resource::Compute(d) = s.resource {
            compute.entry(d).or_default().push((s.start, s.end));
        }
    }
    let mut out = CommOverlap::default();
    for s in spans {
        let devs: Vec<usize> = match s.resource {
            Resource::Comm(d) => vec![d],
            Resource::Link(n) => {
                (n * devices_per_node..(n + 1) * devices_per_node).collect()
            }
            _ => continue,
        };
        out.total += s.end - s.start;
        let ivs: Vec<(f64, f64)> = devs
            .iter()
            .filter_map(|d| compute.get(d))
            .flatten()
            .copied()
            .collect();
        out.hidden += overlap_len(&merge(ivs), s.start, s.end);
    }
    out
}

/// Per-stage pipeline bubble fractions for a `build_model_sim` timeline:
/// the share of the makespan during which *no* compute stream of stage
/// `s` (devices `s*devices_per_stage ..`) is busy. One entry per stage,
/// each in [0, 1].
pub fn stage_bubbles(spans: &[Span], stages: usize,
                     devices_per_stage: usize) -> Vec<f64> {
    let ms = makespan(spans);
    (0..stages)
        .map(|st| {
            let lo = st * devices_per_stage;
            let hi = lo + devices_per_stage;
            let ivs: Vec<(f64, f64)> = spans
                .iter()
                .filter_map(|s| match s.resource {
                    Resource::Compute(d) if d >= lo && d < hi => {
                        Some((s.start, s.end))
                    }
                    _ => None,
                })
                .collect();
            let busy: f64 = merge(ivs).iter().map(|(a, b)| b - a).sum();
            if ms > 0.0 { 1.0 - busy / ms } else { 0.0 }
        })
        .collect()
}

/// Sort-and-merge a set of possibly overlapping intervals.
fn merge(mut ivs: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    ivs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (s, e) in ivs {
        if let Some(last) = out.last_mut() {
            if s <= last.1 {
                if e > last.1 {
                    last.1 = e;
                }
                continue;
            }
        }
        out.push((s, e));
    }
    out
}

/// Length of `[s, e] ∩ merged`, with `merged` disjoint and sorted.
fn overlap_len(merged: &[(f64, f64)], s: f64, e: f64) -> f64 {
    let mut acc = 0.0;
    for &(a, b) in merged {
        acc += (b.min(e) - a.max(s)).max(0.0);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::Sim;

    #[test]
    fn hidden_plus_exposed_is_total() {
        let mut sim = Sim::new();
        let a = sim.add("comp1", Resource::Compute(0), 2.0, &[]);
        sim.add("comm", Resource::Comm(0), 3.0, &[a]);
        sim.add("comp2", Resource::Compute(0), 1.0, &[a]);
        let spans = sim.run();
        let ov = comm_overlap(&spans, 1);
        assert_eq!(ov.total, 3.0);
        assert_eq!(ov.hidden, 1.0); // comm [2,5] vs compute [2,3]
        assert_eq!(ov.exposed(), 2.0);
        assert!((ov.hidden_fraction() - 1.0 / 3.0).abs() < 1e-15);
    }

    #[test]
    fn uplink_hides_behind_any_node_device() {
        let mut sim = Sim::new();
        sim.add("c0", Resource::Compute(0), 1.0, &[]);
        sim.add("c1", Resource::Compute(1), 3.0, &[]);
        sim.add("x", Resource::Link(0), 2.0, &[]);
        let ov = comm_overlap(&sim.run(), 2);
        assert_eq!(ov.total, 2.0);
        assert_eq!(ov.hidden, 2.0); // device 1 is busy the whole window
    }

    #[test]
    fn utilization_in_unit_interval() {
        let mut sim = Sim::new();
        let a = sim.add("a", Resource::Compute(0), 2.0, &[]);
        sim.add("b", Resource::Comm(0), 1.0, &[a]);
        sim.add("f", Resource::Free, 10.0, &[]);
        for u in utilization(&sim.run()) {
            assert!(u.utilization >= 0.0 && u.utilization <= 1.0);
            assert!(!matches!(u.resource, Resource::Free));
        }
    }

    #[test]
    fn bubbles_count_compute_gaps() {
        let mut sim = Sim::new();
        // stage 0 busy [0,1]; stage 1 busy [3,4]; makespan 4
        let a = sim.add("s0", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("x", Resource::Comm(0), 2.0, &[a]);
        sim.add("s1", Resource::Compute(1), 1.0, &[b]);
        let bub = stage_bubbles(&sim.run(), 2, 1);
        assert_eq!(bub, vec![0.75, 0.75]);
    }
}
