//! Chrome-trace-event JSON export (chrome://tracing / Perfetto "Trace
//! Event Format") for any DES timeline, via `util::json`.
//!
//! Layout: one *process* per node (`pid` = node index, `Link(n)` rows on
//! node `n`), one *thread* per resource row (`tid` = the row's rank in
//! `Resource` order, named via `thread_name` metadata with the shared
//! [`Resource::row_label`] tokens). Every span becomes a complete
//! (`"ph":"X"`) event with microsecond `ts`/`dur`; its `args` carry the
//! analysis layer's verdict — `crit` (on the critical path) and
//! `slack_us`.
//!
//! Determinism: metadata events first (processes, then threads, in
//! sorted order), then spans in task-id order; objects serialize with
//! sorted keys (`util::json` uses a BTreeMap). On dyadic timelines every
//! number is an exact integer, so the output is byte-for-byte
//! reproducible (pinned by `rust/tests/golden/trace_fleet.json` and the
//! mirror).

use std::collections::{BTreeMap, BTreeSet};

use crate::simtime::{Resource, Sim, TracedRun};
use crate::util::json::{num, obj, s, Json};

use super::critpath::{critical_path, slack};

/// Node (= Chrome process) owning a resource row.
fn node_of(r: Resource, devices_per_node: usize) -> usize {
    match r {
        Resource::Compute(d)
        | Resource::Comm(d)
        | Resource::H2D(d)
        | Resource::D2H(d) => d / devices_per_node,
        Resource::Link(n) => n,
        Resource::Free => 0,
    }
}

/// Serialize a traced run as Chrome-trace-event JSON (one line, no
/// trailing newline). `devices_per_node` maps device rows to their node
/// process, matching [`super::overlap::comm_overlap`].
pub fn chrome_trace(sim: &Sim, run: &TracedRun,
                    devices_per_node: usize) -> String {
    assert!(devices_per_node > 0, "devices_per_node must be positive");
    let on_path: BTreeSet<usize> = critical_path(run).into_iter().collect();
    let slacks = slack(sim, run);

    // tid = rank of the resource row in Resource order
    let resources: BTreeSet<Resource> =
        run.spans.iter().map(|sp| sp.resource).collect();
    let tid: BTreeMap<Resource, usize> = resources
        .iter()
        .enumerate()
        .map(|(i, r)| (*r, i))
        .collect();
    let pids: BTreeSet<usize> = resources
        .iter()
        .map(|r| node_of(*r, devices_per_node))
        .collect();

    let mut events: Vec<Json> = Vec::new();
    for p in &pids {
        events.push(obj(vec![
            ("args", obj(vec![("name", s(&format!("node{p}")))])),
            ("name", s("process_name")),
            ("ph", s("M")),
            ("pid", num(*p as f64)),
        ]));
    }
    for r in &resources {
        events.push(obj(vec![
            ("args", obj(vec![("name", s(&r.row_label()))])),
            ("name", s("thread_name")),
            ("ph", s("M")),
            ("pid", num(node_of(*r, devices_per_node) as f64)),
            ("tid", num(tid[r] as f64)),
        ]));
    }
    for sp in &run.spans {
        events.push(obj(vec![
            ("args", obj(vec![
                ("crit", Json::Bool(on_path.contains(&sp.id))),
                ("slack_us", num(slacks[sp.id] * 1e6)),
            ])),
            ("cat", s("sim")),
            ("dur", num((sp.end - sp.start) * 1e6)),
            ("name", s(&sp.label)),
            ("ph", s("X")),
            ("pid", num(node_of(sp.resource, devices_per_node) as f64)),
            ("tid", num(tid[&sp.resource] as f64)),
            ("ts", num(sp.start * 1e6)),
        ]));
    }
    obj(vec![
        ("displayTimeUnit", s("ms")),
        ("traceEvents", Json::Arr(events)),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::Sim;
    use crate::util::json::Json;

    fn toy() -> Sim {
        let mut sim = Sim::new();
        let a = sim.add("Attn(l)", Resource::Compute(0), 1.0, &[]);
        sim.add("A2A-Dx0", Resource::Link(0), 2.0, &[a]);
        sim.add("MLP(l)", Resource::Compute(1), 0.5, &[a]);
        sim
    }

    #[test]
    fn trace_parses_and_counts_events() {
        let sim = toy();
        let run = sim.run_traced();
        let txt = chrome_trace(&sim, &run, 2);
        let v = Json::parse(&txt).unwrap();
        assert_eq!(v.get("displayTimeUnit").unwrap().as_str(), Some("ms"));
        let ev = v.get("traceEvents").unwrap().as_arr().unwrap();
        // 1 process + 3 thread rows + 3 spans
        assert_eq!(ev.len(), 7);
        let span_evs: Vec<&Json> = ev
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str() == Some("X"))
            .collect();
        assert_eq!(span_evs.len(), 3);
        // the dispatch uplink is critical and slack-free
        let a2a = span_evs
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("A2A-Dx0"))
            .unwrap();
        assert_eq!(a2a.get("args").unwrap().get("crit").unwrap().as_bool(),
                   Some(true));
        assert_eq!(
            a2a.get("args").unwrap().get("slack_us").unwrap().as_f64(),
            Some(0.0)
        );
        assert_eq!(a2a.get("ts").unwrap().as_f64(), Some(1e6));
        assert_eq!(a2a.get("dur").unwrap().as_f64(), Some(2e6));
    }

    #[test]
    fn thread_names_use_row_labels() {
        let sim = toy();
        let run = sim.run_traced();
        let txt = chrome_trace(&sim, &run, 2);
        assert!(txt.contains("\"compute[0]\""));
        assert!(txt.contains("\"link[0]\""));
        assert!(txt.contains("\"node0\""));
    }
}
