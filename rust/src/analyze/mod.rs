//! Timeline analytics over DES output: where did the makespan go, and
//! how much communication actually hid behind compute?
//!
//! Every simulation in this repo ends in a `Vec<Span>`; this module is
//! the layer that turns those spans into explanations:
//!
//! - [`critpath`] — realized blocking graph, critical path, per-task
//!   slack, and makespan attribution into backbone / expert / dispatch /
//!   combine / migration / idle buckets.
//! - [`overlap`] — per-resource utilization, the hidden-communication
//!   fraction (the measured counterpart of the paper's overlap claim),
//!   and per-stage pipeline bubbles for whole-model timelines.
//! - [`export`] — Chrome-trace-event JSON so any timeline opens in
//!   Perfetto / `chrome://tracing`, with slack and critical-path
//!   verdicts attached to every span.
//!
//! Everything here is deterministic and is mirrored op-for-op by
//! `tools/des_mirror/mirror2.py`, which mints the golden corpus in
//! `rust/tests/golden/analyze.txt` and `trace_fleet.json`.

pub mod critpath;
pub mod export;
pub mod overlap;

pub use critpath::{attribute, category, critical_path, makespan_with_zeroed,
                   slack, Attribution, Category};
pub use export::chrome_trace;
pub use overlap::{comm_overlap, stage_bubbles, utilization, CommOverlap,
                  ResourceUtil};
