//! Critical-path extraction, per-task slack, and makespan attribution
//! over a traced DES run.
//!
//! The realized blocking graph has two edge kinds: the DAG dependencies
//! the schedule was built with, and the resource-serialization edges the
//! engine *realized* (for each span, the predecessor whose finish gated
//! its start — [`crate::simtime::Blocker`]). Because the engine is
//! work-conserving, walking blockers back from the latest-finishing span
//! yields a time-contiguous chain from t = 0 whose durations telescope to
//! the makespan exactly — the critical path. Slack is classic CPM over
//! the full realized edge set (every dep edge plus the per-resource
//! execution order), so a task's slack is how much it could stretch
//! without moving the makespan *given the realized schedule*.

use std::collections::BTreeMap;

use crate::simtime::{makespan, Resource, Sim, TaskId, TracedRun};

/// Makespan-attribution category, classified from the schedule layer's
/// task-label vocabulary (`coordinator::schedule`) plus the resource.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    /// Backbone compute: `Attn(l)` / `MLP(l)` / `Attn(l+1)` / `SE` /
    /// `Gate` / `Encode` / `Decode` (+ the model layer's zero-duration
    /// `Join-*` bookkeeping).
    Backbone,
    /// Expert FFN compute: `Expert` / `Expert{i}` chunks.
    Expert,
    /// Dispatch All-to-All: `A2A-D*` (intra) and `A2A-Dx*` (uplink).
    Dispatch,
    /// Combine All-to-All: `A2A-C*` / `A2A-Cx*`.
    Combine,
    /// Live re-placement traffic: anything on an H2D or D2H engine
    /// (`H2D-E{e}` writes, `D2H-E{e}` read-outs).
    Migration,
}

/// Classify one task. Migration is recognized by resource (every task on
/// a transfer engine is re-placement traffic); the A2A split and expert
/// compute by label prefix; everything else is backbone.
pub fn category(label: &str, resource: Resource) -> Category {
    if matches!(resource, Resource::H2D(_) | Resource::D2H(_)) {
        return Category::Migration;
    }
    if label.starts_with("A2A-D") {
        return Category::Dispatch;
    }
    if label.starts_with("A2A-C") {
        return Category::Combine;
    }
    if label.starts_with("Expert") {
        return Category::Expert;
    }
    Category::Backbone
}

/// The critical path: task ids in time order, from a t = 0 task to the
/// latest-finishing span (lowest id on ties), following each task's
/// realized blocking predecessor. The chain is time-contiguous, so the
/// path's summed durations equal the makespan exactly.
pub fn critical_path(run: &TracedRun) -> Vec<TaskId> {
    if run.spans.is_empty() {
        return Vec::new();
    }
    let mut sink = 0usize;
    for s in &run.spans {
        if s.end > run.spans[sink].end {
            sink = s.id;
        }
    }
    let mut path = vec![sink];
    let mut cur = sink;
    while let Some(b) = run.blockers[cur] {
        cur = b.pred;
        path.push(cur);
    }
    path.reverse();
    path
}

/// Per-task slack (seconds): how much each task's duration could grow
/// without moving the makespan, holding the realized schedule's edge set
/// fixed (dep edges plus the execution order on every exclusive
/// resource). Critical-path tasks have slack 0.
pub fn slack(sim: &Sim, run: &TracedRun) -> Vec<f64> {
    let n = run.spans.len();
    let ms = makespan(&run.spans);
    let succs = realized_succs(sim, run);
    // backward CPM pass in reverse topological order (Kahn)
    let mut indeg = vec![0usize; n];
    for ss in &succs {
        for &s in ss {
            indeg[s] += 1;
        }
    }
    let mut stack: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(i) = stack.pop() {
        order.push(i);
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                stack.push(s);
            }
        }
    }
    assert_eq!(order.len(), n, "realized edge set must be acyclic");
    let mut lf = vec![ms; n];
    for &i in order.iter().rev() {
        for &s in &succs[i] {
            let cand = lf[s] - (run.spans[s].end - run.spans[s].start);
            if cand < lf[i] {
                lf[i] = cand;
            }
        }
    }
    (0..n).map(|i| lf[i] - run.spans[i].end).collect()
}

/// The realized successor lists: every dep edge plus the execution order
/// on each exclusive resource (sorted by start, end, id). This edge set
/// *explains* the schedule — each task's start is exactly the latest
/// finish among its predecessors here — so CPM over it is sound.
fn realized_succs(sim: &Sim, run: &TracedRun) -> Vec<Vec<TaskId>> {
    let n = run.spans.len();
    let mut succs: Vec<Vec<TaskId>> = vec![Vec::new(); n];
    for (id, t) in sim.tasks().iter().enumerate() {
        for &d in &t.deps {
            succs[d].push(id);
        }
    }
    let mut by_res: BTreeMap<Resource, Vec<TaskId>> = BTreeMap::new();
    for s in &run.spans {
        if !matches!(s.resource, Resource::Free) {
            by_res.entry(s.resource).or_default().push(s.id);
        }
    }
    for ids in by_res.values_mut() {
        ids.sort_by(|a, b| {
            run.spans[*a]
                .start
                .total_cmp(&run.spans[*b].start)
                .then(run.spans[*a].end.total_cmp(&run.spans[*b].end))
                .then(a.cmp(b))
        });
        for w in ids.windows(2) {
            succs[w[0]].push(w[1]);
        }
    }
    succs
}

/// Makespan of the counterfactual schedule in which task `zero` takes no
/// time, holding the realized execution order fixed — a forward CPM pass
/// over the same edge set [`slack`] uses, with task durations taken from
/// the specs. With `zero = None` it replays the schedule as-is and
/// reproduces the makespan bit-exactly (the edge set explains every
/// start time). This is deliberately *not* an engine re-run: list
/// scheduling is not anomaly-free — shortening a task can reorder a
/// resource queue downstream and move the makespan (the dyadic
/// `Top1/pipe2` corpus timeline exhibits exactly that, found empirically
/// by the mirror) — whereas slack is defined over the realized order,
/// where zeroing any positive-slack task provably changes nothing.
pub fn makespan_with_zeroed(sim: &Sim, run: &TracedRun,
                            zero: Option<TaskId>) -> f64 {
    let n = run.spans.len();
    let succs = realized_succs(sim, run);
    let mut indeg = vec![0usize; n];
    for ss in &succs {
        for &s in ss {
            indeg[s] += 1;
        }
    }
    let mut stack: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut es = vec![0.0f64; n];
    let mut ms = 0.0f64;
    let mut seen = 0usize;
    while let Some(i) = stack.pop() {
        seen += 1;
        let dur = if zero == Some(i) { 0.0 } else { sim.tasks()[i].duration };
        let ef = es[i] + dur;
        if ef > ms {
            ms = ef;
        }
        for &s in &succs[i] {
            if ef > es[s] {
                es[s] = ef;
            }
            indeg[s] -= 1;
            if indeg[s] == 0 {
                stack.push(s);
            }
        }
    }
    assert_eq!(seen, n, "realized edge set must be acyclic");
    ms
}

/// Makespan attribution: the total time partitioned into the categories
/// of the critical-path tasks plus residual idle. Because the blocking
/// chain is contiguous, `idle` is zero (up to float association) on every
/// schedule the engine produces — it exists so the partition is exact by
/// construction and stays honest if release times ever appear.
#[derive(Debug, Clone, Copy, Default)]
pub struct Attribution {
    pub makespan: f64,
    pub backbone: f64,
    pub expert: f64,
    pub dispatch: f64,
    pub combine: f64,
    pub migration: f64,
    pub idle: f64,
}

impl Attribution {
    /// Sum of the five labeled categories (== `makespan - idle`).
    pub fn categorized(&self) -> f64 {
        self.backbone + self.expert + self.dispatch + self.combine
            + self.migration
    }
}

/// Attribute the makespan to critical-path task categories.
pub fn attribute(run: &TracedRun) -> Attribution {
    let ms = makespan(&run.spans);
    let mut a = Attribution { makespan: ms, ..Attribution::default() };
    for id in critical_path(run) {
        let s = &run.spans[id];
        let dur = s.end - s.start;
        match category(&s.label, s.resource) {
            Category::Backbone => a.backbone += dur,
            Category::Expert => a.expert += dur,
            Category::Dispatch => a.dispatch += dur,
            Category::Combine => a.combine += dur,
            Category::Migration => a.migration += dur,
        }
    }
    a.idle = ms - a.categorized();
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simtime::Sim;

    fn diamond() -> Sim {
        let mut sim = Sim::new();
        let a = sim.add("Attn(l)", Resource::Compute(0), 1.0, &[]);
        let b = sim.add("A2A-D", Resource::Comm(0), 4.0, &[a]);
        let c = sim.add("MLP(l)", Resource::Compute(0), 2.0, &[a]);
        sim.add("Expert", Resource::Compute(0), 1.0, &[b, c]);
        sim
    }

    #[test]
    fn path_durations_telescope_to_makespan() {
        let sim = diamond();
        let run = sim.run_traced();
        let path = critical_path(&run);
        let len: f64 = path.iter()
            .map(|&i| run.spans[i].end - run.spans[i].start)
            .sum();
        assert_eq!(len, makespan(&run.spans));
        // a -> comm -> expert, not through the slack-y MLP
        assert_eq!(path, vec![0, 1, 3]);
    }

    #[test]
    fn slack_zero_on_path_positive_off_path() {
        let sim = diamond();
        let run = sim.run_traced();
        let sl = slack(&sim, &run);
        assert_eq!(sl[0], 0.0);
        assert_eq!(sl[1], 0.0);
        assert_eq!(sl[3], 0.0);
        // MLP ends at 3, the expert can't start before 5: slack 2
        assert_eq!(sl[2], 2.0);
    }

    #[test]
    fn attribution_partitions_makespan() {
        let sim = diamond();
        let run = sim.run_traced();
        let a = attribute(&run);
        assert_eq!(a.makespan, 6.0);
        assert_eq!(a.backbone, 1.0);
        assert_eq!(a.dispatch, 4.0);
        assert_eq!(a.expert, 1.0);
        assert_eq!(a.idle, 0.0);
        assert_eq!(a.categorized() + a.idle, a.makespan);
    }

    #[test]
    fn counterfactual_replay_respects_slack() {
        let sim = diamond();
        let run = sim.run_traced();
        let ms = makespan(&run.spans);
        assert_eq!(makespan_with_zeroed(&sim, &run, None), ms);
        // zeroing the slack-2 MLP leaves the makespan alone...
        assert_eq!(makespan_with_zeroed(&sim, &run, Some(2)), ms);
        // ...zeroing the critical dispatch collapses it to the MLP path
        assert_eq!(makespan_with_zeroed(&sim, &run, Some(1)), 4.0);
    }

    #[test]
    fn migration_category_is_resource_keyed() {
        assert_eq!(category("H2D-E3", Resource::H2D(1)), Category::Migration);
        assert_eq!(category("D2H-E3", Resource::D2H(1)), Category::Migration);
        assert_eq!(category("A2A-Dx1", Resource::Link(0)),
                   Category::Dispatch);
        assert_eq!(category("A2A-Cx0", Resource::Link(0)), Category::Combine);
        assert_eq!(category("Expert2", Resource::Compute(0)),
                   Category::Expert);
        assert_eq!(category("Join-L0M0", Resource::Free), Category::Backbone);
    }
}
