//! Training driver: owns the loop around the AOT `train_step` artifact.
//! Python never runs here — the step function is a compiled executable and
//! all state (params + Adam moments) stays in XLA literals between steps.

pub mod checkpoint;
pub mod driver;

pub use driver::{EvalResult, StepRecord, TrainOptions, Trainer};
