//! The training loop.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::{ClsTask, Corpus};
use crate::metrics::CsvWriter;
use crate::runtime::{ArtifactSet, HostTensor};

#[derive(Debug, Clone)]
pub struct TrainOptions {
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub seed: i32,
    pub log_csv: Option<PathBuf>,
    /// Also log the Fig.11 instrumentation rows.
    pub stats_csv: Option<PathBuf>,
    pub verbose: bool,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions {
            steps: 100,
            eval_every: 50,
            eval_batches: 4,
            seed: 0,
            log_csv: None,
            stats_csv: None,
            verbose: true,
        }
    }
}

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f32,
    pub aux: f32,
    pub acc: f32,
    pub secs: f64,
}

#[derive(Debug, Clone)]
pub struct EvalResult {
    pub step: usize,
    pub loss: f32,
    pub acc: f32,
    pub ppl: f32,
}

pub struct Trainer<'a> {
    set: &'a ArtifactSet,
    /// params ++ m ++ v, kept as XLA literals between steps (outputs of step
    /// t feed straight into step t+1 — no host conversion of the state).
    state: Vec<xla::Literal>,
    n_params: usize,
    corpus: Option<Corpus>,
    cls: Option<ClsTask>,
    pub records: Vec<StepRecord>,
    pub evals: Vec<EvalResult>,
    pub stats_rows: Vec<(usize, Vec<f32>)>,
    pub step: usize,
}

impl<'a> Trainer<'a> {
    /// Initialize from the manifest's `init` artifact.
    pub fn new(set: &'a ArtifactSet, seed: i32) -> Result<Trainer<'a>> {
        let cfg = &set.manifest.config;
        let n_params = set.manifest.param_specs.len();
        if n_params == 0 {
            bail!("manifest {} is not a quality manifest", set.manifest.dir.display());
        }
        let init = set.get("init")?;
        let params = init.run_raw(&[HostTensor::scalar_i32(seed).to_literal()?])?;

        // zero moments: reuse init shapes
        let mut state = params;
        for i in 0..n_params {
            let spec = &set.manifest.param_specs[i];
            let z = HostTensor::zeros(&spec.1).to_literal()?;
            state.push(z);
        }
        for i in 0..n_params {
            let spec = &set.manifest.param_specs[i];
            let z = HostTensor::zeros(&spec.1).to_literal()?;
            state.push(z);
        }

        let (corpus, cls) = match cfg.task.as_str() {
            "lm" => (Some(Corpus::bundled()?), None),
            "cls" => (None, Some(ClsTask::new(cfg.n_classes, cfg.vocab_size.min(256)))),
            other => bail!("unknown task {other}"),
        };
        Ok(Trainer {
            set,
            state,
            n_params,
            corpus,
            cls,
            records: Vec::new(),
            evals: Vec::new(),
            stats_rows: Vec::new(),
            step: 0,
        })
    }

    fn batch_literals(&self, step: u64) -> Result<(xla::Literal, xla::Literal)> {
        let cfg = &self.set.manifest.config;
        let (b, s) = (cfg.batch_size, cfg.seq_len);
        match (&self.corpus, &self.cls) {
            (Some(c), _) => {
                let batch = c.train_batch(step, b, s);
                Ok((
                    HostTensor::i32(vec![b, s], batch.tokens).to_literal()?,
                    HostTensor::i32(vec![b, s], batch.targets).to_literal()?,
                ))
            }
            (_, Some(t)) => {
                let batch = t.batch(step, b, s);
                Ok((
                    HostTensor::i32(vec![b, s], batch.tokens).to_literal()?,
                    HostTensor::i32(vec![b], batch.labels).to_literal()?,
                ))
            }
            _ => unreachable!(),
        }
    }

    /// Run one training step; returns the record.
    pub fn train_step(&mut self) -> Result<StepRecord> {
        let exe = self.set.get("train_step")?;
        let (tokens, targets) = self.batch_literals(self.step as u64)?;
        let t0 = Instant::now();

        let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
        let step_lit = HostTensor::scalar_i32(self.step as i32).to_literal()?;
        let seed_lit = HostTensor::scalar_i32(self.step as i32 + 7919).to_literal()?;
        inputs.push(&step_lit);
        inputs.push(&tokens);
        inputs.push(&targets);
        inputs.push(&seed_lit);

        let outs = exe.run_raw(&inputs)?;
        let n3 = 3 * self.n_params;
        let loss = HostTensor::from_literal(&outs[n3])?.as_f32()?[0];
        let aux = HostTensor::from_literal(&outs[n3 + 1])?.as_f32()?[0];
        let acc = HostTensor::from_literal(&outs[n3 + 2])?.as_f32()?[0];
        let stats = HostTensor::from_literal(&outs[n3 + 3])?;
        if !stats.shape.is_empty() && stats.elements() > 0 {
            self.stats_rows.push((self.step, stats.as_f32()?.to_vec()));
        }
        self.state = outs.into_iter().take(n3).collect();

        let rec = StepRecord {
            step: self.step,
            loss,
            aux,
            acc,
            secs: t0.elapsed().as_secs_f64(),
        };
        if !loss.is_finite() {
            bail!("non-finite loss at step {}: {loss}", self.step);
        }
        self.step += 1;
        self.records.push(rec.clone());
        Ok(rec)
    }

    /// Run `n_calls` invocations of the fused multi-step artifact
    /// (`train_step_<m>`, lowered with lax.scan) — the §Perf hot-path
    /// optimization: state crosses the PJRT boundary once per m steps.
    pub fn train_steps_fused(&mut self, n_calls: usize) -> Result<Vec<StepRecord>> {
        let cfg = &self.set.manifest.config;
        let (b, s) = (cfg.batch_size, cfg.seq_len);
        // discover the fused artifact and its step multiplicity
        let name = self.set.names().into_iter()
            .find(|n| n.starts_with("train_step_"))
            .context("no fused train_step_<n> artifact in manifest")?;
        let multi: usize = name["train_step_".len()..].parse()?;
        let exe = self.set.get(&name)?;
        let mut out_records = Vec::new();
        for _ in 0..n_calls {
            // stack `multi` batches
            let mut toks = Vec::with_capacity(multi * b * s);
            let mut tgts = Vec::new();
            for i in 0..multi {
                let (t, g) = match (&self.corpus, &self.cls) {
                    (Some(c), _) => {
                        let bt = c.train_batch((self.step + i) as u64, b, s);
                        (bt.tokens, bt.targets)
                    }
                    (_, Some(t_)) => {
                        let bt = t_.batch((self.step + i) as u64, b, s);
                        (bt.tokens, bt.labels)
                    }
                    _ => unreachable!(),
                };
                toks.extend(t);
                tgts.extend(g);
            }
            let tgt_shape = if cfg.task == "lm" {
                vec![multi, b, s]
            } else {
                vec![multi, b]
            };
            let tokens = HostTensor::i32(vec![multi, b, s], toks).to_literal()?;
            let targets = HostTensor::i32(tgt_shape, tgts).to_literal()?;
            let step_lit = HostTensor::scalar_i32(self.step as i32).to_literal()?;
            let seed_lit = HostTensor::scalar_i32(self.step as i32 + 7919).to_literal()?;
            let t0 = Instant::now();
            let mut inputs: Vec<&xla::Literal> = self.state.iter().collect();
            inputs.push(&step_lit);
            inputs.push(&tokens);
            inputs.push(&targets);
            inputs.push(&seed_lit);
            let outs = exe.run_raw(&inputs)?;
            let secs = t0.elapsed().as_secs_f64();
            let n3 = 3 * self.n_params;
            let losses = HostTensor::from_literal(&outs[n3])?;
            let accs = HostTensor::from_literal(&outs[n3 + 1])?;
            let (losses, accs) = (losses.as_f32()?.to_vec(), accs.as_f32()?.to_vec());
            self.state = outs.into_iter().take(n3).collect();
            for i in 0..multi {
                let rec = StepRecord {
                    step: self.step,
                    loss: losses[i],
                    aux: 0.0,
                    acc: accs[i],
                    secs: secs / multi as f64,
                };
                if !rec.loss.is_finite() {
                    bail!("non-finite loss at fused step {}", self.step);
                }
                self.step += 1;
                self.records.push(rec.clone());
                out_records.push(rec);
            }
        }
        Ok(out_records)
    }

    /// Evaluate on held-out batches; returns loss/acc/ppl.
    pub fn evaluate(&mut self, n_batches: usize) -> Result<EvalResult> {
        let exe = self.set.get("eval_step")?;
        let cfg = &self.set.manifest.config;
        let (b, s) = (cfg.batch_size, cfg.seq_len);
        let mut losses = Vec::new();
        let mut accs = Vec::new();
        for i in 0..n_batches {
            let (tokens, targets) = match (&self.corpus, &self.cls) {
                (Some(c), _) => {
                    let batches = c.valid_batches(n_batches, b, s);
                    let bt = &batches[i];
                    (
                        HostTensor::i32(vec![b, s], bt.tokens.clone()).to_literal()?,
                        HostTensor::i32(vec![b, s], bt.targets.clone()).to_literal()?,
                    )
                }
                (_, Some(t)) => {
                    let bt = t.batch(1_000_000 + i as u64, b, s);
                    (
                        HostTensor::i32(vec![b, s], bt.tokens).to_literal()?,
                        HostTensor::i32(vec![b], bt.labels).to_literal()?,
                    )
                }
                _ => unreachable!(),
            };
            let mut lits: Vec<&xla::Literal> = self.state[..self.n_params].iter().collect();
            lits.push(&tokens);
            lits.push(&targets);
            let outs = exe.run_raw(&lits)?;
            losses.push(HostTensor::from_literal(&outs[0])?.as_f32()?[0]);
            accs.push(HostTensor::from_literal(&outs[1])?.as_f32()?[0]);
        }
        let loss = losses.iter().sum::<f32>() / losses.len() as f32;
        let acc = accs.iter().sum::<f32>() / accs.len() as f32;
        let res = EvalResult { step: self.step, loss, acc, ppl: loss.exp() };
        self.evals.push(res.clone());
        Ok(res)
    }

    /// Full loop per the options; writes CSV logs if requested.
    pub fn run(&mut self, opts: &TrainOptions) -> Result<()> {
        let mut log = match &opts.log_csv {
            Some(p) => Some(CsvWriter::create(p, &["step", "loss", "aux", "acc", "secs"])?),
            None => None,
        };
        for _ in 0..opts.steps {
            let rec = self.train_step()?;
            if let Some(w) = log.as_mut() {
                w.row(&[rec.step as f64, rec.loss as f64, rec.aux as f64,
                        rec.acc as f64, rec.secs])?;
            }
            if opts.verbose && (rec.step % 10 == 0 || rec.step + 1 == opts.steps) {
                println!("step {:5}  loss {:.4}  aux {:.4}  acc {:.3}  {:.2}s",
                         rec.step, rec.loss, rec.aux, rec.acc, rec.secs);
            }
            if opts.eval_every > 0 && (rec.step + 1) % opts.eval_every == 0 {
                let ev = self.evaluate(opts.eval_batches)?;
                if opts.verbose {
                    println!("eval@{:5}  loss {:.4}  ppl {:.2}  acc {:.3}",
                             ev.step, ev.loss, ev.ppl, ev.acc);
                }
            }
        }
        if let Some(w) = log.as_mut() {
            w.flush()?;
        }
        if let Some(p) = &opts.stats_csv {
            let n_moe = self.set.manifest.n_moe_blocks.max(1);
            let mut hdr = vec!["step".to_string()];
            for l in 0..n_moe {
                for f in ["repeat", "l2", "score_prev", "score_cur"] {
                    hdr.push(format!("moe{l}_{f}"));
                }
            }
            let hdr_refs: Vec<&str> = hdr.iter().map(|s| s.as_str()).collect();
            let mut w = CsvWriter::create(p, &hdr_refs)?;
            for (step, row) in &self.stats_rows {
                let mut vals = vec![*step as f64];
                vals.extend(row.iter().map(|v| *v as f64));
                if vals.len() == hdr.len() {
                    w.row(&vals)?;
                }
            }
            w.flush()?;
        }
        Ok(())
    }

    /// Current parameter literals (for checkpointing / inference).
    pub fn params(&self) -> &[xla::Literal] {
        &self.state[..self.n_params]
    }

    pub fn params_host(&self) -> Result<Vec<HostTensor>> {
        self.state[..self.n_params]
            .iter()
            .map(HostTensor::from_literal)
            .collect()
    }

    /// Load parameters (e.g. from a checkpoint), resetting moments.
    pub fn set_params(&mut self, params: Vec<xla::Literal>) -> Result<()> {
        if params.len() != self.n_params {
            bail!("expected {} params, got {}", self.n_params, params.len());
        }
        for (i, p) in params.into_iter().enumerate() {
            self.state[i] = p;
        }
        Ok(())
    }
}
