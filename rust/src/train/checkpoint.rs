//! Flat binary checkpoints: `param_specs`-ordered f32 tensors with a JSON
//! sidecar for shapes. No external serialization crates are available, so
//! the format is a simple length-prefixed little-endian dump.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::{HostTensor, Manifest};

const MAGIC: &[u8; 8] = b"SCMOECK1";

pub fn save(path: &Path, manifest: &Manifest, params: &[HostTensor]) -> Result<()> {
    if params.len() != manifest.param_specs.len() {
        bail!("param count mismatch");
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    for (t, (name, shape)) in params.iter().zip(&manifest.param_specs) {
        if &t.shape != shape {
            bail!("checkpoint shape mismatch for {name}");
        }
        let data = t.as_f32()?;
        f.write_all(&(data.len() as u64).to_le_bytes())?;
        for v in data {
            f.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

pub fn load(path: &Path, manifest: &Manifest) -> Result<Vec<HostTensor>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad checkpoint magic");
    }
    let mut n8 = [0u8; 8];
    f.read_exact(&mut n8)?;
    let n = u64::from_le_bytes(n8) as usize;
    if n != manifest.param_specs.len() {
        bail!("checkpoint has {n} tensors, manifest wants {}", manifest.param_specs.len());
    }
    let mut out = Vec::with_capacity(n);
    for (name, shape) in &manifest.param_specs {
        f.read_exact(&mut n8)?;
        let len = u64::from_le_bytes(n8) as usize;
        if len != shape.iter().product::<usize>() {
            bail!("tensor {name} length mismatch");
        }
        let mut bytes = vec![0u8; len * 4];
        f.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.push(HostTensor::f32(shape.clone(), data));
    }
    Ok(out)
}
