//! In-tree substitutes for unavailable third-party crates (offline build):
//! JSON, PRNG, CLI parsing, summary statistics.

pub mod cli;
pub mod propcheck;
pub mod json;
pub mod rng;
pub mod stats;
