//! Small numeric helpers for benches and reports.

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile via nearest-rank on a sorted copy (p in [0, 100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Format a duration in seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3}s")
    } else if s >= 1e-3 {
        format!("{:.3}ms", s * 1e3)
    } else {
        format!("{:.1}us", s * 1e6)
    }
}

/// Format a byte count with an adaptive unit.
pub fn fmt_bytes(b: f64) -> String {
    if b >= 1e9 {
        format!("{:.2}GB", b / 1e9)
    } else if b >= 1e6 {
        format!("{:.2}MB", b / 1e6)
    } else if b >= 1e3 {
        format!("{:.1}KB", b / 1e3)
    } else {
        format!("{b:.0}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 3.0); // nearest-rank (round half up)
        assert!((stddev(&xs) - 1.118).abs() < 1e-2);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_secs(2.0), "2.000s");
        assert_eq!(fmt_secs(0.002), "2.000ms");
        assert_eq!(fmt_bytes(1500.0), "1.5KB");
    }
}
