//! Miniature property-based testing kit (offline build: no proptest crate).
//!
//! `check(name, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! from seeded RNG streams; on failure it reports the seed so the case can
//! be replayed deterministically. Shrinking is intentionally omitted — the
//! generators below produce small cases by construction.

use super::rng::Rng;

/// Run a property over `cases` seeded inputs; panics with the failing seed.
pub fn check<T, G, P>(name: &str, cases: u64, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for seed in 0..cases {
        let mut rng = Rng::new(0x9E1A_0000 ^ seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!("property {name} failed at seed {seed}: {msg}");
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.range_f64(lo, hi)
    }

    /// Random routing problem: (indices, weights, n_tokens, k, n_experts).
    pub fn routing(rng: &mut Rng) -> (Vec<i32>, Vec<f32>, usize, usize, usize) {
        let n_tokens = usize_in(rng, 1, 64);
        let k = usize_in(rng, 1, 3);
        let n_experts = [2, 4, 8][rng.below(3)];
        let mut indices = Vec::with_capacity(n_tokens * k);
        let mut weights = Vec::with_capacity(n_tokens * k);
        for _ in 0..n_tokens {
            // k distinct experts per token, descending weights
            let mut picked: Vec<usize> = Vec::new();
            while picked.len() < k.min(n_experts) {
                let e = rng.below(n_experts);
                if !picked.contains(&e) {
                    picked.push(e);
                }
            }
            while picked.len() < k {
                picked.push(picked[0]);
            }
            let mut ws: Vec<f32> = (0..k).map(|_| rng.next_f32() + 0.01).collect();
            ws.sort_by(|a, b| b.total_cmp(a));
            let total: f32 = ws.iter().sum();
            for (e, w) in picked.iter().zip(ws) {
                indices.push(*e as i32);
                weights.push(w / total);
            }
        }
        (indices, weights, n_tokens, k, n_experts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("sum-commutes", 32, |r| (r.below(100), r.below(100)),
              |&(a, b)| if a + b == b + a { Ok(()) } else { Err("math broke".into()) });
    }

    #[test]
    #[should_panic(expected = "property always-fails failed at seed 0")]
    fn reports_failing_seed() {
        check("always-fails", 4, |r| r.below(10), |_| Err("nope".into()));
    }

    #[test]
    fn routing_generator_valid() {
        let mut rng = Rng::new(3);
        for _ in 0..50 {
            let (idx, w, t, k, e) = gen::routing(&mut rng);
            assert_eq!(idx.len(), t * k);
            assert_eq!(w.len(), t * k);
            assert!(idx.iter().all(|&i| (i as usize) < e));
        }
    }
}
