//! Deterministic PRNG (splitmix64 core) — the offline build has no `rand`.
//!
//! Used by the data generators, the property-test kit and the cluster
//! simulator. Not cryptographic.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork a child stream (stable under reordering of other draws).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut r = Rng::new(self.state ^ stream.wrapping_mul(0xA0761D6478BD642F));
        r.next_u64();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            let n = r.below(10);
            assert!(n < 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fork_independent() {
        let r = Rng::new(1);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
