//! Minimal JSON parser/serializer (offline build: no serde available).
//!
//! Supports the full JSON grammar needed by `manifest.json` and the metric
//! dumps: objects, arrays, strings with escapes, numbers, bools, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
    /// Strict field access with a useful error message.
    pub fn field(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing field {key:?}"))
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {text:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape")?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| format!("invalid utf8: {e}"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                other => return Err(format!("expected , or ] got {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                other => return Err(format!("expected , or }} got {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "x\ny", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(2.5));
        assert_eq!(v.get("b").unwrap().as_str(), Some("x\ny"));
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_trailing() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn nested() {
        let v = Json::parse(r#"{"o":{"p":[{"q":[[1]]}]}}"#).unwrap();
        let q = v.get("o").unwrap().get("p").unwrap().as_arr().unwrap()[0]
            .get("q")
            .unwrap();
        assert_eq!(q.as_arr().unwrap()[0].as_arr().unwrap()[0].as_f64(), Some(1.0));
    }
}
