//! Tiny declarative CLI argument parser (offline build: no clap).
//!
//! `Args::parse` splits `--key value` / `--key=value` / `--flag` style
//! options plus positionals; typed getters with defaults.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options.insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.str_opt(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parse_mixed() {
        let a = Args::parse(&sv(&["train", "--steps", "100", "--arch=scmoe", "--verbose"]));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("steps", 0), 100);
        assert_eq!(a.str_or("arch", ""), "scmoe");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse(&sv(&[]));
        assert_eq!(a.usize_or("n", 7), 7);
        assert_eq!(a.f64_or("x", 1.5), 1.5);
    }
}
